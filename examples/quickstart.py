"""Quickstart: train a tiny LM under full LMS monitoring in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

What you get: a monitored training job (HPM metrics derived from the
compiled step's cost analysis + live loss/grad series), streaming
pathological-job detection, and a generated dashboard (JSON + self-
contained HTML) in ./quickstart_out/.
"""

import sys

sys.path.insert(0, "src")

from repro.configs import ShapeConfig, TrainConfig, get_config
from repro.core import MonitoringStack
from repro.train.loop import train


def main():
    cfg = get_config("lms-demo", smoke=True)        # reduced llama-style LM
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8,
                        kind="train")
    tcfg = TrainConfig(total_steps=40, warmup_steps=4, learning_rate=3e-3)

    stack = MonitoringStack.inprocess(out_dir="quickstart_out")
    stack.on_finding(lambda f: print(f"!! finding: {f.rule} on {f.host}"))

    losses = []
    result = train(cfg, tcfg, shape, stack=stack, user="quickstart",
                   job_id="quickstart",
                   step_callback=lambda s, m: losses.append(
                       float(m["loss"])))

    print(f"\ntrained {result.steps_run} steps: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    db = stack.backend.db("global")
    mfu = db.aggregate("hpm", "mfu", agg="mean")
    print(f"measurements collected: {db.measurements()}")
    print(f"mean MFU (CPU, so tiny): {mfu.get('', 0):.2e}")

    job = stack.router.jobs.all_jobs()[-1]
    path = stack.dashboards.write_dashboard(job)
    print(f"dashboard: {path} (+ .html next to it)")


if __name__ == "__main__":
    main()
