"""Serving example: batched requests through a monitored ServingEngine.

    PYTHONPATH=src python examples/serve_requests.py

Per-request TTFT/latency and per-batch decode throughput land in the LMS
as ``serve_request`` / ``serve_decode`` measurements — a serving job is
monitored exactly like a training job.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_config
from repro.core import MonitoringStack
from repro.models.transformer import init_model_params
from repro.serve.engine import ServingEngine


def main():
    cfg = get_config("lms-demo", smoke=True)
    params = init_model_params(cfg, seed=0)
    stack = MonitoringStack.inprocess(out_dir="serve_out")
    rng = np.random.default_rng(0)

    with stack.job("serve-demo", user="server", hosts=["host0"]) as job:
        um = stack.usermetric(host="host0")
        engine = ServingEngine(cfg, params, max_batch=4, max_len=96,
                               usermetric=um)
        for i in range(12):
            prompt = rng.integers(1, cfg.vocab_size, rng.integers(4, 20))
            engine.submit(prompt, max_new_tokens=12)
        done = engine.run_until_empty()
        um.flush()

    for r in done[:4]:
        print(f"req {r.rid}: {len(r.output)} tokens, "
              f"ttft {1e3 * (r.first_token_at - r.submitted_at):.1f}ms, "
              f"latency {1e3 * (r.finished_at - r.submitted_at):.1f}ms")
    db = stack.backend.db("global")
    agg = db.aggregate("serve_decode", "tokens_per_s", agg="mean")
    print(f"\nmean decode throughput: {agg.get('', 0):.1f} tok/s")
    print(f"dashboard: {stack.dashboards.write_dashboard(job)}")


if __name__ == "__main__":
    main()
