"""Paper Fig. 2 + Fig. 4 reproduction on a simulated 4-node cluster.

    PYTHONPATH=src python examples/pathological_jobs.py

Three jobs run "concurrently" (simulated timestamps, no sleeps):

  * job-healthy   — all hosts busy;
  * job-idle      — one host's FP rate + memory bandwidth drop below the
                    thresholds for >10 minutes (Fig. 4's "break in
                    computation");
  * job-straggler — one host's step time is 30% above its peers.

The continuous analysis engine flags both pathological jobs (alerts open,
extend, and resolve — hysteresis keeps a flapping metric from re-firing),
persists the full lifecycle plus a per-job footprint report into the TSDB
as the ``analysis`` measurement, and the admin view (Fig. 2) lists every
job with its alert count; each job gets a templated dashboard whose
analysis header reads the persisted findings (no rule rescan per render).
"""

import sys

sys.path.insert(0, "src")

from repro.core import MonitoringStack, now_ns
from repro.core.analysis import default_rules


def simulate(stack, job_id, *, idle_host=None, straggler_host=None,
             minutes=30):
    hosts = [f"{job_id}-h{i}" for i in range(4)]
    with stack.job(job_id, user="alice", hosts=hosts,
                   tags={"arch": "miniMD"}) as job:
        agents = {h: stack.host_agent(
            h, hlo_flops=5e14, model_flops=4.2e14, hlo_bytes=3e11,
            collective_bytes=2e10, tokens_per_step=2 ** 20) for h in hosts}
        um = stack.usermetric(host=hosts[0], jobid=job_id)
        um.event("run_state", "starting miniMD")
        t0 = now_ns()
        for step in range(minutes * 6):               # a step every 10 s
            ts = t0 + step * 10 * 10 ** 9
            for h, agent in agents.items():
                step_time = 10.0
                extra = {"data_wait_s": 0.2}
                if h == idle_host and step > 30:
                    step_time = 1000.0                # FP rate collapses
                if h == straggler_host:
                    step_time = 13.0                  # +30% vs peers
                skew = 0.3 if straggler_host == h else 0.0
                extra["straggler_skew"] = skew
                agent.collect_step(step=step, step_time_s=step_time,
                                   extra_events=extra, ts=ts)
            # application-level series (Fig. 3): pressure/energy analogues
            um.metric("minimd", {"pressure": 42.0 + 0.1 * step,
                                 "energy": -1520.0 + 0.05 * step}, ts=ts)
        um.event("run_state", "finished miniMD")
        um.flush()
    return job


def main():
    stack = MonitoringStack.inprocess(out_dir="pathological_out",
                                      rules=default_rules(
                                          idle_timeout_s=600))
    stack.on_finding(lambda f: print(
        f"  !! live finding: {f.rule:22s} host={f.host:16s} "
        f"after {f.duration_s:5.0f}s"))

    print("simulating job-healthy ...")
    j1 = simulate(stack, "job-healthy")
    print("simulating job-idle (Fig. 4) ...")
    j2 = simulate(stack, "job-idle", idle_host="job-idle-h3")
    print("simulating job-straggler ...")
    j3 = simulate(stack, "job-straggler",
                  straggler_host="job-straggler-h1")

    print("\nalert lifecycle (all resolved at their last violation when "
          "the job ended):")
    for a in stack.findings():
        print(f"  {a.rule:22s} {a.host:18s} {a.duration_s:6.0f}s "
              f"[{a.severity}] state={a.state} job={a.jobid}")

    print("\nper-job footprint reports (persisted as the `analysis` "
          "measurement):")
    for job in (j1, j2, j3):
        rep = stack.analysis.job_report(job.job_id)
        print(f"  {job.job_id:16s} status={rep['status']:9s} "
              f"pattern={rep['pattern']:24s} alerts={len(rep['alerts'])} "
              f"mfu~{rep['metrics']['mfu']['mean']:.3f}")

    for job in (j1, j2, j3):
        print(f"dashboard: {stack.dashboards.write_dashboard(job)}")
    admin = stack.dashboards.write_admin_view([j1, j2, j3])
    print(f"admin view (Fig. 2): {admin}")


if __name__ == "__main__":
    main()
