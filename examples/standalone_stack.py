"""The paper's integration story: LMS components used WITHOUT the training
framework — an HTTP router endpoint fed by external collectors.

    PYTHONPATH=src python examples/standalone_stack.py

Starts the router's HTTP face (the InfluxDB-compatible /write API plus the
job-signal endpoint), then plays three external clients against it:

  1. a "Diamond-style" host daemon POSTing batched system metrics,
  2. the libusermetric CLI sending app metrics/events from a "batch
     script" (paper §IV),
  3. a raw ``urllib`` client standing in for "cronjobs sending metrics
     with curl" (paper §III.A),
  3b. a high-rate collector on the *binary ingest plane*
     (``repro.core.ingest``): persistent socket, columnar frames sharing
     the WAL codec, explicit backpressure — with the HTTP line path as
     automatic fallback,
  4. a ``POST /query/v2`` client running a *derived-metric query*
     (``repro.core.query``): a performance-group formula evaluated at
     query time over the stored windows, grouped and top-k'd server-side
     — nothing in the stored points ever carried the derived metric.

Everything lands tagged in the TSDB; the dashboard agent renders the job.
The stack runs with crash-safe persistence on (``persist_dir``): run the
example twice and the second run recovers the first run's history from
the segmented WAL before serving — kill it mid-run and it still comes
back (torn tails are truncated, never fatal).
"""

import json
import sys
import tempfile
import urllib.request

sys.path.insert(0, "src")

from repro.core import (HttpSink, LMSHttpServer, MetricsRouter,
                        MonitoringStack, Point, UserMetric, now_ns)
from repro.core.usermetric_cli import main as cli


def main():
    persist_dir = f"{tempfile.gettempdir()}/lms_standalone_wal"
    stack = MonitoringStack.inprocess(out_dir="standalone_out",
                                      persist_dir=persist_dir,
                                      serve_http=True, serve_ingest=True)
    url = stack.http.url
    print(f"LMS router HTTP endpoint: {url}")
    if stack.recovery_stats:
        rec = stack.recovery_stats.get("global", {})
        print(f"recovered previous run from {persist_dir}: "
              f"{rec.get('snapshot_points', 0)} snapshot points + "
              f"{rec.get('points_replayed', 0)} WAL points; alert state: "
              f"{stack.analysis_recovery}")

    # job allocation signal (normally sent by the scheduler prolog)
    sink = HttpSink(url)
    sink.job_start("batch-7", "carol", ["n01", "n02"],
                   {"queue": "standard"})

    # 1. Diamond-style daemon: batched system metrics over HTTP
    daemon = UserMetric(HttpSink(url), hostname="n01", batch_size=32)
    t0 = now_ns()
    for i in range(100):
        daemon.metric("system", {"cpu_load_1m": 3.5 + 0.01 * i,
                                 "net_tx_bytes": 1e6 * i},
                      ts=t0 + i * 10 ** 9)
    daemon.flush()

    # 2. the usermetric CLI, as a batch script would call it
    cli(["--url", url, "--hostname", "n02",
         "event", "run_state", "starting miniMD"])
    cli(["--url", url, "--hostname", "n02",
         "metric", "pressure", "41.7", "--tag", "region=init"])

    # 3. raw curl-style POST of line protocol
    body = f"temperature,hostname=n01 celsius=61.5 {now_ns()}".encode()
    urllib.request.urlopen(urllib.request.Request(
        f"{url}/write?db=global", data=body, method="POST"))

    # 3b. binary ingest plane: a high-rate collector on a persistent
    #     socket (columnar frames = the WAL's own codec), HTTP fallback
    #     configured; the server surfaces its counters on /meta
    bsink = stack.binary_sink(fallback=HttpSink(url))
    bsink.write([Point("hpm", {"hostname": "n01"},
                       {"mfu": 0.41 + 0.0001 * s, "step": float(s)},
                       t0 + s * 10 ** 9) for s in range(256)])
    bsink.close()
    ing = json.load(urllib.request.urlopen(
        f"{url}/meta?what=ingest"))["ingest"]
    print(f"binary ingest plane: {ing['points_ok']} pts over "
          f"{ing['connections_total']} connection(s), "
          f"{ing['shed_frames']} shed frames")

    # 4. derived-metric query over the wire: load per MB of network send,
    #    derived at query time from the daemon's stored raw fields (no
    #    such metric was ever POSTed), 10 s windows, grouped by host
    spec = {"measurement": "system",
            "metrics": [["load_per_net_mb",
                         "cpu_load_1m / (net_tx_bytes / 1e6 + 1)"]],
            "window_ns": 10 * 10 ** 9, "group_by": "hostname",
            "order_by": "load_per_net_mb", "limit": 3}
    req = urllib.request.Request(
        f"{url}/query/v2", data=json.dumps({"spec": spec}).encode(),
        method="POST", headers={"Content-Type": "application/json"})
    res = json.load(urllib.request.urlopen(req))["result"]
    for host, metrics in res["groups"].items():
        windows = metrics["load_per_net_mb"]["values"]
        print(f"derived load_per_net_mb[{host}]: {len(windows)} windows, "
              f"last={windows[-1]:.4g}")

    sink.job_end("batch-7")

    # the continuous analysis engine persisted the job's alert history and
    # footprint report — both are plain HTTP endpoints
    alerts = json.load(urllib.request.urlopen(f"{url}/alerts?jobid=batch-7"))
    print(f"alerts for batch-7: {alerts['alerts'] or 'none'}")
    report = json.load(urllib.request.urlopen(f"{url}/jobs/batch-7/report"))
    print(f"report: pattern={report['report']['pattern']!r} "
          f"status={report['report']['status']}")

    db = stack.backend.db("global")
    print(f"measurements: {db.measurements()}")
    for meas in ("system", "pressure", "temperature"):
        for s in db.select(meas):
            print(f"  {meas:12s} tags={s.tags}")
    job = stack.router.jobs.get("batch-7")
    print(f"dashboard: {stack.dashboards.write_dashboard(job)}")
    stack.close()


if __name__ == "__main__":
    main()
