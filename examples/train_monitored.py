"""End-to-end driver: train the ~115M-parameter lms-demo config for a few
hundred steps under the full monitoring stack, with checkpointing and
(optionally) an injected failure + automatic restart.

    PYTHONPATH=src python examples/train_monitored.py --steps 300
    PYTHONPATH=src python examples/train_monitored.py --steps 60 \
        --inject-failure 30          # crash at step 30, auto-resume, finish

This is the assignment's "train ~100M model for a few hundred steps"
deliverable; on one CPU core a step at seq 256 x batch 8 takes a few
seconds — pass --steps 40 for a quick look.  The same driver runs the
full-size assigned configs on real hardware (see repro.launch.train for
the mesh-aware CLI).
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ShapeConfig, TrainConfig, get_config
from repro.core import MonitoringStack
from repro.train.loop import InjectedFailure, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="train_monitored_ckpt")
    args = ap.parse_args()

    cfg = get_config("lms-demo")                    # full ~115M config
    print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.0f}M params")
    shape = ShapeConfig("e2e", seq_len=args.seq_len,
                        global_batch=args.batch, kind="train")
    tcfg = TrainConfig(total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20),
                       learning_rate=6e-4, ckpt_dir=args.ckpt_dir,
                       ckpt_interval=20)

    stack = MonitoringStack.inprocess(out_dir="train_monitored_out")

    def cb(step, metrics):
        if step % 10 == 0 or step <= 2:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}",
                  flush=True)

    try:
        r = train(cfg, tcfg, shape, stack=stack, step_callback=cb,
                  fail_at_step=args.inject_failure, job_id="e2e")
    except InjectedFailure as e:
        print(f"\n-- {e}; restarting (auto-resume from checkpoint) --\n")
        r = train(cfg, tcfg, shape, stack=stack, step_callback=cb,
                  job_id="e2e-restart")
        print(f"resumed from step {r.resumed_from}")

    print(f"\nfinal loss {r.last_loss:.4f} after {r.final_step} steps")
    job = stack.router.jobs.all_jobs()[-1]
    print(f"dashboard: {stack.dashboards.write_dashboard(job)}")


if __name__ == "__main__":
    main()
