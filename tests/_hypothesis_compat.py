"""Fallback for ``hypothesis`` so test modules collect without it.

Property tests in this repo guard their import with::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

On images without hypothesis, ``given``-decorated tests are collected as
zero-argument functions that skip with a clear reason, while every other
test in the module runs normally — collection never fails.  The strategy
namespace ``st`` accepts any strategy-building call chain (``st.text(...)
.filter(...)``) made at module-import time and returns inert objects.
"""

import pytest


class _Strategy:
    """Inert stand-in for a hypothesis strategy (chainable, never drawn)."""

    def __call__(self, *args, **kwargs):
        return _Strategy()

    def filter(self, *args, **kwargs):
        return self

    def map(self, *args, **kwargs):
        return self

    def flatmap(self, *args, **kwargs):
        return self


class _StrategiesModule:
    def __getattr__(self, name):
        return _Strategy()


st = _StrategiesModule()


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    def deco(fn):
        # zero-arg wrapper: the original signature only names strategy-
        # provided params, which pytest would otherwise demand as fixtures
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        skipper.__module__ = fn.__module__
        return skipper
    return deco
