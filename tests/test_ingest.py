"""Binary ingest plane (repro.core.ingest) + ingest-edge bugfixes.

Covers: codec round-trip vs the line protocol (property + seeded
fallback), byte-identical DB state binary vs HTTP line path, automatic
reconnect and HTTP fallback, queue-full shedding (no point lost or
duplicated after retry), and the four edge bugfix regressions
(partial-write /write, 204 without body, UserMetric implicit-flush
swallowing, request-body cap -> 413).
"""

import json
import random
import socket
import threading
import time
import urllib.error
import urllib.request

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.httpd import HttpSink, LMSHttpServer
from repro.core.ingest import (BinarySink, IngestError, IngestServer,
                               MAGIC, points_to_entries)
from repro.core.line_protocol import Point, decode_batch_errors
from repro.core.router import MetricsRouter
from repro.core.tsdb import TSDBServer
from repro.core.usermetric import UserMetric
from repro.core.wal import decode_batch_payload, encode_batch_payload


@pytest.fixture
def router():
    return MetricsRouter(TSDBServer(), per_job_db=True, per_user_db=True)


@pytest.fixture
def served(router):
    srv = IngestServer(router).start()
    yield router, srv
    srv.stop()


def _db_state(db, measurements):
    """Canonical dump of a database's series (sorted, JSON-encoded) —
    two ingest paths are equivalent iff these bytes are identical."""
    out = []
    for m in measurements:
        for s in sorted(db.select(m), key=lambda s: sorted(s.tags.items())):
            out.append([m, sorted(s.tags.items()), s.times,
                        sorted(s.values.items())])
    return json.dumps(out, sort_keys=True).encode()


def _mixed_points(n=200, hosts=3, seed=7):
    rng = random.Random(seed)
    pts = []
    for i in range(n):
        host = f"h{rng.randrange(hosts)}"
        fields = {"value": rng.uniform(-1e6, 1e6),
                  "step": rng.randrange(1 << 40)}
        if rng.random() < 0.2:
            fields["state"] = rng.choice(["ok", "warn", "x\ny"])
        if rng.random() < 0.1:
            fields["flag"] = rng.random() < 0.5
        pts.append(Point(rng.choice(["hpm", "system"]),
                         {"hostname": host}, fields, 1_000_000 + i))
    return pts


# -- codec round-trip ---------------------------------------------------------


def _assert_roundtrip(points):
    entries = points_to_entries(points)
    decoded = decode_batch_payload(encode_batch_payload(entries))
    rebuilt = []
    for m, tags, times, cols in decoded:
        for i, t in enumerate(times):
            rebuilt.append(Point(m, dict(tags),
                                 {k: c[i] for k, c in cols.items()
                                  if c[i] is not None}, t))
    def key(p):
        # repr-keyed fields: deterministic total order even when points
        # sharing (meas, tags, ts) carry different field *types*
        return (p.measurement, sorted(p.tags.items()), p.timestamp,
                repr(sorted(p.fields.items())))
    orig = sorted(points, key=key)
    back = sorted(rebuilt, key=key)
    assert len(orig) == len(back)
    for a, b in zip(orig, back):
        assert a.measurement == b.measurement
        assert a.tags == b.tags
        assert a.timestamp == b.timestamp
        assert a.fields == b.fields   # exact types incl. bool/int/str


def test_codec_roundtrip_seeded():
    """Seeded fallback for the property below: mixed numeric/str/bool
    fields with None holes survive the wire byte-exactly."""
    for seed in range(5):
        _assert_roundtrip(_mixed_points(seed=seed))


@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.tuples(
        st.sampled_from(["m1", "m2"]),
        st.sampled_from(["h0", "h1"]),
        st.integers(min_value=0, max_value=2**48),
        st.one_of(st.floats(allow_nan=False, allow_infinity=False),
                  st.integers(min_value=-2**62, max_value=2**62),
                  st.booleans(),
                  st.text(max_size=8)),
    ),
    min_size=1, max_size=60))
def test_codec_roundtrip_property(rows):
    pts = [Point(m, {"hostname": h}, {"value": v}, ts)
           for m, h, ts, v in rows]
    _assert_roundtrip(pts)


# -- binary vs HTTP equivalence ----------------------------------------------


def test_binary_matches_http_line_path():
    """The same workload through the binary plane and through /write
    must leave byte-identical query results (acceptance criterion)."""
    pts = _mixed_points()

    r_bin = MetricsRouter(TSDBServer(), per_job_db=True, per_user_db=True)
    r_http = MetricsRouter(TSDBServer(), per_job_db=True, per_user_db=True)
    for r in (r_bin, r_http):
        r.job_start("j1", "alice", ["h0", "h1"], {"arch": "demo"}, ts=1)

    srv = IngestServer(r_bin).start()
    try:
        sink = BinarySink(srv.host, srv.port)
        assert sink.write(pts) == len(pts)
        sink.close()
    finally:
        srv.stop()
    with LMSHttpServer(r_http) as hsrv:
        HttpSink(hsrv.url).write(pts)

    meas = ["hpm", "system"]
    for dbname in ("global", "job_j1", "user_alice"):
        a = _db_state(r_bin.backend.db(dbname), meas)
        b = _db_state(r_http.backend.db(dbname), meas)
        assert a == b, f"state diverged in {dbname}"


def test_binary_ingest_persisted_wal(tmp_path):
    """Columnar writes go through the WAL: a recovered store answers
    exactly like the one that ingested over the socket."""
    backend = TSDBServer(persist_dir=str(tmp_path))
    router = MetricsRouter(backend)
    pts = _mixed_points(n=80)
    srv = IngestServer(router).start()
    try:
        sink = BinarySink(srv.host, srv.port)
        assert sink.write(pts) == len(pts)
        sink.close()
    finally:
        srv.stop()
    want = _db_state(backend.db("global"), ["hpm", "system"])
    backend.close()

    backend2 = TSDBServer(persist_dir=str(tmp_path))
    stats = backend2.load_persisted()
    assert stats["global"]["points_replayed"] == len(pts)
    assert _db_state(backend2.db("global"), ["hpm", "system"]) == want
    backend2.close()


def test_write_entries_enriches_per_series(served):
    router, srv = served
    router.job_start("j7", "dana", ["h0"])
    sink = BinarySink(srv.host, srv.port)
    sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 10),
                Point("m", {"hostname": "nope"}, {"v": 2.0}, 11),
                Point("m", {}, {"v": 3.0}, 12)])     # no host -> dropped
    sink.close()
    s = router.backend.db("global").select("m", ["v"], {"jobid": "j7"})
    assert len(s) == 1 and s[0].tags["username"] == "dana"
    assert router.stats.snapshot()["dropped_no_host"] == 1
    # per-job/per-user duplication happened for the tagged series only
    assert router.backend.db("job_j7").point_count() == 1
    assert router.backend.db("user_dana").point_count() == 1


# -- transport: reconnect, fallback, shed ------------------------------------


def test_sink_reconnects_after_server_side_drop(served):
    router, srv = served
    sink = BinarySink(srv.host, srv.port)
    assert sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)]) == 1
    # kill every server-side connection under the client
    with srv._lock:
        conns = list(srv._conns)
    for c in conns:
        c.close()
    time.sleep(0.05)
    assert sink.write([Point("m", {"hostname": "h0"}, {"v": 2.0}, 2)]) == 1
    assert sink.stats["reconnects"] >= 1
    assert router.backend.db("global").select("m")[0].times == [1, 2]
    sink.close()


def test_sink_falls_back_to_http(router):
    """Binary endpoint down -> the batch flows through the HTTP line
    path instead; after the cooldown the sink retries binary."""
    with LMSHttpServer(router) as hsrv:
        # a port with no listener: connect() must fail fast
        dead = socket.socket()
        dead.bind(("127.0.0.1", 0))
        port = dead.getsockname()[1]
        dead.close()
        sink = BinarySink("127.0.0.1", port, fallback=HttpSink(hsrv.url),
                          fallback_cooldown_s=60.0)
        n = sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)])
        assert n == 1
        st = sink.stats
        assert st["fallback_batches"] == 1 and st["batches"] == 0
        # inside the cooldown the sink goes straight to HTTP
        sink.write([Point("m", {"hostname": "h0"}, {"v": 2.0}, 2)])
        assert sink.stats["fallback_batches"] == 2
        sink.close()
    assert router.backend.db("global").select("m")[0].times == [1, 2]


def test_sink_without_fallback_raises(router):
    dead = socket.socket()
    dead.bind(("127.0.0.1", 0))
    port = dead.getsockname()[1]
    dead.close()
    sink = BinarySink("127.0.0.1", port)
    with pytest.raises(OSError):
        sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)])


def _raw_conn(srv):
    """Handshaken raw socket — for pipelining frames (multiplexed
    req_ids), which the synchronous BinarySink never does."""
    from repro.core.ingest import _FRAME, _recv_exact
    s = socket.create_connection((srv.host, srv.port), timeout=10.0)
    s.sendall(MAGIC + (0).to_bytes(2, "little"))
    _, _, ln = _FRAME.unpack(_recv_exact(s, _FRAME.size))   # T_HELLO
    _recv_exact(s, ln)
    return s


def test_queue_full_sheds_then_retry_is_exact(router):
    """Overload: 20 pipelined writes against a slow worker and a
    2-deep queue force shed frames; the client resends each shed
    req_id after the advertised delay and every point lands exactly
    once — nothing lost, nothing duplicated, nothing stalls."""
    from repro.core.ingest import _FRAME, _recv_exact, T_OK, T_SHED, T_WRITE
    orig = router.write_entries

    def slow_write_entries(entries):
        time.sleep(0.02)
        return orig(entries)
    router.write_entries = slow_write_entries

    srv = IngestServer(router, queue_max=2, shed_retry_after_s=0.01)
    srv.start()
    try:
        s = _raw_conn(srv)
        payloads = {
            rid: encode_batch_payload(
                [("m", {"hostname": "h0"}, [rid], {"v": [float(rid)]})])
            for rid in range(1, 21)}
        for rid, pl in payloads.items():        # pipeline all 20 at once
            s.sendall(_FRAME.pack(T_WRITE, rid, len(pl)) + pl)
        pending = set(payloads)
        sheds = 0
        while pending:
            ftype, rid, ln = _FRAME.unpack(_recv_exact(s, _FRAME.size))
            body = _recv_exact(s, ln) if ln else b""
            if ftype == T_OK:
                pending.discard(rid)
            elif ftype == T_SHED:
                # explicit shed: the batch was NOT applied server-side,
                # so the resend below is exactly-once
                sheds += 1
                time.sleep(0.01)
                pl = payloads[rid]
                s.sendall(_FRAME.pack(T_WRITE, rid, len(pl)) + pl)
            else:
                raise AssertionError(f"unexpected frame type {ftype}")
        s.close()
        assert sheds > 0                        # overload really shed
        assert srv.stats()["shed_frames"] == sheds
        series = router.backend.db("global").select("m")
        times = sorted(t for se in series for t in se.times)
        assert times == list(range(1, 21))      # exactly once each
    finally:
        router.write_entries = orig
        srv.stop()


def test_shed_budget_exhaustion_raises(router):
    """A sink whose server sheds past max_shed_retries surfaces an
    IngestError (never a silent drop or an unbounded stall)."""
    from repro.core.ingest import (_FRAME, _HELLO_DB, _SHED_BODY,
                                   _recv_exact, T_HELLO, T_SHED)
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    port = lst.getsockname()[1]

    def shed_everything():
        conn, _ = lst.accept()
        _recv_exact(conn, len(MAGIC))
        (n,) = _HELLO_DB.unpack(_recv_exact(conn, _HELLO_DB.size))
        if n:
            _recv_exact(conn, n)
        conn.sendall(_FRAME.pack(T_HELLO, 0, 2) + b"{}")
        try:
            while True:
                _, rid, ln = _FRAME.unpack(_recv_exact(conn, _FRAME.size))
                if ln:
                    _recv_exact(conn, ln)
                conn.sendall(_FRAME.pack(T_SHED, rid, _SHED_BODY.size)
                             + _SHED_BODY.pack(0.001))
        except (ConnectionError, OSError):
            pass

    t = threading.Thread(target=shed_everything, daemon=True)
    t.start()
    sink = BinarySink("127.0.0.1", port, max_shed_retries=2)
    with pytest.raises(IngestError, match="shed"):
        sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)])
    assert sink.stats["sheds"] == 3             # initial + 2 retries
    sink.close()
    lst.close()


def test_oversized_frame_rejected(served):
    router, srv = served
    srv.max_frame_bytes = 1024
    sink = BinarySink(srv.host, srv.port)
    pts = [Point("m", {"hostname": "h0"}, {"v": float(i)}, i)
           for i in range(1000)]
    with pytest.raises(IngestError, match="exceeds limit"):
        sink.write(pts)
    # the connection survives (stream stayed in sync) and serves more
    assert sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)]) == 1
    sink.close()


def test_handshake_rejects_bad_magic(served):
    router, srv = served
    s = socket.create_connection((srv.host, srv.port), timeout=2.0)
    s.sendall(b"NOTMAGIC" + b"\x00\x00")
    s.settimeout(2.0)
    try:
        assert s.recv(1) == b""      # server closed the connection (FIN)
    except ConnectionError:
        pass                         # ... or reset it outright (RST)
    s.close()


def test_meta_ingest_counters(served):
    router, srv = served
    sink = BinarySink(srv.host, srv.port)
    sink.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)])
    assert sink.ping()
    sink.close()
    with LMSHttpServer(router) as hsrv:
        with urllib.request.urlopen(hsrv.url + "/meta?what=ingest") as r:
            meta = json.loads(r.read())["ingest"]
    assert meta["batches_ok"] == 1 and meta["points_ok"] == 1
    assert meta["pings"] == 1 and meta["shed_frames"] == 0
    assert meta["queue_max"] == srv.queue_max


def test_usermetric_over_binary_sink(served):
    router, srv = served
    sink = BinarySink(srv.host, srv.port)
    um = UserMetric(sink, hostname="h0", batch_size=8,
                    flush_interval_s=9999)
    for i in range(20):
        um.metric("loss", float(i), ts=i + 1)
    um.close()
    sink.close()
    s = router.backend.db("global").select("loss")[0]
    assert s.times == list(range(1, 21))


# -- satellite regressions ----------------------------------------------------


def test_partial_write_semantics(router):
    """One malformed line must not abort its siblings (regression: the
    whole batch used to 400 and drop)."""
    body = ("m,hostname=h0 v=1.0 1\n"
            "m,hostname=h0 v=12xi 2\n"          # bad integer field
            "m,hostname=h0 v=3.0 zzz\n"         # bad timestamp
            "m,hostname=h0 v=4.0 4")
    res = router.write_lines(body)
    assert res["written"] == 2
    assert [e["line"] for e in res["errors"]] == [2, 3]
    assert all("bad" in e["error"] for e in res["errors"])
    s = router.backend.db("global").select("m")[0]
    assert s.times == [1, 4]
    assert router.stats.snapshot()["parse_errors"] == 2


def test_parse_field_value_raises_protocol_error():
    from repro.core.line_protocol import (LineProtocolError,
                                          _parse_field_value)
    with pytest.raises(LineProtocolError):
        _parse_field_value("12xi")
    pts, errs = decode_batch_errors("m,hostname=h0 v=12xi 1")
    assert pts == [] and errs[0]["line"] == 1


def test_http_write_reports_partial_errors(router):
    with LMSHttpServer(router) as srv:
        body = b"m,hostname=h0 v=1.0 1\nm,hostname=h0 v=bogusx 2"
        req = urllib.request.Request(srv.url + "/write", data=body,
                                     method="POST")
        with urllib.request.urlopen(req) as r:
            assert r.status == 200
            out = json.loads(r.read())
        assert out["written"] == 1 and out["errors"][0]["line"] == 2
        # nothing parsed -> 400
        req = urllib.request.Request(srv.url + "/write", data=b"garbage",
                                     method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400


def test_204_has_no_body(router):
    """RFC 9110 §6.4.1 regression: /ping 204 must not carry a body or
    Content-Length — raw socket read so no client library hides it."""
    with LMSHttpServer(router) as srv:
        host, port = srv.httpd.server_address[:2]
        s = socket.create_connection((host, port), timeout=2.0)
        s.sendall(b"GET /ping HTTP/1.1\r\nHost: x\r\n"
                  b"Connection: close\r\n\r\n")
        raw = b""
        while True:
            chunk = s.recv(4096)
            if not chunk:
                break
            raw += chunk
        s.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    assert b"204" in head.split(b"\r\n")[0]
    assert b"content-length" not in head.lower()
    assert body == b""


def test_usermetric_implicit_flush_never_raises():
    """Monitoring must not crash the monitored app: a batch-size-
    triggered flush with a dead sink is swallowed (and counted);
    explicit flush() still raises."""
    def sink(points):
        raise ConnectionError("router down")

    um = UserMetric(sink, batch_size=2, flush_interval_s=9999,
                    hostname="h0")
    um.metric("v", 1.0)
    um.metric("v", 2.0)          # triggers implicit flush -> swallowed
    um.metric("v", 3.0)
    st = um.stats
    assert st["failed_flushes"] >= 1 and st["buffered"] == 3
    with pytest.raises(ConnectionError):
        um.flush()               # explicit stays loud


def test_host_agent_survives_dead_router():
    from repro.core.host_agent import HostAgent

    class DeadRouter:
        def write(self, points):
            raise ConnectionError("down")

    agent = HostAgent(DeadRouter(), hostname="h0", batch_size=1,
                      max_pending_points=10)
    for step in range(20):       # collection ticks must not raise
        agent.collect_step(step=step, step_time_s=0.1)
    st = agent.emit_stats
    assert st["failed_flushes"] == 20
    assert st["pending"] == 10 and st["dropped_points"] == 10
    with pytest.raises(ConnectionError):
        agent.flush()            # explicit stays loud


def test_request_body_cap_413(router):
    with LMSHttpServer(router, max_body_bytes=1024) as srv:
        url = srv.url
        body = b"m,hostname=h0 v=1.0 1\n" * 100      # > 1 KiB
        req = urllib.request.Request(url + "/write", data=body,
                                     method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 413
        assert json.loads(ei.value.read())["max_body_bytes"] == 1024
        # small bodies still flow
        req = urllib.request.Request(url + "/write",
                                     data=b"m,hostname=h0 v=1.0 1",
                                     method="POST")
        with urllib.request.urlopen(req) as r:
            assert json.loads(r.read())["written"] == 1
    assert router.backend.db("global").point_count() == 1


def test_stack_serves_binary_plane(tmp_path):
    from repro.core import MonitoringStack
    stack = MonitoringStack(out_dir=str(tmp_path), serve_http=True,
                            serve_ingest=True, per_job_db=False)
    try:
        sink = stack.binary_sink()
        assert sink.write([Point("m", {"hostname": "h0"},
                                 {"v": 1.0}, 1)]) == 1
        sink.close()
        assert stack.router.ingest is stack.ingest
        assert stack.backend.db("global").point_count() == 1
    finally:
        stack.close()
