"""Attention path equivalences: chunked/recursive/decode vs dense masked."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (chunked_attention, full_attention,
                                    gqa_attention, mla_attention,
                                    recursive_causal_attention)
from repro.models.layers import rope_table
from repro.models.params import init_params
from repro.models.attention import attn_specs, mla_specs


def _qkv(rng, b, s, h, kv, d):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("kv", [2, 8])
def test_chunked_matches_full(rng, kv, window):
    q, k, v = _qkv(rng, 2, 128, 8, kv, 16)
    want = full_attention(q, k, v, causal=True, window=window)
    got = chunked_attention(q, k, v, causal=True, window=window, chunk_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_recursive_matches_full(rng):
    q, k, v = _qkv(rng, 1, 512, 4, 4, 16)
    want = full_attention(q, k, v, causal=True)
    got = recursive_causal_attention(q, k, v, levels=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_gqa_decode_matches_train(rng):
    """Token-by-token decode with a cache == teacher-forced attention."""
    cfg = get_config("granite-3-8b", smoke=True)
    params = init_params(attn_specs(cfg), seed=0)
    b, s = 2, 16
    x = 0.1 * jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                          jnp.float32)
    cos, sin = rope_table(jnp.arange(s)[None], cfg.head_dim, cfg.rope_theta)
    want, _ = gqa_attention(params, x, cfg, rope=(cos, sin), mode="train")

    cache = {"k": jnp.zeros((b, s, cfg.num_kv_heads, cfg.head_dim)),
             "v": jnp.zeros((b, s, cfg.num_kv_heads, cfg.head_dim))}
    outs = []
    for t in range(s):
        cos_t, sin_t = rope_table(jnp.arange(t, t + 1)[None], cfg.head_dim,
                                  cfg.rope_theta)
        y, cache = gqa_attention(params, x[:, t:t + 1], cfg,
                                 rope=(cos_t, sin_t), mode="decode",
                                 cache=cache, pos=jnp.int32(t))
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_swa_ring_buffer_decode(rng):
    """Ring-buffer SWA decode == full-cache SWA decode beyond the window."""
    cfg = get_config("mixtral-8x7b", smoke=True)   # sliding_window=16
    cfg.num_kv_heads = cfg.num_heads               # MHA for the unit test
    params = init_params(attn_specs(cfg), seed=0)
    b, s, w = 1, 48, cfg.sliding_window
    x = 0.1 * jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                          jnp.float32)
    cos, sin = rope_table(jnp.arange(s)[None], cfg.head_dim, cfg.rope_theta)
    want, _ = gqa_attention(params, x, cfg, rope=(cos, sin), mode="train")

    ring = {"k": jnp.zeros((b, w, cfg.num_kv_heads, cfg.head_dim)),
            "v": jnp.zeros((b, w, cfg.num_kv_heads, cfg.head_dim))}
    outs = []
    for t in range(s):
        cos_t, sin_t = rope_table(jnp.arange(t, t + 1)[None], cfg.head_dim,
                                  cfg.rope_theta)
        y, ring = gqa_attention(params, x[:, t:t + 1], cfg,
                                rope=(cos_t, sin_t), mode="decode",
                                cache=ring, pos=jnp.int32(t))
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4,
                               atol=3e-4)


def test_mla_decode_matches_train(rng):
    """Weight-absorbed MLA decode == decompressed train-path attention."""
    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = init_params(mla_specs(cfg), seed=0)
    b, s = 2, 12
    x = 0.1 * jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                          jnp.float32)
    rd = cfg.mla.qk_rope_head_dim
    cos, sin = rope_table(jnp.arange(s)[None], rd, cfg.rope_theta)
    want, _ = mla_attention(params, x, cfg, rope=(cos, sin), mode="train")

    cache = {"ckv": jnp.zeros((b, s, cfg.mla.kv_lora_rank)),
             "krope": jnp.zeros((b, s, rd))}
    outs = []
    for t in range(s):
        cos_t, sin_t = rope_table(jnp.arange(t, t + 1)[None], rd,
                                  cfg.rope_theta)
        y, cache = mla_attention(params, x[:, t:t + 1], cfg,
                                 rope=(cos_t, sin_t), mode="decode",
                                 cache=cache, pos=jnp.int32(t))
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_moe_dispatch_invariants(rng):
    """Sort-based MoE dispatch: top-k mass conservation + capacity."""
    from repro.models.moe import apply_moe, capacity, moe_specs
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(moe_specs(cfg), seed=0)
    b, s = 4, 16
    x = 0.1 * jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                          jnp.float32)
    y, aux = apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert 0.0 <= float(aux["moe_dropped_frac"]) < 0.5
    assert float(aux["moe_aux_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz
    # capacity is lane-aligned and >= tokens*topk/experts
    cap = capacity(cfg, b * s)
    assert cap % 8 == 0
    assert cap * cfg.moe.num_experts >= b * s * cfg.moe.top_k
