"""Ragged all-to-all MoE dispatch == reference grouped dispatch (8 host
devices, subprocess-isolated)."""

import os
import subprocess
import sys
import textwrap

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_a2a_dispatch_matches_reference():
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import apply_moe, apply_moe_a2a, moe_specs
        from repro.models.params import init_params

        cfg = get_config("mixtral-8x7b", smoke=True)
        # generous capacity so neither path drops tokens -> exact parity
        cfg.moe = dataclasses.replace(cfg.moe, num_experts=8,
                                      capacity_factor=8.0)
        params = init_params(moe_specs(cfg), seed=0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        b, s = 4, 16
        x = 0.1 * jnp.asarray(
            np.random.default_rng(0).standard_normal((b, s, cfg.d_model)),
            jnp.float32)

        want, _ = apply_moe(params, x, cfg)
        with mesh:
            got, aux = jax.jit(
                lambda p, x: apply_moe_a2a(p, x, cfg, mesh))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        assert jnp.isfinite(aux["moe_aux_loss"])

        # the lowered HLO must exchange via all-to-all, not all-reduce
        txt = jax.jit(lambda p, x: apply_moe_a2a(p, x, cfg, mesh)
                      ).lower(params, x).compile().as_text()
        assert "all-to-all" in txt
        print("A2A OK")
    """)
    assert "A2A OK" in out


def test_a2a_dispatch_differentiable():
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import apply_moe, apply_moe_a2a, moe_specs
        from repro.models.params import init_params

        cfg = get_config("mixtral-8x7b", smoke=True)
        cfg.moe = dataclasses.replace(cfg.moe, num_experts=8,
                                      capacity_factor=8.0)
        params = init_params(moe_specs(cfg), seed=0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = 0.1 * jnp.asarray(
            np.random.default_rng(1).standard_normal((4, 16, cfg.d_model)),
            jnp.float32)

        def loss_ref(p):
            y, _ = apply_moe(p, x, cfg)
            return jnp.sum(jnp.square(y))

        def loss_a2a(p):
            y, _ = apply_moe_a2a(p, x, cfg, mesh)
            return jnp.sum(jnp.square(y))

        g_ref = jax.grad(loss_ref)(params)
        with mesh:
            g_a2a = jax.jit(jax.grad(loss_a2a))(params)
        for k in ("w_gate", "w_up", "w_down"):
            np.testing.assert_allclose(np.asarray(g_a2a[k]),
                                       np.asarray(g_ref[k]),
                                       rtol=5e-3, atol=5e-4)
        print("A2A GRAD OK")
    """)
    assert "A2A GRAD OK" in out
