"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode.

(This container is CPU-only; ``interpret=True`` executes the kernel body in
Python, which validates the block decomposition, masking and online-softmax
logic.  The Mosaic lowering path is exercised on real TPUs.)
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _r(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# -- flash attention ---------------------------------------------------------

SWEEP = [
    # b, h, kv, s, d, causal, window, dtype
    (2, 4, 4, 256, 64, True, 0, jnp.float32),
    (1, 8, 2, 256, 64, True, 0, jnp.float32),
    (2, 4, 2, 256, 32, True, 64, jnp.float32),
    (1, 2, 2, 128, 64, False, 0, jnp.float32),
    (1, 4, 1, 128, 128, True, 0, jnp.float32),       # MQA
    (1, 4, 4, 128, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,h,kv,s,d,causal,window,dtype", SWEEP)
def test_flash_attention_allclose(rng, b, h, kv, s, d, causal, window,
                                  dtype):
    q = _r(rng, (b, s, h, d), dtype)
    k = _r(rng, (b, s, kv, d), dtype)
    v = _r(rng, (b, s, kv, d), dtype)
    got = ops.flash_attention_bshd(q, k, v, causal=causal, window=window,
                                   bq=64, bk=64, interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_invariance(rng):
    q = _r(rng, (1, 256, 4, 32))
    k = _r(rng, (1, 256, 2, 32))
    v = _r(rng, (1, 256, 2, 32))
    a = ops.flash_attention_bshd(q, k, v, bq=128, bk=128, interpret=True)
    b = ops.flash_attention_bshd(q, k, v, bq=32, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# -- rmsnorm -------------------------------------------------------------------


@pytest.mark.parametrize("shape,dtype", [
    ((4, 100, 512), jnp.float32),
    ((7, 384), jnp.float32),
    ((2, 64, 256), jnp.bfloat16),
])
def test_rmsnorm_allclose(rng, shape, dtype):
    x = _r(rng, shape, dtype)
    scale = _r(rng, (shape[-1],), jnp.float32)
    got = ops.fused_rmsnorm(x, scale, interpret=True)
    want = ref.rmsnorm_ref(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


# -- ssd -----------------------------------------------------------------------


@pytest.mark.parametrize("l,chunk,p,n", [(256, 64, 32, 16), (128, 128, 16, 8),
                                         (192, 64, 8, 4)])
def test_ssd_kernel_allclose(rng, l, chunk, p, n):
    b, h = 2, 3
    x = _r(rng, (b, l, h, p))
    a = -jnp.abs(_r(rng, (b, l, h))) * 0.1
    bm = _r(rng, (b, l, h, n))
    cm = _r(rng, (b, l, h, n))
    got = ops.ssd_chunked_kernel(x, a, bm, cm, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x.transpose(0, 2, 1, 3), a.transpose(0, 2, 1),
                       bm.transpose(0, 2, 1, 3), cm.transpose(0, 2, 1, 3)
                       ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_ssd_kernel_strong_decay_stable(rng):
    b, h, l, p, n = 1, 1, 128, 8, 4
    x = _r(rng, (b, l, h, p))
    a = -jnp.abs(_r(rng, (b, l, h))) * 20.0     # brutal decay
    bm = _r(rng, (b, l, h, n))
    cm = _r(rng, (b, l, h, n))
    y = ops.ssd_chunked_kernel(x, a, bm, cm, chunk=64, interpret=True)
    assert bool(jnp.all(jnp.isfinite(y)))


# -- model-level integration ---------------------------------------------------


def test_flash_impl_matches_masked_at_model_level(rng):
    """forward(attn_impl="flash") == forward(attn_impl="masked") for a
    reduced dense config (kernel runs in interpret mode on CPU)."""
    import jax
    from repro.configs import get_config
    from repro.models.transformer import forward, init_model_params

    cfg = get_config("granite-3-8b", smoke=True)
    params = init_model_params(cfg, seed=0)
    toks = jax.random.randint(jax.random.key(0), (2, 32), 0, cfg.vocab_size)
    ref_logits, _, _ = forward(params, cfg, tokens=toks, mode="train",
                               attn_impl="masked")
    fl_logits, _, _ = forward(params, cfg, tokens=toks, mode="train",
                              attn_impl="flash")
    np.testing.assert_allclose(
        np.asarray(fl_logits, np.float32), np.asarray(ref_logits, np.float32),
        rtol=5e-2, atol=5e-2)   # bf16 activations
