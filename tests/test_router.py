"""Metrics router: tag store, job signals, duplication, pub-sub, HTTP."""

import json
import urllib.request

import pytest

from repro.core.httpd import HttpSink, LMSHttpServer
from repro.core.line_protocol import Point, encode_batch
from repro.core.router import MetricsRouter
from repro.core.tsdb import TSDBServer
from repro.core.usermetric_cli import main as cli_main


@pytest.fixture
def router():
    return MetricsRouter(TSDBServer(), per_job_db=True, per_user_db=True)


def test_job_tagging(router):
    router.job_start("j1", "alice", ["h0", "h1"], {"arch": "demo"})
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    router.write(Point("m", {"hostname": "h2"}, {"v": 2.0}, 2))  # not in job
    series = router.backend.db("global").select("m", ["v"],
                                                 {"jobid": "j1"})
    assert len(series) == 1
    assert series[0].tags["username"] == "alice"
    assert series[0].tags["arch"] == "demo"
    # untagged host still stored, without job tags
    other = router.backend.db("global").select("m", ["v"],
                                               {"hostname": "h2"})
    assert "jobid" not in other[0].tags


def test_job_end_stops_tagging(router):
    router.job_start("j1", "alice", ["h0"])
    router.job_end("j1")
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    s = router.backend.db("global").select("m", ["v"])[0]
    assert "jobid" not in s.tags


def test_overlapping_jobs_on_shared_host(router):
    """Two running jobs sharing a host (regression): the flat host->tags
    store let the second ``start`` clobber the first job's enrichment and
    ``end`` of either job corrupt the survivor's.  The per-host job stack
    resolves to the most recently started *running* job, and re-exposes
    the older job when the newer one ends."""
    router.job_start("j1", "alice", ["h0", "h1"])
    router.job_start("j2", "bob", ["h0"])           # overlaps j1 on h0
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    router.write(Point("m", {"hostname": "h1"}, {"v": 1.0}, 1))
    db = router.backend.db("global")
    # latest allocation wins on the shared host; h1 still belongs to j1
    [s] = db.select("m", ["v"], {"hostname": "h0"})
    assert s.tags["jobid"] == "j2" and s.tags["username"] == "bob"
    [s] = db.select("m", ["v"], {"hostname": "h1"})
    assert s.tags["jobid"] == "j1"
    # ending the NEWER job re-exposes the older job's enrichment
    router.job_end("j2")
    router.write(Point("m", {"hostname": "h0"}, {"v": 2.0}, 2))
    tagged = db.select("m", ["v"], {"hostname": "h0", "jobid": "j1"})
    assert [v for s in tagged for v in s.values["v"]] == [2.0]
    # both ended: writes are untagged again
    router.job_end("j1")
    router.write(Point("m", {"hostname": "h0"}, {"v": 3.0}, 3))
    untagged = [s for s in db.select("m", ["v"], {"hostname": "h0"})
                if "jobid" not in s.tags]
    assert [v for s in untagged for v in s.values["v"]] == [3.0]


def test_end_first_of_overlapping_jobs_keeps_second(router):
    """Ending the OLDER job must not disturb the newer job's enrichment."""
    router.job_start("j1", "alice", ["h0"])
    router.job_start("j2", "bob", ["h0"])
    router.job_end("j1")
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    [s] = router.backend.db("global").select("m", ["v"])
    assert s.tags["jobid"] == "j2" and s.tags["username"] == "bob"


def test_restarted_job_releases_deallocated_hosts(router):
    """Restarting a job id with a smaller host set must drop the old
    allocation everywhere: de-allocated hosts stop receiving the job's
    tags, now and after any future restart (regression: the stale entry
    used to linger in the per-host stack forever)."""
    router.job_start("jr", "alice", ["h0", "h1"])
    router.job_start("jr", "alice", ["h0"])         # requeue, h1 dropped
    assert router.jobs.tags_for_host("h1") == {}
    router.write(Point("m", {"hostname": "h1"}, {"v": 1.0}, 1))
    [s] = router.backend.db("global").select("m", ["v"],
                                             {"hostname": "h1"})
    assert "jobid" not in s.tags
    # h0 still enriched by the restarted allocation
    assert router.jobs.tags_for_host("h0")["jobid"] == "jr"
    router.job_end("jr")
    assert router.jobs.tags_for_host("h0") == {}


def test_signals_stored_as_events(router):
    router.job_start("j1", "alice", ["h0"])
    router.job_end("j1")
    ev = router.backend.db("global").select("job_event")
    vals = sorted(v for s in ev for v in s.values["event"])
    assert vals == ["end", "start"]


def test_per_user_and_per_job_duplication(router):
    router.job_start("j1", "alice", ["h0"])
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    assert router.backend.db("user_alice").point_count() == 1
    assert router.backend.db("job_j1").point_count() == 1


def test_pubsub_and_broken_subscriber(router):
    got = []
    router.subscribe(lambda kind, payload: got.append((kind, payload)))
    router.subscribe(lambda *a: 1 / 0)          # must not break ingest
    router.job_start("j1", "alice", ["h0"])
    router.write(Point("m", {"hostname": "h0"}, {"v": 1.0}, 1))
    kinds = [k for k, _ in got]
    assert kinds == ["job_start", "points"]
    assert got[1][1][0].tags["jobid"] == "j1"


def test_requires_host_tag(router):
    router.write(Point("m", {}, {"v": 1.0}, 1))
    assert router.stats.dropped_no_host == 1
    assert router.backend.db("global").point_count() == 0


def test_write_lines(router):
    res = router.write_lines("m,hostname=h0 v=1.0 1\nm,hostname=h0 v=2.0 2")
    assert res == {"written": 2, "errors": []}
    assert router.backend.db("global").point_count() == 2


def test_http_end_to_end(router):
    with LMSHttpServer(router) as srv:
        sink = HttpSink(srv.url)
        sink.job_start("j9", "bob", ["hx"])
        sink.write([Point("appm", {"hostname": "hx"}, {"v": 3.5}, 7)])
        # query back over HTTP
        with urllib.request.urlopen(
                srv.url + "/query?m=appm&field=v&agg=last") as r:
            out = json.loads(r.read())
        assert out["result"][""] == 3.5
        with urllib.request.urlopen(srv.url + "/ping") as r:
            assert r.status == 204
        sink.job_end("j9")
    s = router.backend.db("global").select("appm")[0]
    assert s.tags["jobid"] == "j9" and s.tags["username"] == "bob"


def test_usermetric_cli(router):
    with LMSHttpServer(router) as srv:
        assert cli_main(["--url", srv.url, "--hostname", "hc",
                         "job-start", "--jobid", "c1", "--user", "carol",
                         "--hosts", "hc"]) == 0
        assert cli_main(["--url", srv.url, "--hostname", "hc",
                         "metric", "pressure", "42.5",
                         "--tag", "phase=warmup"]) == 0
        assert cli_main(["--url", srv.url, "--hostname", "hc",
                         "event", "run_state", "starting miniMD"]) == 0
    s = router.backend.db("global").select("pressure")[0]
    assert s.values["value"] == [42.5]
    assert s.tags["phase"] == "warmup" and s.tags["jobid"] == "c1"
    ev = router.backend.db("global").select("run_state")[0]
    assert ev.values["event"] == ["starting miniMD"]
