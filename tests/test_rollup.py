"""Streaming rollups: tiered aggregates == naive recompute; thread safety.

The core invariant (see ``repro/core/rollup.py`` design notes): for any
point stream — batched, out-of-order, sparse-fielded — a windowed
aggregate served from the rollup tiers equals the same aggregate
recomputed naively from the raw points, for every supported aggregate and
every window size that nests into a tier.  Retention may then drop the
raw points without changing what the rollups answer.
"""

import random
import threading

import pytest

from repro.core.line_protocol import Point, encode_batch
from repro.core.rollup import ROLLUP_AGGS, RollupConfig
from repro.core.router import MetricsRouter
from repro.core.tsdb import Database, TSDBServer

S = 1_000_000_000
WINDOWS = (S, 2 * S, 10 * S, 30 * S, 60 * S, 120 * S)   # all nest into tiers


def _random_stream(rng, n, hosts=3, t_span_s=300):
    """Out-of-order, sparse-fielded random stream."""
    pts = []
    for _ in range(n):
        fields = {}
        if rng.random() < 0.9:
            fields["v"] = rng.uniform(-100, 100)
        if rng.random() < 0.3:
            fields["w"] = float(rng.randint(-5, 5))
        if not fields:
            fields["v"] = 1.0
        pts.append(Point("m", {"hostname": f"h{rng.randrange(hosts)}"},
                         fields, rng.randrange(t_span_s * S)))
    return pts


def _write_in_batches(db, pts, rng):
    i = 0
    while i < len(pts):
        k = rng.randint(1, 64)
        db.write(pts[i:i + k])
        i += k


def _assert_same(rollup_out, raw_out):
    assert set(rollup_out) == set(raw_out)
    for g in raw_out:
        r_starts, r_vals = rollup_out[g]
        n_starts, n_vals = raw_out[g]
        assert r_starts == n_starts, g
        assert r_vals == pytest.approx(n_vals, rel=1e-9, abs=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_rollup_equals_naive_recompute(seed):
    rng = random.Random(seed)
    db = Database("t")
    _write_in_batches(db, _random_stream(rng, 2000), rng)
    for window in WINDOWS:
        for agg in ROLLUP_AGGS:
            for group_by in (None, "hostname"):
                rollup = db.aggregate("m", "v", agg=agg, window_ns=window,
                                      group_by_tag=group_by,
                                      use_rollups=True)
                raw = db.aggregate("m", "v", agg=agg, window_ns=window,
                                   group_by_tag=group_by, use_rollups=False)
                _assert_same(rollup, raw)


def test_rollup_transparent_auto_path():
    """Default ``aggregate`` serves aligned windowed queries from rollups
    and the answer matches a forced raw rescan."""
    rng = random.Random(7)
    db = Database("t")
    _write_in_batches(db, _random_stream(rng, 500), rng)
    auto = db.aggregate("m", "v", agg="sum", window_ns=10 * S)
    raw = db.aggregate("m", "v", agg="sum", window_ns=10 * S,
                       use_rollups=False)
    _assert_same(auto, raw)
    # aligned t_min is exact too
    auto = db.aggregate("m", "v", agg="mean", window_ns=10 * S,
                        t_min=100 * S)
    raw = db.aggregate("m", "v", agg="mean", window_ns=10 * S,
                       t_min=100 * S, use_rollups=False)
    _assert_same(auto, raw)


def test_rollup_out_of_order_and_sparse_fields():
    db = Database("t")
    # strictly decreasing timestamps + a field that appears late
    pts = [Point("m", {"hostname": "h"}, {"v": float(i)}, (99 - i) * S)
           for i in range(100)]
    pts += [Point("m", {"hostname": "h"}, {"late": 1.0}, 5 * S)]
    db.write(pts)
    for agg in ROLLUP_AGGS:
        _assert_same(
            db.aggregate("m", "v", agg=agg, window_ns=10 * S,
                         use_rollups=True),
            db.aggregate("m", "v", agg=agg, window_ns=10 * S,
                         use_rollups=False))
    starts, vals = db.aggregate("m", "late", agg="count",
                                window_ns=10 * S, use_rollups=True)[""]
    assert starts == [0] and vals == [1.0]


def test_rollup_survives_raw_retention():
    """Retention drops raw points; rollups keep answering, unchanged."""
    rng = random.Random(11)
    db = Database("t")
    _write_in_batches(db, _random_stream(rng, 3000, hosts=2), rng)
    before = {agg: db.aggregate("m", "v", agg=agg, window_ns=60 * S,
                                use_rollups=False)
              for agg in ROLLUP_AGGS}
    db.enforce_retention(max_points_per_series=5)
    assert db.stored_points() <= 2 * 5
    for agg, want in before.items():
        _assert_same(db.aggregate("m", "v", agg=agg, window_ns=60 * S,
                                  use_rollups=True), want)
    # the raw path, by contrast, has lost the history
    raw_after = db.aggregate("m", "v", agg="count", window_ns=60 * S,
                             use_rollups=False)
    assert sum(raw_after[""][1]) < sum(before["count"][""][1])


def test_rollup_events_excluded_and_disableable():
    db = Database("t")
    db.write([Point("ev", {"hostname": "h"}, {"event": "start", "ok": True},
                    1 * S)])
    assert db.rollup_aggregate("ev", "event", window_ns=S) == {}
    assert db.rollup_aggregate("ev", "ok", window_ns=S) == {}   # bools too
    raw_only = Database("r", rollup_config=None)
    raw_only.write([Point("m", {"hostname": "h"}, {"v": 1.0}, 1)])
    assert raw_only.aggregate("m", "v", agg="sum", window_ns=S,
                              use_rollups=False)[""][1] == [1.0]
    # rollup entry points on a rollup-disabled db: empty, never a crash
    assert raw_only.rollup_aggregate("m", "v") == {}
    assert raw_only.rollup_series("m", "v") == []
    assert raw_only.rollup_window_count("m", "v") == 0
    # ... and forcing rollup-backed rule evaluation is a loud error
    from repro.core.analysis import default_rules, evaluate_rules_on_db
    with pytest.raises(ValueError):
        evaluate_rules_on_db(raw_only, default_rules(), use_rollups=True)


def test_rollup_nan_no_inf_sentinel():
    """All-NaN windows must not fabricate +/-inf min/max on the batched
    ingest path (it seeds from the first value, like the scalar path)."""
    import math
    db = Database("t")
    nan = float("nan")
    db.write([Point("m", {"hostname": "h"}, {"v": nan}, 1 * S),
              Point("m", {"hostname": "h"}, {"v": nan}, 1 * S + 2)])
    for agg in ("min", "max", "sum", "mean"):
        _, vals = db.rollup_aggregate("m", "v", agg=agg, window_ns=S)[""]
        assert math.isnan(vals[0]), agg
    _, counts = db.rollup_aggregate("m", "v", agg="count", window_ns=S)[""]
    assert counts == [2.0]


def test_rollup_7s_window_served_by_1s_tier():
    """7 s windows don't match a tier exactly but the 1 s tier divides
    them, so the rollup path serves them — and matches raw."""
    db = Database("t")
    db.write([Point("m", {"hostname": "h"}, {"v": float(i)}, i * S)
              for i in range(20)])
    out = db.aggregate("m", "v", agg="sum", window_ns=7 * S)
    raw = db.aggregate("m", "v", agg="sum", window_ns=7 * S,
                       use_rollups=False)
    _assert_same(out, raw)
    assert RollupConfig().tier_for(7 * S) == S      # really the rollup path


def test_rollup_unservable_window():
    """A window finer than the finest tier (0.5 s): 'auto' falls back to
    the raw rescan; forcing the rollup path is a loud error, never a
    silent raw fallback over retention-truncated data."""
    db = Database("t")
    db.write([Point("m", {"hostname": "h"}, {"v": float(i)}, i * S // 4)
              for i in range(20)])
    half = S // 2
    out = db.aggregate("m", "v", agg="sum", window_ns=half)
    raw = db.aggregate("m", "v", agg="sum", window_ns=half,
                       use_rollups=False)
    _assert_same(out, raw)
    with pytest.raises(ValueError):
        db.aggregate("m", "v", agg="sum", window_ns=half, use_rollups=True)


def test_new_field_after_retention():
    """Retention must not break ingest of fields first seen afterwards
    (trim used to downgrade the column defaultdict to a plain dict)."""
    db = Database("t")
    db.write([Point("m", {"hostname": "h"}, {"v": float(i)}, i * S)
              for i in range(10)])
    db.enforce_retention(max_points_per_series=5)
    db.write([Point("m", {"hostname": "h"}, {"v": 1.0, "newf": 2.0},
                    20 * S)])
    s = db.select("m", ["newf"])[0]
    assert s.values["newf"][-1] == 2.0
    # single-point out-of-order insert path too
    db.write([Point("m", {"hostname": "h"}, {"older": 3.0}, 19 * S)])
    col = db.select("m", ["older"])[0].values["older"]
    assert [v for v in col if v is not None] == [3.0]


def test_rollup_config_tier_selection():
    cfg = RollupConfig()
    assert cfg.tier_for(60 * S) == 60 * S        # exact tier
    assert cfg.tier_for(120 * S) == 60 * S       # coarsest that divides
    assert cfg.tier_for(15 * S) == S             # 10 s doesn't divide 15 s
    assert cfg.tier_for(int(0.5 * S)) is None    # finer than finest tier


def test_rollup_own_retention():
    db = Database("t", rollup_config=RollupConfig(max_age_ns=10 * S))
    db.write([Point("m", {"hostname": "h"}, {"v": 1.0}, 1 * S)])
    # rollup windows far older than max_age relative to *wall clock* now
    db.enforce_retention()
    assert db.rollup_aggregate("m", "v", window_ns=S) == {}


# -- concurrency regression ---------------------------------------------------


def test_concurrent_batch_ingest_select_retention():
    """One writer batch-ingesting through the router while readers run
    select/aggregate and retention enforcement: no exceptions, counts
    consistent (tsdb.py's thread-safety promise)."""
    server = TSDBServer()
    router = MetricsRouter(server, per_job_db=True)
    router.job_start("j1", "alice", [f"h{i}" for i in range(4)])
    db = server.db("global")
    errors = []
    stop = threading.Event()
    N_BATCHES, BATCH = 200, 50

    def writer():
        try:
            for b in range(N_BATCHES):
                lines = encode_batch([
                    Point("hpm", {"hostname": f"h{i % 4}"},
                          {"mfu": 0.4, "step": float(b * BATCH + i)},
                          (b * BATCH + i) * 10_000_000)
                    for i in range(BATCH)])
                router.write_lines(lines)
        except Exception as e:          # noqa: BLE001
            errors.append(e)
        finally:
            stop.set()

    def reader():
        try:
            while not stop.is_set():
                db.select("hpm", ["mfu"], {"jobid": "j1"})
                db.aggregate("hpm", "mfu", agg="mean", window_ns=S)
                db.aggregate("hpm", "step", agg="count",
                             group_by_tag="hostname")
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    def reaper():
        try:
            while not stop.is_set():
                db.enforce_retention(max_points_per_series=500)
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader) for _ in range(2)] + \
        [threading.Thread(target=reaper)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert router.stats.points_in == N_BATCHES * BATCH
    assert router.stats.points_out == N_BATCHES * BATCH
    # cumulative count: every metric point + the job_start event
    assert db.point_count() == N_BATCHES * BATCH + 1
    assert db.stored_points() <= N_BATCHES * BATCH + 1
    # rollups saw every point even though retention culled raw storage
    total = db.aggregate("hpm", "mfu", agg="count", window_ns=60 * S,
                         use_rollups=True)
    assert sum(sum(v) for _, v in total.values()) == N_BATCHES * BATCH
