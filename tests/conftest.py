import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dry-run-only, per the assignment).  Make repro importable when pytest is
# invoked without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))

import jax
import numpy as np
import pytest

# partial-manual shard_map (manual pipe/pod axis + auto data/model axes via
# the ``auto``/``axis_names`` kwarg) hits a fatal XLA SPMD-partitioner check
# (hlo_sharding_util: IsManualSubgroup) on JAX versions predating shard_map's
# graduation to jax.shard_map — the subprocess dies with SIGABRT, nothing a
# test can catch or work around in-process.
needs_partial_manual_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map crashes XLA's SPMD partitioner on this "
           "JAX version (IsManualSubgroup check failure)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
