import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dry-run-only, per the assignment).  Make repro importable when pytest is
# invoked without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
