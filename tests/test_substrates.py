"""Optimizers, compression, checkpointing, data pipeline, sharding rules."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # minimal images: property tests skip, rest run
    from _hypothesis_compat import given, settings, st

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import available_steps
from repro.configs import TrainConfig
from repro.data import DataLoader, SyntheticTokenSource, make_batch_fn
from repro.configs.base import ShapeConfig
from repro.models.params import spec
from repro.parallel.sharding import (SERVE_RULES, TRAIN_RULES,
                                     logical_to_pspec, shardings_for_specs)
from repro.train.compression import (dequantize_int8, quantize_int8,
                                     quantization_error)
from repro.train.optim import (adafactor, adamw, clip_by_global_norm,
                               global_norm, lr_schedule, opt_state_specs)

# -- optimizers ---------------------------------------------------------------


def _quadratic_steps(opt, steps=120):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params, 0.05)
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_adamw_converges():
    cfg = TrainConfig(weight_decay=0.0)
    assert _quadratic_steps(adamw(cfg)) < 0.1


def test_adafactor_converges():
    cfg = TrainConfig(weight_decay=0.0)
    assert _quadratic_steps(adafactor(cfg), steps=300) < 0.15


def test_adafactor_factored_state_small():
    cfg = TrainConfig(optimizer="adafactor")
    opt = adafactor(cfg)
    params = {"w": jnp.zeros((64, 128))}
    state = opt.init(params)
    s = state["s"]["w"]
    assert s["vr"].shape == (64,) and s["vc"].shape == (128,)
    assert s["m"].dtype == jnp.bfloat16     # bf16 momentum


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr = lr_schedule(cfg)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(55)) < float(lr(12))


def test_opt_state_specs_match_init():
    """Spec-level opt state must structurally match the runtime opt state."""
    for name in ("adamw", "adafactor"):
        cfg = TrainConfig(optimizer=name)
        pspecs = {"w": spec((8, 16), ("embed", "mlp")),
                  "b": spec((16,), ("mlp",))}
        from repro.models.params import abstract_params, init_params
        params = init_params(pspecs)
        from repro.train.optim import get_optimizer
        state = get_optimizer(cfg).init(params)
        sspecs = abstract_params(opt_state_specs(pspecs, cfg))
        got = jax.tree.map(lambda x: (x.shape, str(x.dtype)), state)
        want = jax.tree.map(lambda x: (x.shape, str(x.dtype)), sspecs)
        assert got == want, name


# -- gradient compression ------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e4))
def test_int8_quantization_error_bound(seed, scale):
    """|dequant(quant(x)) - x| <= scale_row / 2 elementwise (round-to-nearest
    symmetric int8)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, 64)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    bound = np.asarray(s) / 2 + 1e-7 * scale
    assert (err <= bound + 1e-12).all()
    assert q.dtype == jnp.int8


def test_quantization_error_helper():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((32, 32)),
                    jnp.float32)
    e = quantization_error(x)
    assert float(jnp.max(jnp.abs(e))) < float(jnp.max(jnp.abs(x))) / 100


# -- checkpointing --------------------------------------------------------------


def _trees(v=1.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros(4)},
            "opt_state": {"m": jnp.full((4, 4), v / 2),
                          "count": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 10, _trees(2.0), {"arch": "t"})
    step, out = load_checkpoint(d, _trees())
    assert step == 10
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.full((4, 4), 2.0))
    assert int(out["opt_state"]["count"]) == 3


def test_checkpoint_atomicity(tmp_path):
    """A partial .tmp dir must never be visible as a checkpoint."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, _trees())
    os.makedirs(os.path.join(d, ".tmp-2"))          # simulated crash mid-save
    with open(os.path.join(d, ".tmp-2", "params.npz"), "w") as f:
        f.write("garbage")
    assert available_steps(d) == [1]
    step, _ = load_checkpoint(d, _trees())
    assert step == 1


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _trees(float(s)))
    assert available_steps(str(tmp_path / "ck")) == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3, async_write=True)
    mgr.save(5, _trees(5.0))
    mgr.wait()
    step, out = mgr.restore(_trees())
    assert step == 5 and float(out["params"]["w"][0, 0]) == 5.0


def test_checkpoint_elastic_reshard(tmp_path):
    """Load with explicit (single-device) shardings — the elastic path."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 7, _trees(3.0))
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), _trees()["params"])
    step, out = load_checkpoint(d, {"params": _trees()["params"]},
                                shardings={"params": sh})
    assert out["params"]["w"].sharding == NamedSharding(mesh, P())


# -- data pipeline ----------------------------------------------------------------


def test_synthetic_determinism():
    s1 = SyntheticTokenSource(1000, seed=3)
    s2 = SyntheticTokenSource(1000, seed=3)
    np.testing.assert_array_equal(s1.batch(5, 4, 16), s2.batch(5, 4, 16))
    assert not np.array_equal(s1.batch(5, 4, 16), s1.batch(6, 4, 16))
    assert s1.batch(0, 4, 16).max() < 1000


def test_host_sharded_loader():
    src = SyntheticTokenSource(100, seed=0)
    shape = ShapeConfig("t", seq_len=8, global_batch=8, kind="train")
    fn = make_batch_fn(src, None, shape)
    full = fn(0, slice(0, 8))
    loaders = [DataLoader(fn, host_index=i, host_count=2, global_batch=8)
               for i in range(2)]
    try:
        got = {}
        for i, ld in enumerate(loaders):
            step, b = next(ld)
            assert step == 0
            assert b["tokens"].shape == (4, 8)
            got[i] = b["tokens"]
        np.testing.assert_array_equal(
            np.concatenate([got[0], got[1]]), full["tokens"])
    finally:
        for ld in loaders:
            ld.close()


def test_loader_replay_from_step():
    src = SyntheticTokenSource(100, seed=0)
    shape = ShapeConfig("t", seq_len=8, global_batch=4, kind="train")
    fn = make_batch_fn(src, None, shape)
    ld = DataLoader(fn, global_batch=4, start_step=17)
    try:
        step, b = next(ld)
        assert step == 17
        np.testing.assert_array_equal(b["tokens"], fn(17, slice(0, 4))["tokens"])
    finally:
        ld.close()


# -- sharding rules ------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402


@pytest.fixture(scope="module")
def mesh2x2():
    dev = np.array(jax.devices() * 4).reshape(2, 2)
    from jax.sharding import Mesh
    return Mesh(dev, ("data", "model"))


def test_pspec_basic(mesh2x2):
    ps = logical_to_pspec(("embed", "mlp"), (8, 16), TRAIN_RULES, mesh2x2)
    assert ps == P("data", "model")


def test_pspec_divisibility_fallback(mesh2x2):
    # 7 % 2 != 0 -> replicate that dim, keep the other
    ps = logical_to_pspec(("embed", "kv_heads"), (8, 7), TRAIN_RULES,
                          mesh2x2)
    assert ps == P("data")
    ps = logical_to_pspec(("embed", "heads"), (7, 8), TRAIN_RULES, mesh2x2)
    assert ps == P(None, "model")


def test_pspec_axis_used_once(mesh2x2):
    # both "heads" and "mlp" want "model"; only the first (priority order)
    ps = logical_to_pspec(("heads", "mlp"), (8, 8), TRAIN_RULES, mesh2x2)
    assert ps == P("model")


def test_pspec_cache_priority(mesh2x2):
    # kv_heads divisible -> it wins the model axis, cache_seq replicated
    ps = logical_to_pspec(("batch", "cache_seq", "kv_heads", None),
                          (8, 64, 4, 16), SERVE_RULES, mesh2x2)
    assert ps == P("data", None, "model")
    # kv_heads NOT divisible -> cache_seq takes the model axis
    ps = logical_to_pspec(("batch", "cache_seq", "kv_heads", None),
                          (8, 64, 3, 16), SERVE_RULES, mesh2x2)
    assert ps == P("data", "model")


def test_pspec_multi_axis_batch():
    from jax.sharding import Mesh
    dev = np.array(jax.devices() * 8).reshape(2, 2, 2)
    mesh = Mesh(dev, ("pod", "data", "model"))
    ps = logical_to_pspec(("batch", "seq"), (8, 32), TRAIN_RULES, mesh)
    assert ps == P(("pod", "data"))


def test_shardings_for_specs_tree(mesh2x2):
    tree = {"w": spec((8, 16), ("embed", "mlp")),
            "scale": spec((16,), ("norm",))}
    sh = shardings_for_specs(tree, TRAIN_RULES, mesh2x2)
    assert sh["w"].spec == P("data", "model")
    assert sh["scale"].spec == P()
