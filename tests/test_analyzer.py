"""Tests for the ``repro.analyzer`` static passes (fixture-based
known-good / known-bad snippets per pass), the ``lms_lint`` CLI, and
concurrency regressions for the real lock-discipline violations the
analyzer found and this PR fixed (jobs.on_end, DashboardAgent._engine,
HostAgent._emit).

The fixtures are written to tmp_path and analyzed in-process; the
``durability`` fixtures are named ``wal.py`` because that pass only
applies to the persistence modules (wal/coldstore/tsdb).
"""

import json
import os
import subprocess
import sys
import textwrap
import threading

from repro.analyzer import analyze_paths

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINT = os.path.join(REPO_ROOT, "scripts", "lms_lint.py")


def _analyze(tmp_path, source, name="fixture.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return analyze_paths([str(p)])


def _rules(report, rule):
    return [f for f in report.findings if f.rule == rule]


# --------------------------------------------------------------------------
# lock-discipline
# --------------------------------------------------------------------------


LOCK_BAD = """
    import threading

    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []
            self._total = 0

        def add(self, x):
            with self._lock:
                self._items.append(x)
                self._total += 1

        def sneak(self, x):
            self._items.append(x)
"""


def test_lock_discipline_flags_unlocked_mutation(tmp_path):
    report = _analyze(tmp_path, LOCK_BAD)
    findings = _rules(report, "unlocked")
    assert len(findings) == 1
    assert "sneak" in findings[0].message
    assert "_items" in findings[0].message
    assert not findings[0].suppressed


def test_lock_discipline_clean_and_held_method(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = []

            def add(self, x):
                with self._lock:
                    self._push(x)

            def _push(self, x):
                # private helper only ever called under the lock: the
                # held-method fixpoint must exempt it
                self._items.append(x)
    """)
    assert not _rules(report, "unlocked")


def test_construction_methods_exempt(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._vals = []

            def read(self):
                with self._lock:
                    return list(self._vals)
    """)
    assert not _rules(report, "unlocked")


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------


def test_suppression_with_reason_silences(tmp_path):
    # patch the *second* occurrence (in sneak) — that one carries the
    # finding
    src = LOCK_BAD[:LOCK_BAD.rindex("self._items.append(x)")] + (
        "self._items.append(x)"
        "  # lms: unlocked(fixture: intentionally racy)\n")
    report = _analyze(tmp_path, src)
    findings = _rules(report, "unlocked")
    assert len(findings) == 1
    assert findings[0].suppressed
    assert findings[0].reason == "fixture: intentionally racy"
    assert not report.unsuppressed()


def test_reasonless_suppression_is_a_finding(tmp_path):
    src = LOCK_BAD[:LOCK_BAD.rindex("self._items.append(x)")] + (
        "self._items.append(x)  # lms: unlocked()\n")
    report = _analyze(tmp_path, src)
    sup = _rules(report, "suppression")
    assert len(sup) == 1
    assert not sup[0].suppressed          # never itself suppressible
    # and the original finding stays unsuppressed too
    assert any(not f.suppressed for f in _rules(report, "unlocked"))


# --------------------------------------------------------------------------
# lock-order
# --------------------------------------------------------------------------


ORDER_CYCLE = """
    import threading

    class Left:
        def __init__(self):
            self.lock = threading.Lock()

    class Right:
        def __init__(self):
            self.lock = threading.Lock()

    class App:
        def __init__(self):
            self.left = Left()
            self.right = Right()

        def forward(self):
            with self.left.lock:
                with self.right.lock:
                    pass

        def backward(self):
            with self.right.lock:
                with self.left.lock:
                    pass
"""


def test_lock_order_detects_seeded_cycle(tmp_path):
    report = _analyze(tmp_path, ORDER_CYCLE)
    findings = _rules(report, "lock-order")
    assert len(findings) == 1
    msg = findings[0].message
    assert "cycle" in msg
    assert "Left.lock" in msg and "Right.lock" in msg
    # both orders present as edges
    assert ("Left.lock", "Right.lock") in report.lock_edges
    assert ("Right.lock", "Left.lock") in report.lock_edges


def test_lock_order_consistent_order_is_clean(tmp_path):
    report = _analyze(tmp_path, ORDER_CYCLE.replace(
        "with self.right.lock:\n                with self.left.lock:",
        "with self.left.lock:\n                with self.right.lock:"))
    assert not _rules(report, "lock-order")
    assert ("Left.lock", "Right.lock") in report.lock_edges
    assert ("Right.lock", "Left.lock") not in report.lock_edges


def test_lock_order_cycle_via_cross_class_call(tmp_path):
    # the indirect shape: A holds its lock and calls into B, which
    # acquires its own lock and calls back into A
    report = _analyze(tmp_path, """
        import threading

        class Peer:
            def __init__(self, other):
                self._lock = threading.Lock()
                self.other = other

        class Alpha:
            def __init__(self, beta: "Beta"):
                self._lock = threading.Lock()
                self.beta = beta

            def poke(self):
                with self._lock:
                    self.beta.nudge()

            def touch(self):
                with self._lock:
                    pass

        class Beta:
            def __init__(self, alpha: "Alpha"):
                self._lock = threading.Lock()
                self.alpha = alpha

            def nudge(self):
                with self._lock:
                    pass

            def kick(self):
                with self._lock:
                    self.alpha.touch()
    """)
    findings = _rules(report, "lock-order")
    assert len(findings) == 1
    assert "Alpha._lock" in findings[0].message
    assert "Beta._lock" in findings[0].message


def test_lock_order_suppression_on_edge_site(tmp_path):
    src = ORDER_CYCLE.replace(
        "with self.right.lock:\n                with self.left.lock:",
        "with self.right.lock:\n                "
        "# lms: lock-order(fixture: benign by construction)\n"
        "                with self.left.lock:")
    report = _analyze(tmp_path, src)
    findings = _rules(report, "lock-order")
    assert len(findings) == 1
    assert findings[0].suppressed
    assert not report.unsuppressed()


# --------------------------------------------------------------------------
# durability (fixtures must be named wal.py — the pass is module-scoped)
# --------------------------------------------------------------------------


def test_durability_flags_unsynced_rename(tmp_path):
    report = _analyze(tmp_path, """
        import os

        def publish(path):
            with open(path + ".tmp", "w") as f:
                f.write("x")
            os.replace(path + ".tmp", path)
    """, name="wal.py")
    findings = _rules(report, "durability")
    msgs = " | ".join(f.message for f in findings)
    assert "directory fsync" in msgs
    assert "os.fsync of the source" in msgs
    assert len(findings) == 2


def test_durability_clean_publish(tmp_path):
    report = _analyze(tmp_path, """
        import os

        def _fsync_dir(d):
            fd = os.open(d, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)

        def publish(path):
            with open(path + ".tmp", "w") as f:
                f.write("x")
                f.flush()
                os.fsync(f.fileno())
            os.replace(path + ".tmp", path)
            _fsync_dir(os.path.dirname(path))
    """, name="wal.py")
    assert not [f for f in _rules(report, "durability")
                if "publish" in f.message]


def test_durability_ignores_other_modules(tmp_path):
    report = _analyze(tmp_path, """
        import os

        def publish(path):
            os.replace(path + ".tmp", path)
    """, name="helpers.py")
    assert not _rules(report, "durability")


def test_wal_write_discipline(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        class MiniWal:
            def __init__(self):
                self.lock = threading.Lock()
                self._fh = open("/dev/null", "ab")

            def append_bad(self, rec):
                self._fh.write(rec)

            def append_good(self, rec):
                with self.lock:
                    self._fh.write(rec)
    """, name="wal.py")
    findings = _rules(report, "durability")
    assert len(findings) == 1
    assert "append_bad" in findings[0].message
    assert "group-commit" in findings[0].message


# --------------------------------------------------------------------------
# thread-lifecycle
# --------------------------------------------------------------------------


def test_thread_lifecycle_flags_unjoined(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        class Leaky:
            def start(self):
                t = threading.Thread(target=self._run)
                t.start()

            def _run(self):
                pass
    """)
    findings = _rules(report, "thread")
    assert len(findings) == 1
    assert "'t'" in findings[0].message


def test_thread_lifecycle_daemon_and_joined_clean(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        class Owner:
            def __init__(self):
                self._thread = threading.Thread(target=self._run,
                                                daemon=False)
                self._thread.start()

            def _run(self):
                pass

            def close(self):
                self._stop()

            def _stop(self):
                # join reached through close() -> _stop(): the teardown
                # reachability must follow in-class calls
                self._thread.join(timeout=2.0)

        class Daemonic:
            def kick(self):
                t = threading.Thread(target=print, daemon=True)
                t.start()
    """)
    assert not _rules(report, "thread")


def test_thread_lifecycle_fire_and_forget(tmp_path):
    report = _analyze(tmp_path, """
        import threading

        def kick():
            threading.Thread(target=print).start()
    """)
    findings = _rules(report, "thread")
    assert len(findings) == 1
    assert "fire-and-forget" in findings[0].message


# --------------------------------------------------------------------------
# http-surface
# --------------------------------------------------------------------------


def test_http_surface_flags_unbounded_read_and_unguarded_db(tmp_path):
    report = _analyze(tmp_path, """
        class Handler:
            def do_GET(self):
                name = self.query.get("db", "global")
                db = self.server.backend.db(name)
                self._send(200, db.stats())

            def do_POST(self):
                body = self.rfile.read(
                    int(self.headers.get("Content-Length", 0)))
                self._send(200, {})
    """)
    findings = _rules(report, "http")
    msgs = " | ".join(f.message for f in findings)
    assert "rfile.read" in msgs
    assert "_known_db" in msgs
    assert len(findings) == 2


def test_http_surface_guarded_and_bounded_clean(tmp_path):
    report = _analyze(tmp_path, """
        class Handler:
            def do_GET(self):
                name = self.query.get("db", "global")
                if not self._known_db(name):
                    self._send(404, {"error": "unknown db"})
                    return
                db = self.server.backend.db(name)
                self._send(200, db.stats())

            def do_POST(self):
                body = self._body()
                self._send(200, {})

            def _body(self):
                return self.rfile.read(100)
    """)
    assert not _rules(report, "http")


def test_http_surface_guard_does_not_leak_across_branches(tmp_path):
    # a _known_db in one elif branch must not launder an unguarded
    # .db() in a *preceding* branch of the same chain
    report = _analyze(tmp_path, """
        class Handler:
            def do_GET(self):
                if self.path == "/a":
                    db = self.server.backend.db(self.q["db"])
                elif self.path == "/b":
                    if not self._known_db(self.q["db"]):
                        self._send(404, {})
                        return
                    db = self.server.backend.db(self.q["db"])
    """)
    findings = _rules(report, "http")
    assert len(findings) == 1


def test_non_handler_classes_ignored(tmp_path):
    report = _analyze(tmp_path, """
        class Plain:
            def fetch(self, name):
                return self.backend.db(name)
    """)
    assert not _rules(report, "http")


# --------------------------------------------------------------------------
# the real tree + the CLI
# --------------------------------------------------------------------------


def test_core_tree_is_clean():
    report = analyze_paths([os.path.join(REPO_ROOT, "src", "repro",
                                         "core")])
    assert report.unsuppressed() == []
    # the static lock graph exists and is what the race tier joins on
    assert report.lock_nodes
    assert report.lock_edges
    assert report.lock_sites


def test_cli_json_output_and_exit_codes(tmp_path):
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "fixture.py").write_text(textwrap.dedent(LOCK_BAD))
    proc = subprocess.run(
        [sys.executable, LINT, "--json", str(bad)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["version"] == 1
    assert doc["counts"]["unsuppressed"] == 1
    assert doc["findings"][0]["rule"] == "unlocked"

    good = tmp_path / "good"
    good.mkdir()
    (good / "fixture.py").write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, LINT, "--json", str(good)],
        capture_output=True, text=True)
    assert proc.returncode == 0
    assert json.loads(proc.stdout)["counts"]["total"] == 0


# --------------------------------------------------------------------------
# concurrency regressions for the violations the analyzer caught
# --------------------------------------------------------------------------


def test_jobs_on_end_registration_races_with_end():
    # pre-fix: JobRegistry.on_end appended to _end_hooks without the
    # lock while end() iterated a copy — racing registrations could be
    # lost or corrupt the list
    from repro.core.jobs import JobRegistry

    reg = JobRegistry()
    errors = []
    N = 200

    def register():
        try:
            for i in range(N):
                reg.on_end(lambda job: None)
        except Exception as e:          # pragma: no cover
            errors.append(e)

    def churn():
        try:
            for i in range(N):
                reg.start(f"j{i}", "u", ["h0"])
                reg.end(f"j{i}")
        except Exception as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=register) for _ in range(2)]
    threads += [threading.Thread(target=churn) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors
    assert len(reg._end_hooks) == 2 * N
    # hooks registered before this end must all fire
    fired = []
    reg.on_end(lambda job: fired.append(job.job_id))
    reg.start("last", "u", ["h0"])
    reg.end("last")
    assert fired == ["last"]


def test_dashboard_engine_lru_concurrent(tmp_path):
    # pre-fix: the fallback-engine OrderedDict was mutated from
    # concurrent dashboard renders without a lock (get/move_to_end/
    # popitem interleavings corrupt the dict)
    from repro.core.dashboard import DashboardAgent

    class _Db:
        pass

    agent = DashboardAgent(backend=object(), out_dir=str(tmp_path))
    errors = []

    def render(seed):
        try:
            dbs = [_Db() for _ in range(12)]
            for r in range(50):
                db = dbs[(seed + r) % len(dbs)]
                eng = agent._engine(db)
                assert eng.backend is db
        except Exception as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=render, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors
    assert len(agent._engines) <= agent.MAX_FALLBACK_ENGINES


def test_host_agent_concurrent_emit_accounting():
    # pre-fix: _pending / _failed_flushes / _dropped_points were
    # unguarded across collection ticks and explicit flush() callers
    from repro.core.host_agent import HostAgent

    class FlakyRouter:
        def __init__(self):
            self.lock = threading.Lock()
            self.received = 0
            self.calls = 0

        def write(self, points):
            with self.lock:
                self.calls += 1
                if self.calls % 5 == 0:
                    raise RuntimeError("transient sink failure")
                self.received += len(points)

    router = FlakyRouter()
    agent = HostAgent(router, hostname="h0", batch_size=4)
    errors = []
    PER_THREAD = 60

    def tick(base):
        try:
            for step in range(PER_THREAD):
                agent.collect_step(step=step, step_time_s=0.001,
                                   ts=base * PER_THREAD + step)
                if step % 7 == 0:
                    try:
                        agent.flush()
                    except RuntimeError:
                        pass            # transient failure: re-buffered
        except Exception as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=tick, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors
    # drain the re-buffered tail
    for _ in range(100):
        try:
            agent.flush()
            break
        except RuntimeError:
            pass
    stats = agent.emit_stats
    emitted = 4 * PER_THREAD
    assert stats["dropped_points"] == 0
    assert router.received + stats["pending"] == emitted
    assert stats["pending"] == 0
