"""Per-arch smoke tests (assignment requirement): every assigned
architecture instantiates a REDUCED config of the same family and runs one
forward/train step on CPU, asserting output shapes and no NaNs; plus
prefill->decode continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, SMOKE_SHAPE, get_config
from repro.models.transformer import (forward, init_cache, init_model_params,
                                      loss_fn, model_specs)
from repro.models.params import param_count

B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len


def smoke_batch(cfg, b=B, s=S, seed=0):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        p = cfg.vlm_num_patches
        batch["patches"] = 0.01 * jax.random.normal(
            k1, (b, p, cfg.d_model), jnp.float32)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s)[None, :, None], (b, s, 3)).astype(jnp.int32)
    if cfg.family == "encdec":
        batch["src_frames"] = 0.01 * jax.random.normal(
            k1, (b, cfg.encdec_source_len, cfg.d_model), jnp.float32)
    return batch


def _extras(batch):
    return {k: v for k, v in batch.items() if k not in ("tokens", "labels")}


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name, smoke=True)
            cache[name] = (cfg, init_model_params(cfg, seed=0))
        return cache[name]
    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["lms-demo"])
def test_forward_shapes_no_nans(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = smoke_batch(cfg)
    logits, _, aux = forward(params, cfg, tokens=batch["tokens"],
                             mode="train", extras=_extras(batch))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    if cfg.moe is not None:
        assert float(aux["moe_aux_loss"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_decreases_loss(arch, arch_state):
    """One SGD step on a repeated batch must reduce the loss."""
    cfg, params = arch_state(arch)
    batch = smoke_batch(cfg)

    def loss_of(p):
        return loss_fn(p, cfg, batch)[0]

    l0, grads = jax.value_and_grad(loss_of)(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert float(gnorm) > 0, "gradients must flow"
    lr = 0.5 / max(float(gnorm), 1.0)
    p1 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = loss_of(p1)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_continuity(arch, arch_state):
    """Greedy logits from decode(t) after prefill(0..t-1) must match the
    teacher-forced forward at position t (same-cache consistency)."""
    cfg, params = arch_state(arch)
    batch = smoke_batch(cfg)
    toks = batch["tokens"]
    extras = _extras(batch)

    # full teacher-forced forward (train mode = no cache)
    full_logits, _, _ = forward(params, cfg, tokens=toks, mode="train",
                                extras=extras)

    # prefill on the first S-1 tokens, then decode token S-1
    cache = init_cache(cfg, B, S + 4)
    pre_extras = dict(extras)
    if "mrope_pos" in pre_extras:
        pre_extras["mrope_pos"] = pre_extras["mrope_pos"][:, :S - 1]
    if cfg.family == "vlm":
        # patches must fit in the shortened prefix
        pre_extras["patches"] = pre_extras["patches"][:, :S - 8]
    _, cache, _ = forward(params, cfg, tokens=toks[:, :S - 1],
                          mode="prefill", cache=cache, extras=pre_extras)
    dec_extras = {}
    if "mrope_pos" in extras:
        dec_extras["mrope_pos"] = jnp.full((B, 1, 3), S - 1, jnp.int32)
    dec_logits, _, _ = forward(params, cfg, tokens=toks[:, S - 1:S],
                               mode="decode", cache=cache,
                               pos=jnp.int32(S - 1), extras=dec_extras)

    if cfg.family == "vlm":
        return  # patch prefix differs between the two paths; shapes-only
    got = dec_logits[:, 0].astype(jnp.float32)
    want = full_logits[:, S - 1].astype(jnp.float32)
    # tolerance: caches are bf16 (the production layout), so the decode path
    # rounds K/V/state through bf16 while teacher-forcing does not; exact
    # fp32 path equivalence is covered in test_attention / test_ssm
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.08, atol=0.25)


def test_param_counts_roughly_match_published():
    """Full configs should land near the published parameter counts."""
    approx = {
        "granite-3-8b": 8.2e9,
        "yi-34b": 34.4e9,
        "phi3-medium-14b": 14e9,
        "mixtral-8x7b": 46.7e9,
        "nemotron-4-340b": 340e9,
        "deepseek-v2-236b": 236e9,
        "rwkv6-1.6b": 1.6e9,
        "qwen2-vl-7b": 7.6e9,
        "zamba2-7b": 7.3e9,
    }
    for arch, want in approx.items():
        cfg = get_config(arch)
        n = cfg.param_count()
        assert 0.75 * want < n < 1.35 * want, (arch, n, want)


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.4 * total            # 2-of-8 experts + shared
    assert 10e9 < active < 16e9            # ~12.9B published
