"""Launcher path: bundles lower+compile on a 1x1 mesh (smoke configs), the
dry-run artifact schema, and the mesh/config helpers."""

import glob
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, ShapeConfig, TrainConfig, get_config
from repro.launch.hlo_analysis import cost_analysis_dict
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import (build_bundle, build_decode_bundle,
                                build_prefill_bundle, build_train_bundle,
                                input_specs, lower_bundle)

TINY = ShapeConfig("tiny", seq_len=32, global_batch=2, kind="train")
TINY_PREFILL = ShapeConfig("tinyp", seq_len=32, global_batch=2,
                           kind="prefill")
TINY_DECODE = ShapeConfig("tinyd", seq_len=32, global_batch=2, kind="decode")


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["granite-3-8b", "mixtral-8x7b",
                                  "rwkv6-1.6b", "zamba2-7b",
                                  "deepseek-v2-236b",
                                  "seamless-m4t-large-v2", "qwen2-vl-7b"])
def test_bundles_lower_and_compile(arch, mesh1):
    """Every bundle kind lowers AND compiles for a reduced config."""
    cfg = get_config(arch, smoke=True)
    for shape in (TINY, TINY_PREFILL, TINY_DECODE):
        bundle = build_bundle(cfg, shape, mesh1,
                              train_cfg=TrainConfig(num_microbatches=2))
        compiled = lower_bundle(bundle, mesh1).compile()
        assert cost_analysis_dict(compiled).get("flops", 0) > 0
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes >= 0


def test_input_specs_cover_modalities():
    cfg = get_config("qwen2-vl-7b")
    sp = input_specs(cfg, SHAPES["prefill_32k"])
    assert {"tokens", "patches", "mrope_pos"} <= set(sp)
    sp = input_specs(cfg, SHAPES["decode_32k"])
    assert "patches" not in sp and "mrope_pos" in sp
    cfg = get_config("seamless-m4t-large-v2")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert "src_frames" in sp
    assert sp["tokens"].shape == (256, 4096)


def test_make_mesh_for_elastic():
    m = make_mesh_for(1)
    assert m.devices.size == 1
    assert m.axis_names == ("data", "model")


def test_dryrun_artifacts_schema():
    """If the dry-run matrix has been generated, validate every record."""
    paths = glob.glob("results/dryrun/*/*.json")
    if not paths:
        pytest.skip("dry-run artifacts not generated")
    meshes = set()
    ok = skipped = 0
    for p in paths:
        r = json.load(open(p))
        meshes.add(r["mesh"])
        assert r["status"] in ("ok", "skipped"), (p, r.get("error"))
        if r["status"] == "skipped":
            skipped += 1
            assert "reason" in r
            continue
        ok += 1
        roof = r["roofline"]
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "model_flops", "hlo_flops", "useful_flop_ratio",
                  "classification"):
            assert k in roof, (p, k)
        assert roof["dominant"] in ("compute", "memory", "collective")
        assert roof["classification"]["pattern"]
        assert r["hlo_analysis"]["global"]["flops"] > 0
        assert r["memory_per_device"]["temp_bytes"] >= 0
    # full matrix = 2 meshes x (33 ok + 7 skipped)
    if len(paths) == 80:
        assert meshes == {"pod16x16", "pod2x16x16"}
        assert ok == 66 and skipped == 14
