"""Crash-safe durability: segmented WAL + snapshot/compaction.

The contract of ``repro.core.wal``: a ``TSDBServer``/``MonitoringStack``
restarted after any shutdown — clean, torn mid-record, or a SIGKILL mid
write loop — answers every ``select`` / ``aggregate`` / ``rollup_*``
query identically to an instance that never died, for any shard count
(including a *different* shard count than the one that wrote the log),
and never aborts recovery on a half-written tail.

Tiers: fast unit tests; ``-m stress`` recovery-equivalence property
(random streams x random crash offsets, shards 1 and 4); ``-m crash``
real subprocess SIGKILL injection (the ci_check.sh crash step, bounded
by ``LMS_CRASH_ITERS``).
"""

import json
import os
import random
import signal
import struct
import subprocess
import sys
import threading
import time
import zlib

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import MonitoringStack
from repro.core.host_agent import _read_net_dev
from repro.core.line_protocol import Point
from repro.core.rollup import ROLLUP_AGGS
from repro.core.router import MetricsRouter
from repro.core.tsdb import Database, TSDBServer, _tags_key
from repro.core.usermetric import UserMetric
from repro.core.wal import (SEGMENT_MAGIC, SegmentedWal, decode_batch_payload,
                            encode_batch_payload, read_segment)

S = 1_000_000_000


def _pts(n=10, host="h0", meas="m", t0=0, dt=S, field="v"):
    return [Point(meas, {"hostname": host}, {field: float(i)}, t0 + i * dt)
            for i in range(n)]


def _random_stream(rng, n, hosts=4, t_span_s=120):
    pts = []
    for _ in range(n):
        fields = {}
        if rng.random() < 0.9:
            fields["v"] = rng.uniform(-100, 100)
        if rng.random() < 0.25:
            fields["w"] = float(rng.randint(-5, 5))
        if rng.random() < 0.1:
            fields["note"] = "evt"
        if rng.random() < 0.1:
            fields["flag"] = True
        if not fields:
            fields["v"] = 1.0
        pts.append(Point("m", {"hostname": f"h{rng.randrange(hosts)}"},
                         fields, rng.randrange(t_span_s * S)))
    return pts


def _series_map(series_list):
    out = {}
    for s in series_list:
        key = _tags_key(s.tags)
        assert key not in out
        out[key] = (s.times, s.values)
    return out


def _windows_equal(got, ref, exact):
    assert set(got) == set(ref)
    for g in ref:
        gs, gv = got[g]
        rs, rv = ref[g]
        assert gs == rs
        if exact:
            assert gv == rv
        else:
            assert gv == pytest.approx(rv, rel=1e-9, abs=1e-12)


def _assert_equivalent(got, ref, meas="m", field="v", exact=True):
    """Recovered database answers like the reference.

    ``exact=False`` only for recovery into a *different* shard count:
    series data, counts and raw-path aggregates stay bitwise identical,
    but cross-series WindowAgg merges associate float sums in series
    insertion order, which re-hashing permutes (the same last-ulp
    tolerance test_shard.py applies between shard counts)."""
    assert got.point_count() == ref.point_count()
    assert got.measurements() == ref.measurements()
    for m in ref.measurements():
        assert got.field_keys(m) == ref.field_keys(m)
        assert _series_map(got.select(m)) == _series_map(ref.select(m))
    for agg in ROLLUP_AGGS:
        # scalar raw path sorts (t, v) pairs globally: exact always
        assert got.aggregate(meas, field, agg=agg,
                             group_by_tag="hostname") == \
            ref.aggregate(meas, field, agg=agg, group_by_tag="hostname")
        _windows_equal(
            got.aggregate(meas, field, agg=agg, window_ns=10 * S),
            ref.aggregate(meas, field, agg=agg, window_ns=10 * S), exact)
        _windows_equal(
            got.rollup_aggregate(meas, field, agg=agg, window_ns=S),
            ref.rollup_aggregate(meas, field, agg=agg, window_ns=S),
            exact)


def _wal_segments(root):
    out = []
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if fn.startswith("wal-") and fn.endswith(".log"):
                out.append(os.path.join(dirpath, fn))
    return sorted(out)


# -- record codec -------------------------------------------------------------


def test_record_codec_roundtrip_types():
    entries = [
        ("m", {"hostname": "h0"}, [1, 2, 3],
         {"f": [0.5, 1.5, 2.5], "i": [1, 2, 3]}),
        ("ev", {"hostname": "h1"}, [10**15],
         {"event": ["start"], "flag": [True], "hole": [None]}),
        ("x", {"a": "b"}, [5, 7],
         {"mix": [1, 2.0], "big": [2**70, -2**70]}),
    ]
    out = decode_batch_payload(encode_batch_payload(entries))
    assert out == [list(e) for e in entries]
    # exact types survive (ints stay ints, bools stay bools)
    assert all(type(v) is int for v in out[0][3]["i"])
    assert type(out[1][3]["flag"][0]) is bool


def test_record_codec_nan_inf():
    import math
    entries = [("m", {}, [1, 2], {"v": [float("nan"), float("inf")]})]
    out = decode_batch_payload(encode_batch_payload(entries))
    assert math.isnan(out[0][3]["v"][0])
    assert math.isinf(out[0][3]["v"][1])


# -- segmented log ------------------------------------------------------------


def test_segmented_wal_append_rotate_replay(tmp_path):
    wal = SegmentedWal(str(tmp_path / "w"), fsync="batch",
                       segment_max_bytes=100)
    for i in range(10):
        wal.append(b"payload-%03d" % i, max_ts=i)
    wal.close()
    assert wal.segment_count() > 1          # rotation happened
    got = []
    stats = wal.replay(lambda p: got.append(p) or None)
    assert got == [b"payload-%03d" % i for i in range(10)]
    assert stats["torn_tails"] == 0
    # replay window: min_seq skips sealed prefixes
    head = wal.rotate()
    wal.append(b"tail", max_ts=99)
    wal.close()
    got = []
    wal.replay(lambda p: got.append(p) or None, min_seq=head)
    assert got == [b"tail"]


def test_torn_tail_truncated_never_fatal(tmp_path):
    wal = SegmentedWal(str(tmp_path / "w"), fsync="batch")
    wal.append(b"first", max_ts=1)
    wal.append(b"second", max_ts=2)
    wal.close()
    (path,) = _wal_segments(tmp_path)
    whole = os.path.getsize(path)
    # torn mid-payload, torn mid-header, and garbage-crc tails
    for tail in (b"\x40\x00\x00\x00\x99\x99\x99\x99partial",
                 b"\x02\x00",
                 struct.pack("<II", 3, 123456789) + b"xyz"):
        with open(path, "r+b") as f:
            f.truncate(whole)
            f.seek(whole)
            f.write(tail)
        wal2 = SegmentedWal(str(tmp_path / "w"), fsync="batch")
        got = []
        stats = wal2.replay(lambda p: got.append(p) or None)
        assert got == [b"first", b"second"]
        assert stats["torn_tails"] == 1
        assert os.path.getsize(path) == whole       # physically truncated


def test_read_segment_empty_and_foreign(tmp_path):
    p = tmp_path / "wal-00000001.log"
    p.write_bytes(b"")
    assert read_segment(str(p)) == ([], True, 0)
    p.write_bytes(b"not-a-wal-file")
    payloads, clean, valid = read_segment(str(p))
    assert payloads == [] and not clean and valid == 0


# -- recovery equivalence -----------------------------------------------------


@pytest.mark.parametrize("shards", [1, 4])
def test_recovery_equivalence_clean_shutdown(tmp_path, shards):
    rng = random.Random(11)
    pts = _random_stream(rng, 400)
    srv = TSDBServer(persist_dir=str(tmp_path), shards=shards)
    ref = TSDBServer(shards=shards)
    i = 0
    while i < len(pts):
        k = rng.randint(1, 64)
        srv.write(pts[i:i + k])
        ref.write(pts[i:i + k])
        i += k
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path), shards=shards)
    rec.load_persisted()
    _assert_equivalent(rec.db("global"), ref.db("global"))


@pytest.mark.parametrize("shards", [1, 4])
def test_recovery_equivalence_after_snapshot(tmp_path, shards):
    rng = random.Random(13)
    pts = _random_stream(rng, 300)
    srv = TSDBServer(persist_dir=str(tmp_path), shards=shards)
    ref = TSDBServer(shards=shards)
    for db in (srv, ref):
        for i in range(0, len(pts), 50):
            db.write(pts[i:i + 50])
    st = srv.snapshot()["global"]
    assert st["segments_dropped"] >= 1
    # post-snapshot writes land in fresh segments and replay on top
    tail = _pts(20, t0=500 * S, host="h9")
    srv.write(tail)
    ref.write(tail)
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path), shards=shards)
    stats = rec.load_persisted()["global"]
    assert stats["snapshot_series"] > 0
    assert stats["points_replayed"] == 20
    _assert_equivalent(rec.db("global"), ref.db("global"))


@pytest.mark.parametrize("old,new", [(4, 1), (1, 4), (4, 2)])
def test_recovery_rehashes_on_shard_count_change(tmp_path, old, new):
    rng = random.Random(17)
    pts = _random_stream(rng, 300)
    srv = TSDBServer(persist_dir=str(tmp_path), shards=old)
    ref = TSDBServer(shards=new)
    for db in (srv, ref):
        for i in range(0, len(pts), 40):
            db.write(pts[i:i + 40])
    srv.snapshot()          # snapshot carries the old layout too
    extra = _pts(15, t0=600 * S, host="h2")
    srv.write(extra)
    ref.write(extra)
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path), shards=new)
    rec.load_persisted()
    _assert_equivalent(rec.db("global"), ref.db("global"), exact=False)
    # a second restart must not double-apply folded orphan logs
    rec.close()
    rec2 = TSDBServer(persist_dir=str(tmp_path), shards=new)
    rec2.load_persisted()
    _assert_equivalent(rec2.db("global"), ref.db("global"), exact=False)


def test_recovery_tolerates_corrupt_snapshot(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path))
    srv.write(_pts(30))
    srv.close()
    srv2 = TSDBServer(persist_dir=str(tmp_path))
    srv2.load_persisted()
    srv2.snapshot()
    srv2.close()
    snap = tmp_path / "global" / "snapshot.json"
    snap.write_bytes(b'{"broken": tru')
    rec = TSDBServer(persist_dir=str(tmp_path))
    stats = rec.load_persisted()["global"]
    assert "snapshot_error" in stats        # warned, not raised
    # snapshot unreadable AND segments compacted away: data loss is
    # bounded to the snapshot, recovery itself still succeeds
    assert rec.db("global").point_count() == 0


def test_concurrent_writers_recover_exact_count(tmp_path):
    """Satellite regression: the legacy path appended outside any lock
    and interleaved partial lines; the WAL serializes appends.  N
    threads x M batches -> recovered point count exact."""
    threads, batches, batch = 8, 20, 25
    srv = TSDBServer(persist_dir=str(tmp_path), shards=4)

    def writer(w):
        for b in range(batches):
            base = (w * batches + b) * batch
            srv.write([Point("m", {"hostname": f"h{w}"},
                             {"v": float(base + i)},
                             (base + i) * 1_000_000)
                       for i in range(batch)])
    ts = [threading.Thread(target=writer, args=(w,))
          for w in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path), shards=4)
    rec.load_persisted()
    total = threads * batches * batch
    assert rec.db("global").point_count() == total
    out = rec.db("global").aggregate("m", "v", agg="count",
                                     group_by_tag="hostname")
    assert out == {f"h{w}": float(batches * batch)
                   for w in range(threads)}


# -- legacy JSONL import ------------------------------------------------------


def test_legacy_jsonl_torn_tail_and_interleaved_lines(tmp_path):
    """Satellite regression: the old ``load_persisted`` raised
    ``JSONDecodeError`` on a torn trailing line and the whole DB failed
    to recover.  Torn tails and interleaved partial lines (the unlocked
    concurrent-append bug) are now skipped, surviving points land in
    the new WAL format, and the legacy file is retired."""
    legacy = tmp_path / "global.jsonl"
    with open(legacy, "w") as f:
        for i in range(10):
            f.write(json.dumps({"m": "m", "t": {"hostname": "h0"},
                                "f": {"v": float(i)}, "ts": i * S}) + "\n")
        # interleaved partial line from a concurrent writer ...
        f.write('{"m": "m", "t": {"hostname{"m": "m", "t": '
                '{"hostname": "h1"}, "f": {"v": 1.0}, "ts": 1}\n')
        for i in range(10, 15):
            f.write(json.dumps({"m": "m", "t": {"hostname": "h0"},
                                "f": {"v": float(i)}, "ts": i * S}) + "\n")
        # ... and a torn tail from a kill mid-write
        f.write('{"m": "m", "t": {"hostn')
    srv = TSDBServer(persist_dir=str(tmp_path))
    stats = srv.load_persisted()["global"]["legacy_import"]
    assert stats["points"] == 15
    assert stats["lines_skipped"] == 2
    assert srv.db("global").point_count() == 15
    assert not legacy.exists()
    assert (tmp_path / "global.jsonl.imported").exists()
    srv.close()
    # the import went through the WAL: a restart still has the points,
    # and the retired file is not imported twice
    rec = TSDBServer(persist_dir=str(tmp_path))
    stats2 = rec.load_persisted()
    assert "legacy_import" not in stats2.get("global", {})
    assert rec.db("global").point_count() == 15


# -- retention + compaction ---------------------------------------------------


def test_enforce_retention_drops_whole_expired_segments(tmp_path):
    from repro.core.line_protocol import now_ns
    now = now_ns()
    srv = TSDBServer(persist_dir=str(tmp_path),
                     wal_segment_bytes=2000)
    old = [Point("m", {"hostname": "h0"}, {"v": float(i)},
                 now - 3600 * S + i * S) for i in range(200)]
    fresh = [Point("m", {"hostname": "h0"}, {"v": float(i)},
                   now - 10 * S + i) for i in range(50)]
    for i in range(0, 200, 20):
        srv.write(old[i:i + 20])
    srv.write(fresh)
    n_before = len(_wal_segments(tmp_path))
    assert n_before > 1                     # tiny segments -> rotation
    srv.enforce_retention(max_age_ns=60 * S)
    assert len(_wal_segments(tmp_path)) < n_before
    srv.close()
    # rollup windows fed by the dropped raw points survive recovery,
    # exactly like they survive in-memory retention
    ref = TSDBServer()
    for i in range(0, 200, 20):
        ref.write(old[i:i + 20])
    ref.write(fresh)
    ref.enforce_retention(max_age_ns=60 * S)
    rec = TSDBServer(persist_dir=str(tmp_path))
    rec.load_persisted()
    assert rec.db("global").rollup_aggregate(
        "m", "v", agg="count", window_ns=60 * S) == \
        ref.db("global").rollup_aggregate(
            "m", "v", agg="count", window_ns=60 * S)
    assert rec.db("global").stored_points() == \
        ref.db("global").stored_points()


def test_snapshot_bounds_recovery_to_live_data(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path))
    for i in range(10):
        srv.write(_pts(50, t0=i * 100 * S))
    # group commit may still hold bytes in the writer buffer, so read
    # the tracked sizes, not the on-disk file sizes
    before = srv.persistence_stats()["databases"]["global"]["wal_bytes"]
    srv.snapshot()
    after = srv.persistence_stats()["databases"]["global"]["wal_bytes"]
    assert after < before / 2
    srv.close()
    stats = TSDBServer(persist_dir=str(tmp_path)).load_persisted()
    assert stats["global"]["records_replayed"] == 0
    assert stats["global"]["snapshot_points"] == 500


def test_compaction_crash_window_not_fatal(tmp_path, monkeypatch):
    """A crash mid-compaction (snapshot persisted, covered segments not
    yet deleted — with or without the seq-floor placeholder written)
    must neither double-apply the covered segments nor skip the records
    of the next process (the pre-fix ordering lost them: segments
    dropped first, floor never written, numbering restarted below the
    snapshot head)."""
    for also_skip_floor in (False, True):
        d = tmp_path / f"floor{also_skip_floor}"
        srv = TSDBServer(persist_dir=str(d))
        srv.write(_pts(30))
        monkeypatch.setattr(SegmentedWal, "drop_segments_below",
                            lambda self, h: 0)
        if also_skip_floor:
            monkeypatch.setattr(SegmentedWal, "ensure_seq_floor",
                                lambda self, h: None)
        srv.snapshot()
        srv.close()
        monkeypatch.undo()
        srv2 = TSDBServer(persist_dir=str(d))
        srv2.load_persisted()
        assert srv2.db("global").point_count() == 30    # not doubled
        srv2.write(_pts(40, t0=10_000 * S))
        srv2.close()
        srv3 = TSDBServer(persist_dir=str(d))
        srv3.load_persisted()
        assert srv3.db("global").point_count() == 70    # none skipped


def test_idle_wal_flushes_within_commit_window(tmp_path):
    """fsync=batch group commit has a periodic half: a quiet WAL's
    buffered tail reaches the OS within ~flush_interval_s even when no
    further append ever comes."""
    srv = TSDBServer(persist_dir=str(tmp_path), fsync="batch")
    srv.write(_pts(20))
    deadline = time.monotonic() + 2.0
    on_disk = 0
    while time.monotonic() < deadline:
        on_disk = sum(os.path.getsize(p)
                      for p in _wal_segments(tmp_path))
        if on_disk > len(SEGMENT_MAGIC):
            break
        time.sleep(0.02)
    assert on_disk > len(SEGMENT_MAGIC)     # no close(), no 2nd write
    srv.close()


def test_store_rejects_path_traversal_db_names(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path))
    for bad in ("../escape", "a/b", "..", "."):
        with pytest.raises(ValueError):
            srv.store(bad)
    assert not os.path.exists(tmp_path.parent / "escape")


def test_router_sanitizes_remote_supplied_db_names(tmp_path):
    """jobids/usernames arrive over HTTP and become persisted database
    names (= directories): hostile characters are mapped, not rejected
    per-write (which would break that scope's ingest forever)."""
    srv = TSDBServer(persist_dir=str(tmp_path))
    router = MetricsRouter(srv, per_job_db=True, per_user_db=True)
    router.job_start("a/b", "../c", ["h0"])
    router.write([Point("m", {"hostname": "h0"}, {"v": 1.0}, 1)])
    assert "job_a_b" in srv.databases()
    for name in srv.databases():
        srv.store(name)         # every routed name is directory-safe
    srv.close()


def test_wal_directory_single_writer_lock(tmp_path):
    import repro.core.wal as wal_mod
    if wal_mod.fcntl is None:
        pytest.skip("no fcntl on this platform")
    srv = TSDBServer(persist_dir=str(tmp_path))
    srv.write(_pts(5))
    # a second writer on the same directory would interleave buffered
    # appends into the same segment files: fail fast instead
    with pytest.raises(RuntimeError):
        TSDBServer(persist_dir=str(tmp_path)).store("global")
    srv.close()                 # close releases the lock ...
    srv2 = TSDBServer(persist_dir=str(tmp_path))
    srv2.load_persisted()       # ... so a restart recovers normally
    assert srv2.db("global").point_count() == 5
    srv2.close()


def test_flusher_and_sealer_threads_are_shared(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path))
    for i in range(5):
        srv.write(_pts(3), f"db{i}")        # five DurableStores
    for name in ("lms-wal-flusher", "lms-wal-sealer"):
        assert sum(1 for t in threading.enumerate()
                   if t.name == name) <= 1, name
    srv.close()


# -- fsync modes + stats ------------------------------------------------------


@pytest.mark.parametrize("fsync", ["none", "batch", "always"])
def test_fsync_modes_roundtrip(tmp_path, fsync):
    srv = TSDBServer(persist_dir=str(tmp_path / fsync), fsync=fsync)
    srv.write(_pts(40))
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path / fsync))
    rec.load_persisted()
    assert rec.db("global").point_count() == 40


def test_invalid_fsync_mode_raises(tmp_path):
    with pytest.raises(ValueError):
        TSDBServer(persist_dir=str(tmp_path), fsync="sometimes")


def test_persistence_stats_surface(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path), fsync="batch")
    srv.write(_pts(25))
    st = srv.persistence_stats()
    assert st["enabled"] and st["fsync"] == "batch"
    db = st["databases"]["global"]
    assert db["appended_points"] == 25
    assert db["appended_records"] == 1
    assert db["segments"] >= 1 and db["wal_bytes"] > 0
    srv.close()
    assert TSDBServer().persistence_stats() == {"enabled": False}


# -- HTTP + stack integration -------------------------------------------------


def test_http_admin_snapshot_and_meta_persistence(tmp_path):
    import urllib.error
    import urllib.request
    from repro.core.httpd import LMSHttpServer

    srv = TSDBServer(persist_dir=str(tmp_path))
    router = MetricsRouter(srv)
    with LMSHttpServer(router) as http:
        srv.write(_pts(30))
        with urllib.request.urlopen(
                f"{http.url}/meta?what=persistence") as r:
            meta = json.loads(r.read())["persistence"]
        assert meta["enabled"]
        assert meta["databases"]["global"]["appended_points"] == 30
        req = urllib.request.Request(f"{http.url}/admin/snapshot",
                                     data=b"", method="POST")
        with urllib.request.urlopen(req) as r:
            snaps = json.loads(r.read())["snapshots"]
        assert snaps["global"]["points"] == 30
        # unknown names 404 without registering a database, and a name
        # that would escape persist_dir creates nothing on disk (the
        # store layer additionally rejects it with ValueError)
        for bad in ("../../escape", "globall"):
            req = urllib.request.Request(
                f"{http.url}/admin/snapshot?db={bad}", data=b"",
                method="POST")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req)
            assert ei.value.code == 404
        assert not os.path.exists(
            os.path.join(str(tmp_path), "..", "..", "escape"))
        assert not os.path.exists(os.path.join(str(tmp_path), "globall"))
    srv.close()
    # without persistence the trigger is a clean 409, not a 500
    router2 = MetricsRouter(TSDBServer())
    with LMSHttpServer(router2) as http:
        req = urllib.request.Request(f"{http.url}/admin/snapshot",
                                     data=b"", method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 409


def test_monitoring_stack_restart_resumes_history(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "out"),
                                      persist_dir=str(tmp_path / "wal"))
    with stack.job("j1", user="alice", hosts=["h0"]):
        agent = stack.host_agent("h0", hlo_flops=1e15, model_flops=8e14,
                                 hlo_bytes=1e12, collective_bytes=1e11,
                                 tokens_per_step=1e6)
        for s in range(20):
            agent.collect_step(step=s, step_time_s=1.0, ts=s * S)
    stack.close()
    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "out"),
                                       persist_dir=str(tmp_path / "wal"))
    assert stack2.recovery_stats            # auto-recovered on restart
    db = stack2.backend.db("global")
    assert "hpm" in db.measurements()
    out = db.aggregate("hpm", "step_time_s", agg="count")
    assert out[""] == 20.0
    stack2.close()


# -- satellite regressions: usermetric + host agent ---------------------------


def test_usermetric_rebuffers_on_sink_failure():
    sunk, fail = [], [True]

    def sink(points):
        if fail[0]:
            raise ConnectionError("router down")
        sunk.extend(points)

    um = UserMetric(sink, batch_size=4, flush_interval_s=9999,
                    hostname="h0")
    for i in range(3):
        um.metric("v", float(i))
    with pytest.raises(ConnectionError):
        um.flush()
    st = um.stats
    assert st["buffered"] == 3 and st["failed_flushes"] == 1
    assert st["sent_points"] == 0
    fail[0] = False                         # sink heals: nothing lost
    um.metric("v", 3.0)
    um.flush()
    assert [p.fields["value"] for p in sunk] == [0.0, 1.0, 2.0, 3.0]
    assert um.stats["sent_points"] == 4
    assert um.stats["dropped_points"] == 0


def test_usermetric_dead_sink_bounded_memory():
    def sink(points):
        raise ConnectionError("dead")

    um = UserMetric(sink, batch_size=1000, flush_interval_s=9999,
                    hostname="h0", max_buffered_points=50)
    for i in range(120):
        um.metric("v", float(i))
        if (i + 1) % 40 == 0:
            with pytest.raises(ConnectionError):
                um.flush()
    st = um.stats
    assert st["buffered"] <= 50
    assert st["dropped_points"] >= 120 - 50 - um.batch_size
    # the oldest points are the dropped ones; the newest survive
    assert um._buf[-1].fields["value"] == 119.0


def test_usermetric_stats_locked_under_concurrent_flush():
    backend = TSDBServer()
    um = UserMetric(MetricsRouter(backend), batch_size=10,
                    flush_interval_s=9999, hostname="h0")
    errors = []

    def emit(k):
        try:
            for i in range(200):
                um.metric(f"v{k}", float(i))
            um.flush()
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    ts = [threading.Thread(target=emit, args=(k,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    st = um.stats
    assert st["sent_points"] == 800 and st["buffered"] == 0
    assert backend.db("global").point_count() == 800


def test_host_agent_net_dev_malformed_rows(tmp_path):
    p = tmp_path / "net_dev"
    p.write_text(
        "Inter-|   Receive                |  Transmit\n"
        " face |bytes    packets ...      |bytes    packets ...\n"
        "  eth0: 100 0 0 0 0 0 0 0 200 0 0 0 0 0 0 0\n"
        "  badrow: not numbers at all\n"
        "  short: 7\n"
        "    lo: 999 0 0 0 0 0 0 0 999 0 0 0 0 0 0 0\n"
        "  eth1: 10 0 0 0 0 0 0 0 20 0 0 0 0 0 0 0\n")
    out = _read_net_dev(str(p))
    # malformed rows skipped, the rest (minus lo) still counted
    assert out == {"net_rx_bytes": 110.0 + 0, "net_tx_bytes": 220.0 + 0}


# -- stress tier: crash-recovery equivalence property -------------------------


def _crash_equivalence_roundtrip(seed, shards, recover_shards=None):
    """Write random batches; tear the tail record(s) mid-byte exactly
    like a kill between write() syscalls; recover; compare against a
    never-crashed reference fed the acknowledged prefix."""
    import shutil
    import tempfile

    rng = random.Random(seed)
    d = tempfile.mkdtemp()
    try:
        pts = _random_stream(rng, rng.randint(20, 250))
        srv = TSDBServer(persist_dir=d, shards=shards)
        ref = TSDBServer(shards=shards if recover_shards is None
                         else recover_shards)
        i = 0
        while i < len(pts):
            k = rng.randint(1, 40)
            srv.write(pts[i:i + k])
            ref.write(pts[i:i + k])
            i += k
        if rng.random() < 0.5:
            srv.snapshot()
            extra = _random_stream(rng, 30)
            srv.write(extra)
            ref.write(extra)
        srv.close()
        # in-flight tail batch, torn at a random byte offset: encode a
        # record the way the writer would and append only a prefix of it
        tail = _random_stream(rng, rng.randint(1, 30))
        by_series, tags_of = Database.group_points(tail)
        by_cols = {k2: Database.transpose_items(v)
                   for k2, v in by_series.items()}
        payload = encode_batch_payload(
            (m, tags_of[(m, k2)], ts, cs)
            for (m, k2), (ts, cs) in by_cols.items())
        record = struct.pack("<II", len(payload),
                             zlib.crc32(payload)) + payload
        cut = rng.randrange(len(record))    # 0 => nothing hit the disk
        seg = rng.choice(_wal_segments(d) or [None])
        if seg is None:
            seg = os.path.join(d, "global", "shard-0000",
                               "wal-00000001.log")
            os.makedirs(os.path.dirname(seg), exist_ok=True)
            with open(seg, "wb") as f:
                f.write(SEGMENT_MAGIC)
        with open(seg, "ab") as f:
            f.write(record[:cut])
        rec = TSDBServer(persist_dir=d,
                         shards=shards if recover_shards is None
                         else recover_shards)
        rec.load_persisted()
        _assert_equivalent(rec.db("global"), ref.db("global"),
                           exact=recover_shards is None)
    finally:
        shutil.rmtree(d, ignore_errors=True)


@pytest.mark.stress
@settings(max_examples=int(os.environ.get("LMS_PROPERTY_EXAMPLES", "30")),
          deadline=None)
@given(st.integers(min_value=0, max_value=10**9),
       st.sampled_from([1, 4]))
def test_property_crash_recovery_equivalence(seed, shards):
    """ANY stream x ANY mid-record crash offset x shards in {1, 4}: the
    recovered DB answers every aggregate/rollup/select identically to
    one that never died."""
    _crash_equivalence_roundtrip(seed, shards)


@pytest.mark.stress
def test_crash_recovery_equivalence_seeded():
    """Seeded variant of the property above — runs (bounded by
    LMS_PROPERTY_EXAMPLES) even where hypothesis is unavailable and the
    @given tests collect as skips."""
    examples = max(5, int(os.environ.get("LMS_PROPERTY_EXAMPLES", "30")))
    rng = random.Random(0xC0FFEE)
    for _ in range(examples):
        _crash_equivalence_roundtrip(rng.randrange(10**9),
                                     rng.choice([1, 4]))
    for _ in range(max(3, examples // 5)):
        seed = rng.randrange(10**9)
        _crash_equivalence_roundtrip(seed, shards=4, recover_shards=1)
        _crash_equivalence_roundtrip(seed, shards=1, recover_shards=4)


@pytest.mark.stress
@settings(max_examples=max(
    5, int(os.environ.get("LMS_PROPERTY_EXAMPLES", "30")) // 3),
    deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_property_crash_recovery_shard_rehash(seed):
    """Same property, recovering into a different shard count."""
    _crash_equivalence_roundtrip(seed, shards=4, recover_shards=1)
    _crash_equivalence_roundtrip(seed, shards=1, recover_shards=4)


# -- crash tier: real SIGKILL injection (ci_check.sh step 4) ------------------

_CRASH_WRITER = r"""
import sys, time
sys.path.insert(0, {src!r})
from repro.core.line_protocol import Point
from repro.core.tsdb import TSDBServer

srv = TSDBServer(persist_dir={d!r}, shards={shards}, fsync="batch")
srv.load_persisted()
b = 0
print("READY", flush=True)
while True:
    # one series per batch: a batch is exactly one WAL record on one
    # shard, so recovered per-host counts are whole multiples of 50
    srv.write([Point("m", {{"hostname": f"h{{b % 4}}"}},
                     {{"v": float(b * 50 + i), "batch": float(b)}},
                     (b * 50 + i) * 10**6) for i in range(50)])
    b += 1
    if b % 20 == 0:
        time.sleep(0.001)
"""


@pytest.mark.crash
@pytest.mark.parametrize("shards", [1, 4])
def test_sigkill_mid_write_recovers(tmp_path, shards):
    """Kill -9 a real writer process at a random moment, then recover:
    never an exception, counts consistent, recovery deterministic.
    Bounded by LMS_CRASH_ITERS (default 3 per shard count)."""
    iters = int(os.environ.get("LMS_CRASH_ITERS", "3"))
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    d = str(tmp_path / "wal")
    rng = random.Random(shards)
    for it in range(iters):
        proc = subprocess.Popen(
            [sys.executable, "-c",
             _CRASH_WRITER.format(src=os.path.abspath(src), d=d,
                                  shards=shards)],
            stdout=subprocess.PIPE)
        assert proc.stdout.readline().strip() == b"READY"
        time.sleep(rng.uniform(0.05, 0.4))
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        # recovery must never raise, whatever instant the kill hit
        rec = TSDBServer(persist_dir=d, shards=shards)
        rec.load_persisted()
        db = rec.db("global")
        n = db.point_count()
        assert n % 50 == 0                  # whole records only
        if n:
            # every recovered batch is complete and internally exact
            out = db.aggregate("m", "v", agg="count",
                               group_by_tag="hostname")
            assert sum(out.values()) == float(n)
            assert all(c % 50 == 0 for c in out.values())
        rec.close()     # release the single-writer lock (db stays readable)
        # recovery is deterministic: a second recovery agrees
        rec2 = TSDBServer(persist_dir=d, shards=shards)
        rec2.load_persisted()
        assert rec2.db("global").point_count() == n
        assert rec2.db("global").aggregate(
            "m", "v", agg="sum", group_by_tag="hostname") == \
            db.aggregate("m", "v", agg="sum", group_by_tag="hostname")
        # compact occasionally so later iterations exercise
        # snapshot + replay recovery as well
        if it % 2 == 0:
            rec2.snapshot()
        rec2.close()
