"""Multi-device behaviours (8 forced host devices, subprocess-isolated:
the main test process must keep seeing 1 device per the assignment)."""

import os
import subprocess
import sys
import textwrap

import pytest

from conftest import needs_partial_manual_shard_map

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pjit_train_step_on_mesh():
    """Smoke config train step under pjit on a 4x2 mesh with the production
    rule table: loss decreases and params stay sharded."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, TrainConfig, ShapeConfig
        from repro.launch.mesh import make_mesh_for
        from repro.launch.steps import build_train_bundle
        from repro.models.transformer import init_model_params, model_specs
        from repro.train.optim import get_optimizer
        from repro.parallel.sharding import shardings_for_specs, TRAIN_RULES
        from repro.data import SyntheticTokenSource

        cfg = get_config("lms-demo", smoke=True)
        tcfg = TrainConfig(num_microbatches=2, learning_rate=5e-3,
                           warmup_steps=1)
        shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
        mesh = make_mesh_for(8, model=2)
        assert mesh.devices.shape == (4, 2)

        bundle = build_train_bundle(cfg, shape, tcfg, mesh)
        params = init_model_params(cfg, 0)
        opt = get_optimizer(tcfg)
        opt_state = opt.init(params)
        psh = shardings_for_specs(model_specs(cfg), TRAIN_RULES, mesh)
        params = jax.device_put(params, psh)

        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       donate_argnums=(0, 1))
        src = SyntheticTokenSource(cfg.vocab_size, seed=0)
        losses = []
        with mesh:
            for i in range(6):
                t = src.batch(i, 8, 32)
                batch = {"tokens": jnp.asarray(t[:, :-1]),
                         "labels": jnp.asarray(t[:, 1:])}
                params, opt_state, m = step(params, opt_state, batch,
                                            jnp.int32(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        emb = params["embed"]["embedding"]
        assert len(emb.sharding.device_set) == 8
        print("LOSSES", [round(x, 3) for x in losses])
    """)
    assert "LOSSES" in out


def test_compressed_pmean_shard_map():
    """int8 compressed all-reduce over a pure-DP axis == exact mean (within
    quantization tolerance)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.train.compression import compressed_pmean

        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4, 16)),
                        jnp.float32)

        def f(xs):
            return compressed_pmean({"g": xs[0]}, "pod", "int8")["g"]

        got = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"),),
                                    out_specs=P(None),
                                    check_vma=False))(x)
        want = jnp.mean(x, axis=0)
        err = float(jnp.max(jnp.abs(got - want)))
        scale = float(jnp.max(jnp.abs(x))) / 127
        assert err <= scale, (err, scale)
    """)


@needs_partial_manual_shard_map
def test_cross_pod_compressed_train_step():
    """Full train step with hierarchical pod-axis int8 gradient sync (manual
    pod axis + auto data/model axes) compiles and runs."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, TrainConfig, ShapeConfig
        from repro.train.step import make_train_step
        from repro.train.optim import get_optimizer
        from repro.models.transformer import init_model_params
        from repro.parallel.sharding import (PartitionConstraints,
                                             TRAIN_RULES)

        cfg = get_config("lms-demo", smoke=True)
        tcfg = TrainConfig(grad_compression="int8", learning_rate=1e-3,
                           warmup_steps=1)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # inside the manual-pod region the constraints must not name "pod"
        pc = PartitionConstraints(TRAIN_RULES.with_overrides(
            batch=("data",)), mesh)
        step, _ = make_train_step(cfg, tcfg, pc=pc, mesh=mesh)
        params = init_model_params(cfg, 0)
        opt_state = get_optimizer(tcfg).init(params)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.zeros((8, 16), jnp.int32)}
        with mesh:
            p2, o2, m = jax.jit(step)(params, opt_state, batch,
                                      jnp.int32(0))
        assert jnp.isfinite(m["loss"])
        # compressed path really lowered an int8 all-gather over the pod axis
        txt = jax.jit(step).lower(params, opt_state, batch,
                                  jnp.int32(0)).compile().as_text()
        assert "s8" in txt and "all-gather" in txt, "int8 exchange missing"
        print("OK", float(m["loss"]))
    """)


def test_elastic_restart_smaller_mesh(tmp_path):
    """Checkpoint on a 4x2 mesh, restore onto 2x2 (elastic reshard)."""
    _run(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.transformer import init_model_params, model_specs
        from repro.parallel.sharding import shardings_for_specs, TRAIN_RULES
        from repro.ckpt import save_checkpoint, load_checkpoint
        from repro.launch.mesh import make_mesh_for

        cfg = get_config("lms-demo", smoke=True)
        params = init_model_params(cfg, 0)
        mesh8 = make_mesh_for(8, model=2)
        sh8 = shardings_for_specs(model_specs(cfg), TRAIN_RULES, mesh8)
        params = jax.device_put(params, sh8)
        save_checkpoint({str(tmp_path)!r}, 3, {{"params": params}})

        # "failure": restart with only 4 devices
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        sh4 = shardings_for_specs(model_specs(cfg), TRAIN_RULES, mesh4)
        step, out = load_checkpoint({str(tmp_path)!r},
                                    {{"params": params}},
                                    shardings={{"params": sh4}})
        emb = out["params"]["embed"]["embedding"]
        assert step == 3
        assert len(emb.sharding.device_set) == 4
        print("ELASTIC OK")
    """)


def test_compiled_step_constants_sharded_collectives():
    """Regression (marker PR satellite): the seed's train loop hardcoded
    collective_bytes=0.0 into the HPM step constants.  A model-sharded
    step compiles all-reduces/all-gathers; compiled_step_constants must
    surface their operand and wire bytes from the HLO walk."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, TrainConfig, ShapeConfig
        from repro.launch.mesh import make_mesh_for
        from repro.launch.steps import build_train_bundle
        from repro.models.transformer import init_model_params, model_specs
        from repro.parallel.sharding import shardings_for_specs, TRAIN_RULES
        from repro.train.optim import get_optimizer
        from repro.train.loop import compiled_step_constants
        from repro.data import SyntheticTokenSource

        cfg = get_config("lms-demo", smoke=True)
        tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=1)
        shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
        mesh = make_mesh_for(8, model=2)
        bundle = build_train_bundle(cfg, shape, tcfg, mesh)
        params = init_model_params(cfg, 0)
        opt = get_optimizer(tcfg)
        opt_state = opt.init(params)
        psh = shardings_for_specs(model_specs(cfg), TRAIN_RULES, mesh)
        params = jax.device_put(params, psh)
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings)
        t = SyntheticTokenSource(cfg.vocab_size, seed=0).batch(0, 8, 32)
        batch = {"tokens": jnp.asarray(t[:, :-1]),
                 "labels": jnp.asarray(t[:, 1:])}
        with mesh:
            compiled = step.lower(params, opt_state, batch,
                                  jnp.int32(0)).compile()
        consts = compiled_step_constants(compiled, model_flops=1.0,
                                         tokens_per_step=8 * 32)
        assert consts["hlo_flops"] > 0
        assert consts["collective_bytes"] > 0, consts
        assert consts["wire_bytes"] > 0, consts
        print("COLLECTIVE_BYTES", consts["collective_bytes"],
              consts["wire_bytes"])
    """)
    assert "COLLECTIVE_BYTES" in out
