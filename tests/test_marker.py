"""Marker-region instrumentation + per-region rooflines
(``repro.core.marker``, ROADMAP item 3).

Contracts under test:

* **Region accounting** — nested regions get exact inclusive/exclusive
  wall time (fake clock), mismatched stops raise, leaked children are
  force-closed into their own accumulators, the context manager stops on
  exception, region stacks are thread-local while totals merge.
* **Emission** — deltas since last flush, one shared timestamp per flush,
  ``UserMetric.region`` reroutes through the session (exact reentrant
  call counts) while still emitting the legacy ``<name>_time_s`` field.
* **Roofline query side** — :func:`roofline_spec` answers byte-identically
  local, sharded and HTTP-federated, keeps answering from rollups after
  raw retention, and calibration points bake measured peaks into specs
  built afterwards.
* **Analysis/dashboard wiring** — the ``low_roofline`` derived rule fires
  only on counter-instrumented regions; the dashboard grows a Roofline
  row whose panel embeds the same spec.
* Satellite regression: ``compiled_step_constants`` threads real
  collective operand/wire bytes from the HLO walk into the HPM step
  constants (the seed hardcoded ``collective_bytes=0.0``).
"""

import threading
import time

import pytest

from repro.core import MonitoringStack
from repro.core.analysis import default_rules, evaluate_rules_on_db
from repro.core.httpd import HttpQueryClient, LMSHttpServer
from repro.core.line_protocol import Point
from repro.core.marker import (CALIB_REGION, MARKER_MEASUREMENT,
                               MarkerSession, calibrate, low_roofline_rule,
                               register_roofline_group, roofline_peaks,
                               roofline_spec)
from repro.core.perf_groups import roofline_group_text
from repro.core.query import QueryEngine, QuerySpec
from repro.core.router import MetricsRouter
from repro.core.shard import FederatedQuery, ShardedDatabase
from repro.core.tsdb import Database, TSDBServer
from repro.core.usermetric import UserMetric

S = 1_000_000_000


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def tick(self, s):
        self.t += s


class CapturingEmitter:
    """UserMetric-shaped: records every metric() call."""

    def __init__(self):
        self.points = []

    def metric(self, name, fields, tags=None, ts=None):
        self.points.append((name, dict(fields), dict(tags or {}), ts))


# --------------------------------------------------------------------------
# region accounting
# --------------------------------------------------------------------------


def test_nested_inclusive_exclusive_time():
    clk = FakeClock()
    ms = MarkerSession(clock=clk)
    ms.start_region("outer")
    clk.tick(1.0)
    with ms.region("inner", counters={"flops": 5.0}):
        clk.tick(2.0)
    clk.tick(0.5)
    ms.stop_region("outer")
    snap = ms.snapshot()
    assert snap["outer"]["time_s"] == pytest.approx(3.5)
    assert snap["outer"]["excl_time_s"] == pytest.approx(1.5)
    assert snap["inner"]["time_s"] == pytest.approx(2.0)
    assert snap["inner"]["excl_time_s"] == pytest.approx(2.0)
    assert snap["inner"]["flops"] == 5.0
    assert snap["outer"]["calls"] == snap["inner"]["calls"] == 1.0


def test_mismatched_or_empty_stop_raises():
    ms = MarkerSession()
    with pytest.raises(ValueError):
        ms.stop_region("nope")
    ms.start_region("a")
    ms.start_region("b")
    with pytest.raises(ValueError):
        ms.stop_region("a")         # innermost is "b"
    assert ms.open_regions() == ["a", "b"]


def test_leaked_children_force_closed():
    clk = FakeClock()
    ms = MarkerSession(clock=clk)
    with ms.region("outer"):
        ms.start_region("leaked")   # never stopped by the caller
        clk.tick(1.0)
    snap = ms.snapshot()
    assert snap["leaked"]["time_s"] == pytest.approx(1.0)
    assert snap["outer"]["excl_time_s"] == pytest.approx(0.0)
    assert ms.open_regions() == []


def test_region_stops_on_exception():
    clk = FakeClock()
    ms = MarkerSession(clock=clk)
    with pytest.raises(RuntimeError):
        with ms.region("body"):
            clk.tick(1.0)
            raise RuntimeError("boom")
    assert ms.open_regions() == []
    assert ms.snapshot()["body"]["time_s"] == pytest.approx(1.0)


def test_region_add_counters():
    ms = MarkerSession()
    with ms.region("r", counters={"bytes": 1.0}) as r:
        r.add(bytes=2.0, tokens=3.0)
    acc = ms.snapshot()["r"]
    assert acc["bytes"] == 3.0 and acc["tokens"] == 3.0


def test_record_external_timing():
    ms = MarkerSession()
    ms.record("wait", 0.25, counters={"bytes": 4.0})
    ms.record("wait", 0.75)
    acc = ms.snapshot()["wait"]
    assert acc["calls"] == 2.0
    assert acc["time_s"] == pytest.approx(1.0)
    assert acc["excl_time_s"] == pytest.approx(1.0)
    assert acc["bytes"] == 4.0


def test_thread_local_stacks_shared_totals():
    ms = MarkerSession()
    barrier = threading.Barrier(2)
    errs = []

    def worker(name):
        try:
            with ms.region("shared"):
                with ms.region(f"only_{name}"):
                    barrier.wait(timeout=5)
                    # both threads inside: my stack sees MY nesting only
                    assert ms.open_regions() == ["shared", f"only_{name}"]
                    barrier.wait(timeout=5)
        except Exception as e:          # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(n,)) for n in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    snap = ms.snapshot()
    assert snap["shared"]["calls"] == 2.0       # totals merged
    assert snap["only_a"]["calls"] == snap["only_b"]["calls"] == 1.0


# --------------------------------------------------------------------------
# emission
# --------------------------------------------------------------------------


def test_flush_emits_deltas_with_shared_ts():
    em = CapturingEmitter()
    clk = FakeClock()
    ms = MarkerSession(em, clock=clk, emit_interval_s=1e9)
    with ms.region("a"):
        clk.tick(1.0)
    out = ms.flush(ts=7)
    assert set(out) == {"a"}
    with ms.region("a"):
        clk.tick(2.0)
    out2 = ms.flush(ts=9)
    # second flush carries only the delta since the first
    assert out2["a"]["time_s"] == pytest.approx(2.0)
    assert out2["a"]["calls"] == 1.0
    assert ms.flush() == {}                     # drained
    assert [p[3] for p in em.points] == [7, 9]
    assert all(p[0] == MARKER_MEASUREMENT for p in em.points)
    assert em.points[0][2] == {"region": "a"}
    # lifetime totals are not reset by flush
    assert ms.snapshot()["a"]["time_s"] == pytest.approx(3.0)


def test_periodic_emission_on_interval():
    em = CapturingEmitter()
    clk = FakeClock()
    ms = MarkerSession(em, clock=clk, emit_interval_s=5.0)
    with ms.region("r"):
        clk.tick(1.0)
    assert em.points == []                      # interval not reached
    clk.tick(10.0)
    with ms.region("r"):
        clk.tick(1.0)
    assert len(em.points) == 1                  # auto-flushed on stop


def test_usermetric_region_reentrant_and_legacy():
    pts = []

    class Sink:
        def write(self, batch):
            pts.extend(batch)

    um = UserMetric(Sink(), hostname="h0", batch_size=10_000)

    def phase():
        with um.region("phase"):
            time.sleep(0.001)

    def outer():
        with um.region("phase"):        # reentrant: phase inside phase
            phase()

    outer()
    phase()
    um.flush()
    marker = [p for p in pts if p.measurement == MARKER_MEASUREMENT]
    legacy = [p for p in pts if p.measurement == "phase_time_s"]
    # the old implementation emitted only per-call durations; the marker
    # path counts the 3 calls exactly (2 reentrant + 1 plain)
    assert sum(p.fields["calls"] for p in marker) == 3.0
    assert len(legacy) == 3                     # backward-compat field
    total = sum(p.fields["time_s"] for p in marker)
    assert total >= sum(p.fields["value"] for p in legacy) - 1e-9


# --------------------------------------------------------------------------
# roofline query side: parity + retention + calibration
# --------------------------------------------------------------------------


def _marker_points(n=90, regions=("fwd", "opt"), hosts=2):
    """Deterministic marker deltas (binary fractions) across regions/hosts;
    region ``opt`` carries no flops/bytes counters."""
    pts = []
    for i in range(n):
        for h in range(hosts):
            tags_base = {"hostname": f"h{h}", "jobid": "j0"}
            pts.append(Point(MARKER_MEASUREMENT,
                             {**tags_base, "region": "fwd"},
                             {"time_s": 0.25 + 0.125 * (i % 2),
                              "calls": 2.0,
                              "flops": float((h + 1) * 2 ** 30),
                              "bytes": float((h + 1) * 2 ** 20)},
                             i * S))
            pts.append(Point(MARKER_MEASUREMENT,
                             {**tags_base, "region": "opt"},
                             {"time_s": 0.0625, "calls": 2.0}, i * S))
    return pts


def _write(db, pts, batch=64):
    for i in range(0, len(pts), batch):
        db.write(pts[i:i + batch])


def test_roofline_spec_local_sharded_federated_identical():
    pts = _marker_points()
    spec = roofline_spec("j0")
    single = Database("one")
    _write(single, pts)
    a = QueryEngine(single).query(spec)
    # per-region groups with derived roofline columns; the counter-less
    # region yields no derived windows but keeps its time/calls columns
    assert set(a.groups) == {"fwd", "opt"}
    assert a.groups["fwd"]["roofline_frac"]["values"]
    assert "roofline_frac" not in a.groups["opt"]
    assert a.groups["opt"]["time_s"]["values"]
    for shards in (2, 4, 7):
        sharded = ShardedDatabase("many", shards=shards)
        _write(sharded, pts)
        b = QueryEngine(sharded).query(spec)
        assert a.to_json() == b.to_json(), shards
    routers = [MetricsRouter(TSDBServer(shards=2)) for _ in range(2)]
    for p in pts:       # each host's series lives on exactly one instance
        routers[int(p.tags["hostname"][1:]) % 2].backend.write([p])
    with LMSHttpServer(routers[0]) as sa, LMSHttpServer(routers[1]) as sb:
        fed = FederatedQuery([HttpQueryClient(sa.url),
                              HttpQueryClient(sb.url)])
        c = QueryEngine(fed).query(spec)
        assert a.to_json() == c.to_json()


def test_roofline_survives_raw_retention():
    pts = _marker_points()
    db = Database("ret")
    _write(db, pts)
    spec = roofline_spec("j0")          # 10s window nests into 10s tier
    before = QueryEngine(db).query(spec)
    dropped = db.enforce_retention(max_points_per_series=1)
    assert dropped["raw_points_dropped"] > 0
    after = QueryEngine(db).query(spec)
    assert before.to_json() == after.to_json()


def test_calibration_points_and_group_registration():
    try:
        db = Database("cal")
        assert roofline_peaks(db) is None
        um = UserMetric(db, hostname="h0", batch_size=10_000)
        calibrate(um, peak_flops=1e12, peak_bw=1e11, ts=5 * S)
        calibrate(um, peak_flops=2e12, peak_bw=2e11, ts=9 * S)
        assert roofline_peaks(db) == (2e12, 2e11)   # latest point wins
        # specs built after calibration embed the peaks as literals — the
        # formula text (not remote state) carries them to any federation
        frac = dict(roofline_spec().metrics)["roofline_frac"]
        assert "2000000000000.0" in frac and "200000000000.0" in frac
        # uncalibrated text references the HW constants instead
        assert "PEAK_FLOPS" in roofline_group_text()
    finally:
        register_roofline_group()       # restore defaults for other tests
    assert "PEAK_FLOPS" in dict(roofline_spec().metrics)["roofline_frac"]


def test_low_roofline_rule_only_fires_on_instrumented_regions():
    db = Database("rule")
    pts = []
    for i in range(100):
        base = {"hostname": "h0", "jobid": "j0"}
        # instrumented region sustained at ~1e-5 of attainable
        pts.append(Point(MARKER_MEASUREMENT, {**base, "region": "slow"},
                         {"time_s": 1.0, "calls": 1.0, "flops": 1e9,
                          "bytes": 1e9}, i * S))
        # un-instrumented region: no counters -> no derived windows ->
        # the "<" rule must never treat it as violating
        pts.append(Point(MARKER_MEASUREMENT, {**base, "region": "plain"},
                         {"time_s": 1.0, "calls": 1.0}, i * S))
    _write(db, pts)
    rule = low_roofline_rule(0.05, min_duration_s=30.0)
    findings = evaluate_rules_on_db(db, [rule], group_by_tag="region")
    assert findings, "sustained low-roofline region must fire"
    assert {f.host for f in findings} == {"slow"}
    assert all(f.rule == "low_roofline" for f in findings)
    # wired into the default rule set
    assert any(r.name == "low_roofline" and r.expr
               for r in default_rules())


# --------------------------------------------------------------------------
# stack wiring: dashboard row + /meta endpoint + end-to-end emission
# --------------------------------------------------------------------------


def test_stack_markers_dashboard_and_meta(tmp_path):
    st = MonitoringStack.inprocess(out_dir=str(tmp_path))
    try:
        with st.job("mj", user="u", hosts=["h0"]) as job:
            mk = st.marker_session(host="h0")
            with mk.region("phase:a", counters={"flops": 2.0 ** 40,
                                                "bytes": 2.0 ** 30}):
                time.sleep(0.002)
            mk.flush()
        db = st.backend.db("global")
        # router enriched the points with the live job's tags
        series = db.select(MARKER_MEASUREMENT, None, {"region": "phase:a"})
        assert series and series[0].tags["jobid"] == "mj"
        # dashboard: Roofline row embeds the canonical /query/v2 spec,
        # marker is excluded from the generic app rows
        dash = st.dashboards.build_dashboard(job)
        rows = {r["title"]: r for r in dash["dashboard"]["rows"]}
        assert "Roofline" in rows and "app:marker" not in rows
        tgt = rows["Roofline"]["panels"][0]["targets"][0]
        assert tgt["query_v2"] == roofline_spec("mj").to_dict()
        html = st.dashboards.render_html(job, dash)
        assert "phase:a" in html and "roofline frac" in html
        # the panel's spec IS executable via the engine (what /query/v2
        # would run) and groups by region
        res = st.backend.query_engine("global").query(
            QuerySpec.from_dict(tgt["query_v2"]))
        assert "phase:a" in res.groups
        assert res.groups["phase:a"]["roofline_frac"]["values"]
    finally:
        st.close()


def test_meta_roofline_endpoint(tmp_path):
    st = MonitoringStack.inprocess(out_dir=str(tmp_path), serve_http=True)
    try:
        import json
        import urllib.request
        meta = json.loads(urllib.request.urlopen(
            f"{st.http.url}/meta?what=roofline").read())["roofline"]
        assert "roofline_frac" in meta["metrics"]
        assert meta["calibrated"] is None
        calibrate(st.usermetric(host="h0"), 1e12, 1e11, register=False)
        meta = json.loads(urllib.request.urlopen(
            f"{st.http.url}/meta?what=roofline").read())["roofline"]
        assert meta["calibrated"] == {"peak_flops": 1e12, "peak_bw": 1e11}
    finally:
        st.close()


def test_kernel_wrappers_instrumented_eager_only():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    ms = MarkerSession()
    prev = ops.set_kernel_markers(ms)
    try:
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((1, 128, 2, 16)), jnp.float32)
        ops.flash_attention_bshd(q, q, q, interpret=True)
        x = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)
        ops.fused_rmsnorm(x, jnp.ones((32,), jnp.float32), interpret=True)
        snap = ms.snapshot()
        assert snap["kernel:flash_attention"]["flops"] > 0
        assert snap["kernel:rmsnorm"]["bytes"] > 0
        # under jit the wrapper body runs at trace time on tracers:
        # instrumentation must skip (timing a trace is noise)
        before = ms.snapshot()["kernel:flash_attention"]["calls"]
        jit_fa = jax.jit(lambda a: ops.flash_attention_bshd(
            a, a, a, interpret=True))
        jit_fa(q)
        assert ms.snapshot()["kernel:flash_attention"]["calls"] == before
    finally:
        ops.set_kernel_markers(prev)


# --------------------------------------------------------------------------
# satellite regression: collective bytes reach the HPM step constants
# --------------------------------------------------------------------------

_SHARDED_HLO = """HloModule m, num_partitions=4

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p: f32[1024,256]) -> f32[1024,256] {
  %p = f32[1024,256]{1,0} parameter(0)
  ROOT %ar = f32[1024,256]{1,0} all-reduce(%p), replica_groups={},
    to_apply=%sum
}
"""


class _StubCompiled:
    """Compiled-artifact shape: cost_analysis + as_text."""

    def cost_analysis(self):
        return {"flops": 1e9, "bytes accessed": 1e8}

    def as_text(self):
        return _SHARDED_HLO


def test_compiled_step_constants_threads_collective_bytes():
    from repro.train.loop import compiled_step_constants
    consts = compiled_step_constants(_StubCompiled(), model_flops=2e9,
                                     tokens_per_step=4096.0)
    assert consts["hlo_flops"] == 1e9
    assert consts["hlo_bytes"] == 1e8
    # the seed hardcoded collective_bytes=0.0; the HLO walk sees the
    # all-reduce (1024*256 f32 operand = 1 MiB per device)
    assert consts["collective_bytes"] == pytest.approx(1024 * 256 * 4)
    assert consts["wire_bytes"] > 0
    assert consts["model_flops"] == 2e9
    assert consts["tokens_per_step"] == 4096.0


def test_compiled_step_constants_no_collectives():
    from repro.train.loop import compiled_step_constants

    class _Plain(_StubCompiled):
        def as_text(self):
            return """HloModule m

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{1,0} parameter(0)
  ROOT %t = f32[8]{1,0} tanh(%p)
}
"""
    consts = compiled_step_constants(_Plain(), model_flops=1.0,
                                     tokens_per_step=1.0)
    assert consts["collective_bytes"] == 0.0
    assert consts["wire_bytes"] == 0.0


def test_serving_engine_request_phase_regions(tmp_path):
    np = pytest.importorskip("numpy")
    from repro.configs import get_config
    from repro.models.transformer import init_model_params
    from repro.serve.engine import ServingEngine

    cfg = get_config("lms-demo", smoke=True)
    params = init_model_params(cfg, seed=0)
    st = MonitoringStack.inprocess(out_dir=str(tmp_path))
    try:
        with st.job("sv1", user="u", hosts=["h0"]):
            um = st.usermetric(host="h0")
            eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                                usermetric=um, jit=False)
            for i in range(3):
                eng.submit(np.arange(1, 5 + i), max_new_tokens=4)
            done = eng.run_until_empty()
            um.flush()
        assert len(done) == 3
        snap = eng.markers.snapshot()
        # one prefill+decode per batch, one request record per request
        assert snap["serve:prefill"]["calls"] == 1.0
        assert snap["serve:decode"]["calls"] == 1.0
        assert snap["serve:request"]["calls"] == 3.0
        assert snap["serve:request"]["tokens"] == sum(
            len(r.output) for r in done)
        assert snap["serve:decode"]["tokens"] > 0
        db = st.backend.db("global")
        regions = set(db.tag_values(MARKER_MEASUREMENT, "region"))
        assert {"serve:prefill", "serve:decode",
                "serve:request"} <= regions
    finally:
        st.close()
