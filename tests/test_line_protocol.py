"""Line-protocol round-trip: unit + hypothesis property tests."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # minimal images: property tests skip, rest run
    from _hypothesis_compat import given, settings, st

from repro.core.line_protocol import (LineProtocolError, Point, decode_batch,
                                      decode_line, encode_batch,
                                      encode_point)

# -- unit ------------------------------------------------------------------


def test_basic_roundtrip():
    p = Point("cpu", {"hostname": "h0", "core": "3"},
              {"load": 0.5, "count": 7, "ok": True, "note": "hi"}, 1234)
    q = decode_line(encode_point(p))
    assert q.measurement == "cpu"
    assert q.tags == p.tags
    assert q.fields == p.fields
    assert q.timestamp == 1234


def test_escaping():
    p = Point("my measure,ment", {"k ey": "v=al,ue"},
              {"str": 'quote " and \\ backslash', "f": 1.0}, 1)
    q = decode_line(encode_point(p))
    assert q.measurement == p.measurement
    assert q.tags == p.tags
    assert q.fields == p.fields


def test_batch():
    pts = [Point("m", {"hostname": f"h{i}"}, {"v": float(i)}, i)
           for i in range(5)]
    out = decode_batch(encode_batch(pts))
    assert [p.fields["v"] for p in out] == [0.0, 1.0, 2.0, 3.0, 4.0]


def test_no_timestamp():
    q = decode_line('m,hostname=h v=1.5')
    assert q.timestamp is None
    assert q.fields == {"v": 1.5}


def test_int_vs_float():
    q = decode_line('m f=3i,g=3.0,b=t')
    assert q.fields["f"] == 3 and isinstance(q.fields["f"], int)
    assert q.fields["g"] == 3.0 and isinstance(q.fields["g"], float)
    assert q.fields["b"] is True


def test_nan_inf_extension():
    p = Point("m", {}, {"a": float("nan"), "b": float("inf")})
    q = decode_line(encode_point(p))
    assert math.isnan(q.fields["a"])
    assert q.fields["b"] == float("inf")


@pytest.mark.parametrize("bad", ["", "m", "m, v=", "m v=notanumber",
                                 'm s="unterminated'])
def test_rejects_malformed(bad):
    with pytest.raises((LineProtocolError, ValueError)):
        decode_line(bad)


def test_fast_and_slow_decode_agree():
    """Seeded-random roundtrips covering both decoder paths: plain lines
    (fast ``str.split`` path) and escape/quote-laden lines (slow path)."""
    import random
    rng = random.Random(0)
    plain = "abcdefgh0123_-."
    tricky = plain + " ,="           # escaped by the encoder -> slow path
    strchars = tricky + '"\\'        # legal only inside quoted string fields

    def rand_name(alphabet):
        s = "".join(rng.choice(alphabet) for _ in range(rng.randint(1, 12)))
        return s.strip() or "x"

    for alphabet in (plain, tricky):
        for _ in range(200):
            fields = {rand_name(alphabet): rng.choice(
                [rng.uniform(-1e6, 1e6), rng.randint(-9999, 9999), True,
                 rand_name(strchars)]) for _ in range(rng.randint(1, 4))}
            p = Point(rand_name(alphabet),
                      {rand_name(alphabet): rand_name(alphabet)},
                      fields, rng.randrange(10**15))
            q = decode_line(encode_point(p))
            assert q.measurement == p.measurement
            assert q.tags == p.tags
            assert q.timestamp == p.timestamp
            for k, v in p.fields.items():
                if isinstance(v, float):
                    assert q.fields[k] == pytest.approx(v)
                else:
                    assert q.fields[k] == v


# -- property --------------------------------------------------------------

# the line protocol is newline-framed: bare CR/LF cannot appear in names
# (InfluxDB has the same restriction)
_name = st.text(
    st.characters(codec="ascii", exclude_characters='\n\r\\"'),
    min_size=1, max_size=20).filter(lambda s: s.strip() == s and s and
                                    not s.startswith("#"))
_tagval = st.text(
    st.characters(codec="ascii", exclude_characters="\n\r\\\""),
    min_size=1, max_size=20).filter(lambda s: s == s.strip() and s)
_fieldval = st.one_of(
    st.integers(min_value=-2**53, max_value=2**53),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.booleans(),
    st.text(st.characters(codec="ascii", exclude_characters="\n"),
            max_size=30),
)


@settings(max_examples=200, deadline=None)
@given(measurement=_name,
       tags=st.dictionaries(_name.filter(lambda s: s == s.strip()), _tagval,
                            max_size=4),
       fields=st.dictionaries(_name.filter(lambda s: s == s.strip()),
                              _fieldval, min_size=1, max_size=5),
       ts=st.one_of(st.none(), st.integers(min_value=0, max_value=2**62)))
def test_roundtrip_property(measurement, tags, fields, ts):
    p = Point(measurement, tags, fields, ts)
    q = decode_line(encode_point(p))
    assert q.measurement == p.measurement
    assert q.tags == {str(k): str(v) for k, v in p.tags.items()}
    assert q.timestamp == p.timestamp
    assert set(q.fields) == set(p.fields)
    for k, v in p.fields.items():
        got = q.fields[k]
        if isinstance(v, float):
            assert got == pytest.approx(v, rel=1e-6)
        else:
            assert got == v and type(got) is type(v)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(_name, _fieldval), min_size=1, max_size=10))
def test_batch_property(items):
    pts = [Point(m, {"hostname": "h"}, {"v": v}, i)
           for i, (m, v) in enumerate(items)]
    out = decode_batch(encode_batch(pts))
    assert len(out) == len(pts)
    assert [p.timestamp for p in out] == list(range(len(pts)))
