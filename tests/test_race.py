"""Dynamic lock-order tier (``-m race``).

Two layers:

* unit tests for ``repro.core.locktrace`` itself — edge recording,
  RLock reentrancy, the threading.Condition protocol, and cycle
  detection on a seeded A->B / B->A inversion;
* the static/dynamic cross-check — run a real monitoring stack (WAL +
  cold tier + sharding + HTTP + binary ingest + continuous analysis)
  under the tracer, map every observed ``held -> acquired`` site pair to
  the ``Class.attr`` lock nodes of the ``repro.analyzer`` static graph,
  and assert the dynamic graph is a **subgraph of the static one**.
  Combined with the static pass proving that graph acyclic, every lock
  order the tests actually executed is deadlock-free — and any future
  code path that acquires locks in an order the analyzer cannot see
  fails here instead of hanging in production.

See tests/README.md ("Race tier") and docs/ARCHITECTURE.md
("Invariants & static analysis").
"""

import os
import threading
import time

import pytest

from repro.core import MonitoringStack, locktrace

pytestmark = pytest.mark.race

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
CORE_DIR = os.path.join(os.path.dirname(TESTS_DIR), "src", "repro",
                        "core")


@pytest.fixture
def tracer():
    """Install the tracer with this test file's directory allowed, so
    locks created in test bodies are traced too."""
    locktrace.reset()
    locktrace.install(extra_paths=[TESTS_DIR])
    try:
        yield locktrace
    finally:
        locktrace.uninstall()
        locktrace.reset()


# --------------------------------------------------------------------------
# locktrace unit tests
# --------------------------------------------------------------------------


def test_nested_acquire_records_edge(tracer):
    a = threading.Lock()
    b = threading.Lock()
    assert isinstance(a, locktrace.TracingLock)
    with a:
        with b:
            pass
    assert tracer.edges().get((a.site, b.site)) == 1
    # sequential (non-nested) acquisition records nothing
    with a:
        pass
    with b:
        pass
    assert (b.site, a.site) not in tracer.edges()


def test_rlock_reacquire_records_no_self_edge(tracer):
    r = threading.RLock()
    with r:
        with r:                       # reentrant: no edge
            pass
    assert all(r.site not in e for e in tracer.edges())


def test_release_out_of_order_keeps_stack_honest(tracer):
    # one per line: a creation *site* is (file, line), shared sites
    # would collapse the three locks into one node
    a = threading.Lock()
    b = threading.Lock()
    c = threading.Lock()
    a.acquire()
    b.acquire()
    a.release()                        # hand-over-hand: a out, b stays
    c.acquire()
    b.release()
    c.release()
    e = tracer.edges()
    assert (a.site, b.site) in e
    assert (b.site, c.site) in e
    assert (a.site, c.site) not in e   # a was already released


def test_condition_wait_releases_on_stack(tracer):
    cv = threading.Condition(threading.Lock())
    other = threading.Lock()
    assert isinstance(cv._lock, locktrace.TracingLock)
    done = []

    def waiter():
        with cv:
            cv.wait(timeout=5)
            with other:                # still holding cv after wake-up
                done.append(1)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.1)
    with cv:
        cv.notify_all()
    t.join(5)
    assert done == [1]
    e = tracer.edges()
    assert (cv._lock.site, other.site) in e
    # wait() released the cv through the wrapper: had the stack gone
    # stale, the *main* thread's cv acquire (under nothing) or the
    # waiter's other-acquire would have minted a reversed edge
    assert (other.site, cv._lock.site) not in e


def test_find_cycle_detects_seeded_inversion(tracer):
    a = threading.Lock()
    b = threading.Lock()
    with a:
        with b:
            pass
    with b:
        with a:                        # the classic AB/BA inversion
            pass
    cyc = locktrace.find_cycle(tracer.edges())
    assert cyc is not None
    assert cyc[0] == cyc[-1]
    assert {a.site, b.site} <= set(cyc)


def test_uninstall_restores_real_factories():
    assert not locktrace.installed()
    lk = threading.Lock()
    assert not isinstance(lk, locktrace.TracingLock)


# --------------------------------------------------------------------------
# static/dynamic cross-check on the real stack
# --------------------------------------------------------------------------


def _drive_stack(tmp_path):
    """A bounded workload touching every locking subsystem: WAL-backed
    sharded writes, cold tier, jobs, host agents, usermetric, HTTP
    queries, binary ingest, analysis ticks, snapshot, recovery."""
    stack = MonitoringStack(
        out_dir=str(tmp_path / "dash"),
        persist_dir=str(tmp_path / "wal"), fsync="batch",
        serve_http=True, serve_ingest=True, shards=2, cold_tier=True)
    try:
        hosts = ["h0", "h1"]
        with stack.job("race-job", user="u", hosts=hosts) as job:
            agents = [stack.host_agent(h) for h in hosts]
            um = stack.usermetric(host=hosts[0])

            def worker(agent, base):
                for step in range(12):
                    agent.collect_step(step=step,
                                       step_time_s=0.01 * (base + 1))
                agent.flush()

            threads = [threading.Thread(target=worker, args=(a, i))
                       for i, a in enumerate(agents)]
            for t in threads:
                t.start()
            for i in range(20):
                um.metric("queue_depth", float(i))
            um.flush()
            for t in threads:
                t.join(10)
            with stack.binary_sink() as sink:
                from repro.core import Point, now_ns
                sink.write([Point("binary_m", {"hostname": "h0"},
                                  {"value": float(i)}, now_ns())
                            for i in range(8)])
            stack.findings()                       # synchronous sweep
            import urllib.request
            for path in ("/query?m=hpm&field=step_time_s",
                         "/meta?what=measurements", "/alerts",
                         "/dbs", "/meta?what=persistence"):
                with urllib.request.urlopen(stack.http.url + path,
                                            timeout=10) as resp:
                    assert resp.status == 200
            stack.dashboards.build_dashboard(job)
        stack.backend.snapshot()
        stack.backend.persistence_stats()
        um.close()
    finally:
        stack.close()


def test_stack_dynamic_order_is_subgraph_of_static(tmp_path):
    from repro.analyzer import analyze_paths

    report = analyze_paths([CORE_DIR])
    assert not [f for f in report.by_rule("lock-order")
                if not f.suppressed], \
        "static lock graph must be acyclic before the dynamic check"
    static_edges = set(report.lock_edges)
    site_map = report.lock_sites

    locktrace.reset()
    locktrace.install()
    try:
        _drive_stack(tmp_path)
    finally:
        locktrace.uninstall()
    dyn = locktrace.edges()

    mapped = set()
    for (src, dst), _count in dyn.items():
        a = site_map.get(src)
        b = site_map.get(dst)
        if a is None or b is None or a == b:
            # unmapped: a lock the analyzer does not model (local/
            # non-self); same-node: distinct instances of one class,
            # instance-level ordering the static collapse already
            # treats as a single node
            continue
        mapped.add((a, b))

    assert mapped, "workload failed to exercise any nested core locking"
    extras = mapped - static_edges
    assert not extras, (
        "dynamic lock orders missing from the static graph — teach the "
        f"analyzer or fix the code: {sorted(extras)}")
    # subgraph of an acyclic graph; belt-and-braces on the union
    assert locktrace.find_cycle(mapped | static_edges) is None
