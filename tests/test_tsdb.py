"""Embedded TSDB: write/select/aggregate/retention/persistence."""

import os

from repro.core.line_protocol import Point
from repro.core.tsdb import Database, TSDBServer


def _pts(meas="m", host="h0", n=10, t0=0, dt=1_000_000_000, field="v"):
    return [Point(meas, {"hostname": host}, {field: float(i)}, t0 + i * dt)
            for i in range(n)]


def test_write_select():
    db = Database("test")
    db.write(_pts())
    series = db.select("m", ["v"], {"hostname": "h0"})
    assert len(series) == 1
    assert series[0].values["v"] == [float(i) for i in range(10)]
    assert db.select("m", ["v"], {"hostname": "nope"}) == []


def test_time_range():
    db = Database("test")
    db.write(_pts())
    s = db.select("m", ["v"], t_min=3_000_000_000, t_max=6_000_000_000)[0]
    assert s.values["v"] == [3.0, 4.0, 5.0, 6.0]


def test_out_of_order_insert():
    db = Database("test")
    db.write([Point("m", {"hostname": "h"}, {"v": 2.0}, 200)])
    db.write([Point("m", {"hostname": "h"}, {"v": 1.0}, 100)])
    s = db.select("m", ["v"])[0]
    assert s.times == [100, 200]
    assert s.values["v"] == [1.0, 2.0]


def test_aggregate_group_by_tag():
    db = Database("test")
    db.write(_pts(host="h0") + _pts(host="h1", field="v"))
    out = db.aggregate("m", "v", agg="mean", group_by_tag="hostname")
    assert out == {"h0": 4.5, "h1": 4.5}
    out = db.aggregate("m", "v", agg="max")
    assert out[""] == 9.0


def test_aggregate_windowed():
    db = Database("test")
    db.write(_pts(n=10))
    out = db.aggregate("m", "v", agg="sum", window_ns=5_000_000_000)
    starts, vals = out[""]
    assert vals == [0 + 1 + 2 + 3 + 4, 5 + 6 + 7 + 8 + 9]


def test_events_and_floats_coexist():
    db = Database("test")
    db.write([Point("ev", {"hostname": "h"}, {"event": "start"}, 1),
              Point("ev", {"hostname": "h"}, {"event": "end"}, 2)])
    s = db.select("ev")[0]
    assert s.values["event"] == ["start", "end"]
    # string fields are excluded from numeric aggregation
    assert db.aggregate("ev", "event") == {}


def test_retention():
    db = Database("test")
    db.write(_pts(n=100))
    db.enforce_retention(max_points_per_series=10)
    s = db.select("m")[0]
    assert len(s.times) == 10
    assert s.values["v"][0] == 90.0


def test_field_keys_and_measurements():
    db = Database("test")
    db.write([Point("a", {"hostname": "h"}, {"x": 1.0, "y": 2.0})])
    db.write([Point("b", {"hostname": "h"}, {"z": 1.0})])
    assert db.measurements() == ["a", "b"]
    assert db.field_keys("a") == ["x", "y"]
    assert db.tag_values("a", "hostname") == ["h"]


def test_server_multiple_dbs(tmp_path):
    srv = TSDBServer(persist_dir=str(tmp_path))
    srv.write(_pts(), "global")
    srv.write(_pts(host="h9"), "user_alice")
    assert set(srv.databases()) == {"global", "user_alice"}
    assert srv.db("user_alice").point_count() == 10
    # persistence round-trip (close() seals + flushes the WAL; the
    # crash-without-close paths are covered in test_wal.py)
    srv.close()
    srv2 = TSDBServer(persist_dir=str(tmp_path))
    srv2.load_persisted()
    assert srv2.db("global").point_count() == 10
    assert srv2.db("user_alice").select("m", ["v"],
                                        {"hostname": "h9"})[0].times


def test_sparse_fields_align():
    db = Database("t")
    db.write([Point("m", {"hostname": "h"}, {"a": 1.0}, 1),
              Point("m", {"hostname": "h"}, {"b": 2.0}, 2)])
    s = db.select("m")[0]
    assert s.values["a"] == [1.0, None]
    assert s.values["b"] == [None, 2.0]
