"""Continuous analysis engine (ISSUE 4): alert lifecycle with hysteresis,
streaming == offline parity, persistence into the ``analysis`` measurement,
restart recovery through the WAL, and the HTTP alert/report endpoints.

The parity contract: the window-driven :class:`AnalysisEngine`, the
point-driven :class:`StreamAnalyzer` and the offline evaluators share one
stretch state machine, so on identical data they report byte-identical
episodes — including data gaps (a gap before the recovery sample must not
inflate a violation past ``min_duration_s``) and out-of-order input.
"""

import json
import os
import random
import threading
import urllib.request

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import MonitoringStack
from repro.core.analysis import (AnalysisEngine, StreamAnalyzer,
                                 ThresholdRule, classify_job, default_rules,
                                 evaluate_rule, evaluate_rules_on_db,
                                 load_alerts, load_job_report)
from repro.core.httpd import HttpQueryClient, LMSHttpServer
from repro.core.line_protocol import Point
from repro.core.tsdb import Database, TSDBServer

S = 1_000_000_000

RULE = ThresholdRule("idle", "hpm", "mfu", "<", 0.05, 30.0, "critical",
                     "idle rule", clear_duration_s=20.0)


def _put(db, ts_s, v, host="h0", tags=None):
    t = dict(tags or {})
    t["hostname"] = host
    db.write([Point("hpm", t, {"mfu": v}, int(ts_s * S))])


def _spans(alerts):
    """Comparable episode view: active alerts end at their last violation,
    exactly like the offline evaluator's tail finding."""
    return sorted((a.rule, a.host, a.start_ns,
                   a.end_ns if a.end_ns is not None else a.last_ns)
                  for a in alerts)


def _finding_spans(findings):
    return sorted((f.rule, f.host, f.start_ns, f.end_ns) for f in findings)


# --------------------------------------------------------------------------
# Offline evaluator fixes (satellite: boundary semantics + OOO guard)
# --------------------------------------------------------------------------


def test_evaluate_rule_closes_at_last_violating_sample():
    """Regression: a data gap before the recovery sample used to be counted
    into the violation's duration."""
    rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05, 300.0)
    times = [i * 10 * S for i in range(11)] + [1000 * S]
    values = [0.0] * 11 + [0.9]
    # violations span only 100 s; the seed evaluator closed at 1000 s and
    # reported a 1000 s stretch for a 300 s rule
    assert evaluate_rule(rule, times, values) == []
    short = ThresholdRule("r", "hpm", "mfu", "<", 0.05, 60.0)
    fs = evaluate_rule(short, times, values)
    assert len(fs) == 1
    assert (fs[0].start_ns, fs[0].end_ns) == (0, 100 * S)


def test_evaluate_rule_drops_out_of_order_samples():
    rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05, 150.0)
    # a stale in-range recovery sample arrives after t=100 — it must not
    # reset the open stretch
    times = [0, 100 * S, 50 * S, 200 * S]
    values = [0.0, 0.0, 0.9, 0.0]
    fs = evaluate_rule(rule, times, values)
    assert _finding_spans(fs) == [("r", "", 0, 200 * S)]


def test_evaluate_rule_hysteresis():
    rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05, 30.0,
                         clear_duration_s=20.0)
    times = [i * 10 * S for i in range(12)]
    # flapping: one clear sample inside the hysteresis window does not
    # close the stretch
    values = [0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.9, 0.0, 0.9, 0.9, 0.9, 0.9]
    fs = evaluate_rule(rule, times, values)
    assert _finding_spans(fs) == [("r", "", 0, 70 * S)]


# --------------------------------------------------------------------------
# StreamAnalyzer (point-driven): fixed semantics + thread safety + pruning
# --------------------------------------------------------------------------


def _stream_points(seq, host="h0"):
    return [Point("hpm", {"hostname": host}, {"mfu": v}, int(t))
            for t, v in seq]


def test_stream_analyzer_matches_offline_incl_gaps():
    rng = random.Random(7)
    for _ in range(25):
        n = rng.randint(5, 60)
        t, seq = 0, []
        for _i in range(n):
            t += rng.choice([S, 5 * S, 10 * S, 120 * S])   # gaps included
            seq.append((t, rng.choice([0.0, 0.01, 0.2, 0.9,
                                       float("nan")])))
        rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05,
                             rng.choice([10.0, 30.0, 60.0]),
                             clear_duration_s=rng.choice([0.0, 15.0]))
        an = StreamAnalyzer([rule])
        for p in _stream_points(seq):
            an.observe(p)
        offline = evaluate_rule(rule, [t for t, _ in seq],
                                [v for _, v in seq], "h0")
        assert _spans(an.findings) == _finding_spans(offline), seq


def test_stream_analyzer_out_of_order_matches_monotonic_filter():
    rng = random.Random(11)
    for _ in range(10):
        seq = [(i * 10 * S, rng.choice([0.0, 0.9])) for i in range(40)]
        shuffled = seq[:]
        rng.shuffle(shuffled)
        an = StreamAnalyzer([RULE])
        for p in _stream_points(shuffled):
            an.observe(p)
        # the documented guard: samples older than the per-key clock drop
        kept, last = [], None
        for t, v in shuffled:
            if last is None or t >= last:
                kept.append((t, v))
                last = t
        offline = evaluate_rule(RULE, [t for t, _ in kept],
                                [v for _, v in kept], "h0")
        assert _spans(an.findings) == _finding_spans(offline)


def test_stream_analyzer_concurrent_hosts():
    """Satellite regression: router subscribers run on concurrent ingest
    threads; per-key state must not corrupt."""
    an = StreamAnalyzer([RULE])
    errs = []

    def feed(host):
        try:
            for i in range(200):
                an.observe(Point("hpm", {"hostname": host},
                                 {"mfu": 0.0}, i * 10 * S))
        except Exception as e:      # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=feed, args=(f"h{i}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert sorted(a.host for a in an.findings) == [f"h{i}" for i in range(4)]
    assert all(a.active for a in an.findings)


def test_stream_analyzer_pruned_on_job_end():
    """Satellite regression: per-(rule, host) state leaked forever when a
    host stopped reporting."""
    from repro.core.jobs import JobRegistry
    an = StreamAnalyzer([RULE])
    reg = JobRegistry()
    reg.on_end(an.on_job_end)
    reg.start("j1", "u", ["h0", "h1"])
    for i in range(10):
        an.observe(Point("hpm", {"hostname": "h0"}, {"mfu": 0.0},
                         i * 10 * S))
    assert len(an._keys) == 1 and len(an.findings) == 1
    reg.end("j1")
    assert an._keys == {}
    # the open tail stretch was closed at its last violation
    assert an.findings[0].state == "resolved"
    assert an.findings[0].end_ns == 90 * S


# --------------------------------------------------------------------------
# AnalysisEngine lifecycle (window-driven)
# --------------------------------------------------------------------------


def _engine(rules=None, server=None, **kw):
    server = server or TSDBServer()
    kw.setdefault("auto_tick", False)
    return server, AnalysisEngine(rules or [RULE], backend=server, **kw)


def test_engine_open_extend_resolve():
    server, eng = _engine()
    db = server.db("global")
    for t in range(0, 61, 10):
        _put(db, t, 0.0)
    eng.tick()
    # newest window (60) held back; fired at 30 s, extended to 50 s
    assert len(eng.alerts) == 1
    a = eng.alerts[0]
    assert a.active and a.start_ns == 0 and a.last_ns == 50 * S
    # clear samples inside the hysteresis window keep it firing
    for t in (70, 75):
        _put(db, t, 0.9)
        eng.tick()
    assert a.active and a.last_ns == 60 * S
    # a clear sample past clear_duration_s resolves at the LAST VIOLATION
    _put(db, 95, 0.9)
    _put(db, 100, 0.9)
    eng.tick()
    assert a.state == "resolved"
    assert a.end_ns == 60 * S
    assert a.duration_s == pytest.approx(60.0)
    # ... and the whole lifecycle is persisted + reconstructable
    episodes = load_alerts(db)
    assert _spans(episodes) == _spans([a])
    assert episodes[0].state == "resolved"
    assert load_alerts(db, state="active") == []


def test_engine_hysteresis_prevents_flapping():
    # 30 s violation stretches separated by single 10 s recovery blips
    flappy = [(t, 0.0 if (t // 10) % 4 != 3 else 0.9)
              for t in range(0, 400, 10)]
    spans = {}
    for clear in (0.0, 25.0):
        rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05, 20.0,
                             clear_duration_s=clear)
        server, eng = _engine([rule])
        db = server.db("global")
        for t, v in flappy:
            _put(db, t, v)
        eng.tick(final=True)
        spans[clear] = _spans(eng.alerts)
    # without hysteresis every 10 s dip is its own fire/resolve episode;
    # with it the flapping metric is ONE continuous alert
    assert len(spans[0.0]) > 5
    assert len(spans[25.0]) == 1


def test_engine_matches_offline_rollup_path_seeded():
    """THE acceptance property (seeded fallback): any stream — including
    out-of-order and gapped — final-ticked through the engine reports
    exactly the episodes of the offline rollup-path scan."""
    rng = random.Random(3)
    for case in range(20):
        rules = [ThresholdRule("low", "hpm", "mfu", "<", 0.05,
                               rng.choice([10.0, 30.0]),
                               clear_duration_s=rng.choice([0.0, 15.0])),
                 ThresholdRule("high", "hpm", "mfu", ">", 0.8, 20.0)]
        server, eng = _engine(rules)
        db = server.db("global")
        pts = []
        for host in ("h0", "h1"):
            t = 0
            for _ in range(rng.randint(5, 50)):
                t += rng.choice([1, 2, 10, 90])
                pts.append(Point("hpm", {"hostname": host},
                                 {"mfu": rng.choice(
                                     [0.0, 0.01, 0.2, 0.9, 1.5,
                                      float("nan")])}, t * S))
        rng.shuffle(pts)                        # out-of-order ingest
        i = 0
        while i < len(pts):
            k = rng.randint(1, 16)
            db.write(pts[i:i + k])
            i += k
        eng.tick(final=True)
        offline = evaluate_rules_on_db(db, rules)
        assert _spans(eng.alerts) == _finding_spans(offline), case


def test_engine_incremental_ticks_match_offline():
    """In-order ingest with ticks interleaved at arbitrary points (the
    held-back newest window makes mid-stream evaluation safe) ends at the
    same episodes as one offline scan."""
    rng = random.Random(5)
    for case in range(10):
        server, eng = _engine()
        db = server.db("global")
        seq = []
        t = 0
        for _ in range(rng.randint(20, 80)):
            t += rng.choice([1, 5, 40])
            seq.append((t, rng.choice([0.0, 0.9])))
        for ts, v in seq:
            _put(db, ts, v)
            if rng.random() < 0.3:
                eng.tick()
        eng.tick(final=True)
        offline = evaluate_rules_on_db(db, [RULE])
        assert _spans(eng.alerts) == _finding_spans(offline), case


@pytest.mark.stress
@settings(max_examples=int(os.environ.get("LMS_PROPERTY_EXAMPLES", 30)),
          deadline=None)
@given(st.lists(st.tuples(st.integers(1, 90),
                          st.sampled_from([0.0, 0.01, 0.2, 0.9])),
                min_size=2, max_size=80),
       st.integers(0, 2 ** 32 - 1))
def test_property_engine_equals_offline(deltas, seed):
    rng = random.Random(seed)
    rule = ThresholdRule("r", "hpm", "mfu", "<", 0.05,
                         rng.choice([10.0, 30.0]),
                         clear_duration_s=rng.choice([0.0, 15.0]))
    server = TSDBServer(shards=rng.choice([1, 4]))
    eng = AnalysisEngine([rule], backend=server, auto_tick=False)
    db = server.db("global")
    t = 0
    pts = []
    for dt, v in deltas:
        t += dt
        pts.append(Point("hpm", {"hostname": f"h{rng.randint(0, 1)}"},
                         {"mfu": v}, t * S))
    rng.shuffle(pts)
    db.write(pts)
    eng.tick(final=True)
    offline = evaluate_rules_on_db(db, [rule])
    assert _spans(eng.alerts) == _finding_spans(offline)


def test_engine_discovers_backfilled_series_below_lowwater():
    """Review regression: a series backfilled entirely below the per-rule
    cursor low-water must still be discovered (periodic/final full sweeps)
    — incremental filtering must never hide a host's violations."""
    server, eng = _engine()
    db = server.db("global")
    for t in range(1000, 1300, 10):         # healthy host advances cursor
        _put(db, t, 0.9, host="hA")
    for _ in range(3):
        eng.tick()
    # hB backfills a violating history entirely in the past
    for t in range(0, 200, 10):
        _put(db, t, 0.0, host="hB")
    eng.tick(final=True)
    offline = evaluate_rules_on_db(db, [RULE])
    assert _spans(eng.alerts) == _finding_spans(offline)
    assert any(a.host == "hB" for a in eng.alerts)


def test_flush_discovers_backfill_below_stale_lowwater():
    """Regression: ``flush()`` must always be a full sweep.  A series
    whose windows sit entirely below the per-rule cursor low-water (a
    new job at older timestamps than an already-consumed one) used to
    stay invisible to a synchronous flush unless the tick counter
    happened to land on a FULL_SWEEP_EVERY boundary — the /alerts
    read-your-writes promise was a race against the background ticker."""
    server, eng = _engine()
    db = server.db("global")
    for t in range(1000, 1300, 10):         # advances cursors/low-water
        _put(db, t, 0.9, host="hA")
    eng.tick()                              # tick #0: full sweep
    for t in range(0, 200, 10):             # violations entirely below
        _put(db, t, 0.0, host="hB")
    eng.flush()                             # tick #1: must still be full
    assert any(a.host == "hB" for a in eng.alerts)


def test_restart_report_includes_resolved_history(tmp_path):
    """Review regression: a job's pre-restart resolved episodes must still
    appear in the report written at its (post-restart) end."""
    persist = str(tmp_path / "wal")
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "d1"),
                                      persist_dir=persist)
    stack.router.job_start("j1", "u", ["h0"])
    stack.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.0},
                              t * S) for t in range(0, 120, 10)])
    stack.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.9},
                              t * S) for t in range(120, 220, 10)])
    stack.analysis.flush(final=True)
    assert stack.analysis.resolved_alerts(jobid="j1")
    stack.close()

    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "d2"),
                                       persist_dir=persist)
    stack2.router.job_start("j1", "u", ["h0"])
    stack2.router.job_end("j1")
    report = load_job_report(stack2.backend.db("global"), "j1")
    assert report is not None
    assert any(a["rule"] == "compute_break" and a["state"] == "resolved"
               for a in report["alerts"])
    assert report["status"] == "unhealthy"
    stack2.close()


def test_recovery_writes_report_for_job_ended_while_down(tmp_path):
    persist = str(tmp_path / "wal")
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "d1"),
                                      persist_dir=persist)
    stack.router.job_start("j1", "u", ["h0"])
    stack.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.0},
                              t * S) for t in range(0, 120, 10)])
    stack.analysis.flush()
    stack.backend.write([Point("job_event",
                               {"jobid": "j1", "username": "u"},
                               {"event": "end"}, 130 * S)], "global")
    stack.close()
    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "d2"),
                                       persist_dir=persist)
    report = load_job_report(stack2.backend.db("global"), "j1")
    assert report is not None and report["status"] == "unhealthy"
    stack2.close()


def test_engine_raw_only_database_fallback():
    """Rules keep evaluating (point granularity) on a rollup-disabled DB."""
    server = TSDBServer(rollup_config=None)
    _, eng = _engine(server=server)
    db = server.db("global")
    for t in range(0, 100, 10):
        _put(db, t, 0.0)
    eng.tick()
    offline = evaluate_rules_on_db(db, [RULE], use_rollups=False)
    assert _spans(eng.alerts) == _finding_spans(offline)
    assert len(eng.alerts) == 1


# --------------------------------------------------------------------------
# Job lifecycle through the stack: end hook, pruning, footprint reports
# --------------------------------------------------------------------------


def _run_job(stack, job_id="j1", idle_host=None, steps=40, user="alice"):
    hosts = [f"h{i}" for i in range(4)]
    from repro.core import now_ns
    with stack.job(job_id, user=user, hosts=hosts,
                   tags={"arch": "demo"}) as job:
        agents = [stack.host_agent(h, hlo_flops=5e14, model_flops=4e14,
                                   hlo_bytes=2e11, collective_bytes=1e10,
                                   tokens_per_step=1024) for h in hosts]
        t0 = now_ns()
        for step in range(steps):
            ts = t0 + step * 5 * 10 ** 9
            for a in agents:
                stt = 500.0 if (a.hostname == idle_host and step > 10) \
                    else 5.0
                a.collect_step(step=step, step_time_s=stt,
                               extra_events={"data_wait_s": 0.1}, ts=ts)
    return job


def test_job_end_resolves_prunes_and_reports(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    _run_job(stack, idle_host="h3")
    alerts = stack.findings()
    assert any(a.rule == "compute_break" and a.host == "h3" for a in alerts)
    # job end closed every episode at its last violation and pruned state
    assert all(not a.active for a in alerts)
    stats = stack.analysis.engine_stats()
    assert stats["series_tracked"] == 0 and stats["alerts_active"] == 0
    # footprint report was persisted; the engine serves it back
    report = stack.analysis.job_report("j1")
    assert report is not None and report["running"] is False
    assert report["status"] == "unhealthy"
    assert report["metrics"]["mfu"]["samples"] > 0
    assert report["pattern"]
    assert any(a["rule"] == "compute_break" for a in report["alerts"])
    assert load_job_report(stack.backend.db("global"), "j1") == report
    # sequential reuse of the host in a NEW job starts a fresh episode
    _run_job(stack, job_id="j2", idle_host="h3")
    j2 = [a for a in stack.findings() if a.jobid == "j2"]
    assert any(a.rule == "compute_break" for a in j2)


def test_dashboard_reads_persisted_findings_no_rescan(tmp_path,
                                                      monkeypatch):
    """Acceptance: build_dashboard must not rescan the DB with the rule
    evaluator per render — it reads the engine's persisted findings."""
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    job = _run_job(stack, idle_host="h3")

    def boom(*a, **k):
        raise AssertionError("dashboard re-ran the full-DB rule scan")

    import repro.core.analysis as analysis_mod
    monkeypatch.setattr(analysis_mod, "evaluate_rules_on_db", boom)
    monkeypatch.setattr(analysis_mod, "evaluate_rule", boom)
    dash = stack.dashboards.build_dashboard(job)
    head = dash["dashboard"]["header"]
    assert head["status"] == "unhealthy"
    assert any(a["rule"] == "compute_break" and a["state"] == "resolved"
               for a in head["analysis"])
    # the analysis measurement itself is a header, not an app panel row
    assert not any(r["title"].startswith("app:analysis")
                   for r in dash["dashboard"]["rows"])
    view = stack.dashboards.build_admin_view([job])
    assert view["jobs"][0]["alerts"] >= 1
    assert view["jobs"][0]["status"] == "unhealthy"


# --------------------------------------------------------------------------
# Restart recovery through the WAL
# --------------------------------------------------------------------------


def test_alert_state_survives_restart(tmp_path):
    persist = str(tmp_path / "wal")
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "d1"),
                                      persist_dir=persist)
    stack.router.job_start("j1", "u", ["h0"])
    pts = [Point("hpm", {"hostname": "h0"}, {"mfu": 0.0}, t * S)
           for t in range(0, 120, 10)]
    stack.router.write(pts)
    stack.analysis.flush()
    (a,) = stack.analysis.active_alerts()
    start0 = a.start_ns
    stack.close()

    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "d2"),
                                       persist_dir=persist)
    assert stack2.analysis_recovery["alerts_recovered"] == 1
    (a2,) = stack2.analysis.active_alerts()
    assert a2.active and a2.start_ns == start0 and a2.jobid == "j1"
    # the scheduler replays the allocation; the SAME episode continues —
    # no duplicate re-fire — then resolves at its true last violation
    stack2.router.job_start("j1", "u", ["h0"])
    stack2.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.0},
                               t * S) for t in range(120, 160, 10)])
    stack2.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.9},
                               t * S) for t in range(160, 260, 10)])
    stack2.analysis.flush()
    episodes = load_alerts(stack2.backend.db("global"))
    assert len(episodes) == 1
    assert episodes[0].start_ns == start0
    assert episodes[0].state == "resolved"
    assert episodes[0].end_ns == 150 * S
    stack2.close()


def test_recovery_resolves_alerts_of_dead_jobs(tmp_path):
    persist = str(tmp_path / "wal")
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "d1"),
                                      persist_dir=persist)
    stack.router.job_start("j1", "u", ["h0"])
    stack.router.write([Point("hpm", {"hostname": "h0"}, {"mfu": 0.0},
                              t * S) for t in range(0, 120, 10)])
    stack.analysis.flush()
    assert stack.analysis.active_alerts()
    # the job's end lands in the DB without the engine seeing it (e.g.
    # another instance recorded it while this one was down)
    stack.backend.write([Point("job_event",
                               {"jobid": "j1", "username": "u"},
                               {"event": "end"}, 130 * S)], "global")
    stack.close()

    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "d2"),
                                       persist_dir=persist)
    assert stack2.analysis_recovery["alerts_closed"] == 1
    assert stack2.analysis_recovery["alerts_recovered"] == 0
    assert load_alerts(stack2.backend.db("global"), state="active") == []
    stack2.close()


# --------------------------------------------------------------------------
# HTTP endpoints on a sharded backend (+ remote client surface)
# --------------------------------------------------------------------------


def _get_json(url):
    with urllib.request.urlopen(url) as r:
        return json.loads(r.read())


def test_http_alerts_and_reports_sharded(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path), shards=4)
    _run_job(stack, job_id="jdone", idle_host="h3")       # ended, resolved
    # a second job still running with an active violation
    stack.router.job_start("jlive", "bob", ["g0"])
    stack.router.write([Point("hpm", {"hostname": "g0"}, {"mfu": 0.0},
                              t * S) for t in range(0, 120, 10)])
    with LMSHttpServer(stack.router) as srv:
        alerts = _get_json(f"{srv.url}/alerts")["alerts"]
        assert {a["jobid"] for a in alerts} >= {"jdone", "jlive"}
        active = _get_json(f"{srv.url}/alerts?state=active")["alerts"]
        assert {a["jobid"] for a in active} == {"jlive"}
        assert all(a["state"] == "firing" for a in active)
        done = _get_json(f"{srv.url}/alerts?jobid=jdone")["alerts"]
        assert done and all(a["state"] == "resolved" for a in done)
        # reports: persisted for the ended job, live for the running one
        rep = _get_json(f"{srv.url}/jobs/jdone/report")["report"]
        assert rep["running"] is False and rep["status"] == "unhealthy"
        live = _get_json(f"{srv.url}/jobs/jlive/report")["report"]
        assert live["running"] is True
        assert any(a["rule"] == "compute_break" for a in live["alerts"])
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{srv.url}/jobs/nope/report")
        assert ei.value.code == 404
        # engine counters over /meta
        stats = _get_json(f"{srv.url}/meta?what=analysis")["analysis"]
        assert stats["alerts_fired"] >= 2
        # remote client surface + federation-by-concatenation (persisted
        # last_ns lags live state by up to the extend-persist interval,
        # so compare episode identity, not the moving edge)
        client = HttpQueryClient(srv.url)
        remote = client.alerts(state="active")
        assert sorted((a.rule, a.host, a.jobid, a.start_ns)
                      for a in remote) == \
            sorted((a.rule, a.host, a.jobid, a.start_ns)
                   for a in stack.analysis.active_alerts())
        assert client.job_report("jdone")["pattern"] == rep["pattern"]
        assert client.job_report("nope") is None
        # load_alerts works over the Database-shaped remote view too
        local = load_alerts(stack.backend.db("global"), jobid="jdone")
        assert _spans(load_alerts(client, jobid="jdone")) == _spans(local)
    stack.close()
