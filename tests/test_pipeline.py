"""Pipeline parallelism: pipelined == sequential, on an 8-device host mesh
(subprocess-isolated like test_multidevice)."""

import os
import subprocess
import sys
import textwrap

from conftest import needs_partial_manual_shard_map

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply, bubble_fraction

        mesh = jax.make_mesh((4,), ("pipe",))
        S, B, D = 4, 8, 16
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

        def stage(w, xb):
            return jnp.tanh(xb @ w)

        got = pipeline_apply(stage, ws, x, mesh=mesh, num_microbatches=4)

        want = x
        for s in range(S):
            want = stage(ws[s], want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9

        # collective-permute must appear in the lowered HLO (neighbor links)
        txt = jax.jit(lambda w, x: pipeline_apply(
            stage, w, x, mesh=mesh, num_microbatches=4)
        ).lower(ws, x).compile().as_text()
        assert "collective-permute" in txt
        print("PIPELINE OK")
    """)
    assert "PIPELINE OK" in out


@needs_partial_manual_shard_map
def test_pipeline_composes_with_data_axis():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.pipeline import pipeline_apply

        mesh = jax.make_mesh((2, 4), ("data", "pipe"))
        S, B, D = 4, 8, 16
        rng = np.random.default_rng(1)
        ws = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

        def stage(w, xb):
            return jnp.tanh(xb @ w)

        f = jax.jit(lambda w, x: pipeline_apply(
            stage, w, x, mesh=mesh, num_microbatches=2))
        with mesh:
            got = f(ws, x)
        want = x
        for s in range(S):
            want = stage(ws[s], want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE+DATA OK")
    """)
    assert "PIPELINE+DATA OK" in out
