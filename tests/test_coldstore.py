"""Compressed columnar cold tier: codec exactness + tier parity + crash.

The contract of ``repro.core.coldstore``: sealing expired raw history
into compressed chunks must be *invisible* to every query — the same
``QuerySpec`` (and every select/aggregate) answers byte-identically
against a sealed hot+rollup+cold database and an uncompacted reference,
locally, sharded (counts 1-8) and HTTP-federated, including ranges that
straddle the seal point.  The chunk codec round-trips bit-exactly
(NaN payloads, ±inf, -0.0, big ints, counter resets, duplicate
timestamps), and corrupted chunks are detected and skipped — never
wrong data.

Tiers: fast unit tests (including the seeded codec properties);
hypothesis variants run wherever hypothesis is installed; ``-m crash``
SIGKILLs a writer mid-seal and checks recovery observes either the
retained raw segment or the sealed chunk — never both (double-count),
never neither (loss) — bounded by ``LMS_CRASH_ITERS``.
"""

import json
import os
import random
import signal
import struct
import subprocess
import sys
import time
import urllib.request

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import coldstore
from repro.core.coldstore import (ColdStore, decode_floats, decode_ints,
                                  decode_series_block, encode_floats,
                                  encode_ints, encode_series_block)
from repro.core.httpd import HttpQueryClient, LMSHttpServer
from repro.core.line_protocol import Point, now_ns
from repro.core.query import QueryEngine, QuerySpec, make_plan, plan_tiers
from repro.core.router import MetricsRouter
from repro.core.rollup import ROLLUP_AGGS
from repro.core.shard import FederatedQuery
from repro.core.tsdb import Database, TSDBServer, _tags_key

S = 1_000_000_000


def _bits(x: float) -> bytes:
    return struct.pack("<d", x)


def _col_bits(col):
    return [_bits(v) if isinstance(v, float) else v for v in col]


# --------------------------------------------------------------------------
# codec: property round-trips (hypothesis where available + seeded always)
# --------------------------------------------------------------------------


_SPECIAL_FLOATS = [
    float("nan"), float("inf"), float("-inf"), -0.0, 0.0, 1e308, -1e-308,
    5e-324,                                      # smallest subnormal
    struct.unpack("<d", struct.pack("<Q", 0x7FF8DEADBEEF0001))[0],  # NaN
    struct.unpack("<d", struct.pack("<Q", 0xFFF0000000000001))[0],  # -NaN
]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=-(2 ** 70), max_value=2 ** 70),
                min_size=1, max_size=120))
def test_property_int_codec_roundtrip(vals):
    """Delta-of-delta varints are exact for ANY ints: int64 range, far
    beyond it, negatives (counter resets), duplicates, any order."""
    assert decode_ints(encode_ints(vals), len(vals)) == vals


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(allow_nan=True, allow_infinity=True),
                min_size=1, max_size=120))
def test_property_float_codec_roundtrip(vals):
    """Gorilla XOR is bit-exact for ANY float64s, NaN included."""
    got = decode_floats(encode_floats(vals), len(vals))
    assert [_bits(v) for v in got] == [_bits(v) for v in vals]


def test_seeded_codec_roundtrip():
    """Seeded twin of the codec properties — runs on minimal images
    where hypothesis is not installed."""
    rng = random.Random(0xC01D)
    int_pool = [0, 1, -1, 2 ** 63 - 1, -(2 ** 63), 2 ** 70, 10 ** 18]
    for _ in range(200):
        n = rng.randrange(1, 100)
        ivals = [rng.choice(int_pool) + rng.randrange(-3, 4)
                 for _ in range(n)]
        assert decode_ints(encode_ints(ivals), n) == ivals
        fvals = [rng.choice(_SPECIAL_FLOATS) if rng.random() < 0.3
                 else rng.choice([rng.uniform(-1e6, 1e6),
                                  float(rng.randrange(1000)),
                                  rng.random() * 10 ** rng.randrange(-30, 30)])
                 for _ in range(n)]
        got = decode_floats(encode_floats(fvals), n)
        assert [_bits(v) for v in got] == [_bits(v) for v in fvals]


def test_counter_reset_and_duplicate_timestamps():
    """The shapes real monitoring data throws at the timestamp codec:
    regular cadence, duplicates, counter resets (big negative deltas),
    and out-of-order stragglers — all exact."""
    streams = [
        [S * i for i in range(500)],                    # regular cadence
        [5, 5, 5, 7, 7, 100, 100],                      # duplicates
        [2 ** 62, 10, 2 ** 62, 11],                     # counter reset
        [100, 50, 200, 1, 300],                         # out of order
        [0],
        [-(10 ** 18), 10 ** 18],
    ]
    for ts in streams:
        assert decode_ints(encode_ints(ts), len(ts)) == ts


def test_series_block_roundtrip_all_column_kinds():
    """One block exercising every codec path: dense float ("g"), dense
    int ("d"), float/int with None holes ("gh"/"dh"), and the JSON
    fallback ("j") for strings/bools/mixed — values and hole positions
    exact, float bit patterns preserved."""
    times = [3, 5, 5, 7, 100]
    cols = {
        "f": [1.5, float("nan"), -0.0, float("inf"), 2.0],
        "i": [1, -(2 ** 70), 0, 2 ** 70, 5],
        "fh": [0.25, None, None, -0.5, None],
        "ih": [None, 7, None, -9, 10 ** 18],
        "s": ["a", None, "c", True, 1.5],
    }
    m, tags, t2, c2 = decode_series_block(
        encode_series_block("m", {"host": "h1"}, times, cols))
    assert (m, tags, t2) == ("m", {"host": "h1"}, times)
    assert set(c2) == set(cols)
    for k in cols:
        assert _col_bits(c2[k]) == _col_bits(cols[k]), k


def test_chunk_corruption_detected_never_wrong_data(tmp_path):
    """Fuzz a sealed chunk with single-byte flips and truncations at
    every region (magic, block data, index, trailer): every fragment
    that IS returned is bit-exact, anything unreadable is skipped and
    counted — wrong data is never returned."""
    rng = random.Random(7)
    d = str(tmp_path / "cold")
    store = ColdStore(d)
    entries = []
    for h in range(3):
        times = [i * S for i in range(50)]
        entries.append(("m", {"host": f"h{h}"}, times,
                        {"v": [float(h) + 0.25 * i for i in range(50)],
                         "n": list(range(h, 50 + h))}))
    store.append_chunk(entries)
    path = store._chunks[1].path
    good = bytearray(open(path, "rb").read())
    view = store.make_view()
    ref = {frag[0]: (frag[2], frag[3])
           for frag in view.fragments("m", None, None, None, None)}
    assert len(ref) == 3

    def check(data):
        with open(path, "wb") as f:
            f.write(bytes(data))
        s2 = ColdStore(d)
        v2 = s2.make_view()
        got = {frag[0]: (frag[2], frag[3])
               for frag in v2.fragments("m", None, None, None, None)}
        for key, (times, vals) in got.items():
            assert times == ref[key][0]
            for k in vals:
                assert _col_bits(vals[k]) == _col_bits(ref[key][1][k])
        if len(got) < len(ref):
            assert s2.corrupt_blocks or s2.skipped_chunks

    for _ in range(40):                      # random single-byte flips
        i = rng.randrange(len(good))
        data = bytearray(good)
        data[i] ^= 1 << rng.randrange(8)
        check(data)
    for _ in range(15):                      # torn writes
        check(good[:rng.randrange(len(good))])
    with open(path, "wb") as f:              # restore for sanity
        f.write(bytes(good))
    assert len(ColdStore(d).make_view().fragments(
        "m", None, None, None, None)) == 3


# --------------------------------------------------------------------------
# tier parity: sealed hot+rollup+cold == uncompacted reference
# --------------------------------------------------------------------------


def _dataset(now):
    """~1h of 4-host metrics ending now.  Binary-fraction values keep
    every partial sum exactly representable, so shard/federation merge
    order cannot perturb float results and byte-identical comparisons
    hold.  Fields cover float ("v"/"w" with holes), int ("n") and string
    ("note") columns; a few duplicate timestamps exercise stable order."""
    pts = []
    t0 = now - 3600 * S
    for i in range(240):
        t = t0 + i * 15 * S
        for h in range(4):
            fields = {"v": float((h + 1) * 2 ** 20) + 0.25 * (i % 8),
                      "n": i * (h + 1)}
            if i % 3 == 0:
                fields["w"] = float(i % 16) / 4.0
            if i % 7 == 0:
                fields["note"] = f"evt{i}"
            pts.append(Point("hpm", {"hostname": f"h{h}",
                                     "jobid": f"j{h % 2}"}, fields, t))
        if i % 11 == 0:     # duplicate timestamp, later arrival
            pts.append(Point("hpm", {"hostname": "h0", "jobid": "j0"},
                             {"v": 0.5}, t))
    return pts


def _series_map(series_list):
    out = {}
    for s in series_list:
        key = _tags_key(s.tags)
        assert key not in out
        out[key] = (s.times, s.values)
    return out


def _assert_db_parity(got, ref, seal_t, meas="hpm"):
    """Every query surface answers identically, including ranges that
    straddle the seal point ``seal_t``."""
    assert got.measurements() == ref.measurements()
    assert got.field_keys(meas) == ref.field_keys(meas)
    assert got.tag_values(meas, "hostname") == ref.tag_values(meas,
                                                              "hostname")
    assert got.stored_points() == ref.stored_points()
    ranges = [(None, None),
              (seal_t - 600 * S, seal_t + 600 * S),     # straddles seal
              (seal_t, seal_t),                          # exact boundary
              (None, seal_t - 1),                        # all-cold
              (seal_t + 1, None)]                        # all-hot
    for t_min, t_max in ranges:
        assert _series_map(got.select(meas, None, None, t_min, t_max)) \
            == _series_map(ref.select(meas, None, None, t_min, t_max))
    assert _series_map(got.select(meas, ["v"], {"jobid": "j1"})) \
        == _series_map(ref.select(meas, ["v"], {"jobid": "j1"}))
    for agg in ROLLUP_AGGS:
        assert got.aggregate(meas, "v", agg=agg,
                             group_by_tag="hostname") == \
            ref.aggregate(meas, "v", agg=agg, group_by_tag="hostname")
        for use in (False, "auto"):
            assert got.aggregate(meas, "v", agg=agg, window_ns=60 * S,
                                 use_rollups=use) == \
                ref.aggregate(meas, "v", agg=agg, window_ns=60 * S,
                              use_rollups=use), (agg, use)
        assert got.aggregate(meas, "n", agg=agg, window_ns=90 * S,
                             t_min=seal_t - 450 * S, t_max=seal_t + 450 * S,
                             use_rollups=False) == \
            ref.aggregate(meas, "n", agg=agg, window_ns=90 * S,
                          t_min=seal_t - 450 * S, t_max=seal_t + 450 * S,
                          use_rollups=False)


def _specs(now):
    seal_t = now - 1800 * S
    return [
        QuerySpec("hpm", ("v", "w"), window_ns=10 * S,
                  group_by="hostname"),                        # rollup plan
        QuerySpec("hpm", ("v",), window_ns=int(1.5 * S),
                  group_by="jobid"),                           # raw plan
        QuerySpec("hpm", ("r=v / 4",), window_ns=int(7.5 * S),
                  group_by="hostname", t_min=seal_t - 900 * S,
                  t_max=seal_t + 900 * S),                     # straddling
        QuerySpec("hpm", ("v",), group_by="jobid"),            # scalar
        QuerySpec("hpm", ("v",), window_ns=int(1.5 * S),
                  t_max=seal_t - 60 * S),                      # all-cold
    ]


def test_sealed_equals_uncompacted_local(tmp_path):
    """The tentpole contract, locally: seal half the data into the cold
    tier; every select/aggregate/QuerySpec answers byte-identically to
    an uncompacted reference, before and after recovery."""
    now = now_ns()
    pts = _dataset(now)
    seal_t = now - 1800 * S
    ref = Database("ref")
    ref.write(pts)
    srv = TSDBServer(persist_dir=str(tmp_path / "db"), cold=True)
    srv.write(pts)
    report = srv.enforce_retention(max_age_ns=1800 * S)
    assert report["global"]["points_sealed"] > 0
    assert report["global"]["raw_points_dropped"] == 0      # moved, not lost
    st_ = srv.store().stats()
    assert st_["cold"]["chunks"] == 1
    assert st_["cold"]["corrupt_blocks"] == 0
    _assert_db_parity(srv.db(), ref, seal_t)
    for spec in _specs(now):
        a = QueryEngine(ref).query(spec)
        b = QueryEngine(srv.db()).query(spec)
        assert a.to_json() == b.to_json(), spec.metrics
    # a second sweep with nothing newly expired seals nothing more
    again = srv.enforce_retention(max_age_ns=1800 * S)
    assert again["global"]["points_sealed"] == 0
    _assert_db_parity(srv.db(), ref, seal_t)
    # recovery: chunks + snapshot + WAL reproduce the same answers
    srv.close()
    rec = TSDBServer(persist_dir=str(tmp_path / "db"), cold=True)
    stats = rec.load_persisted()
    assert stats["global"]["cold_chunks"] == 1
    assert stats["global"].get("cold_orphans_dropped", 0) == 0
    _assert_db_parity(rec.db(), ref, seal_t)
    for spec in _specs(now):
        assert QueryEngine(ref).query(spec).to_json() == \
            QueryEngine(rec.db()).query(spec).to_json()
    rec.close()


@pytest.mark.parametrize("shards", [1, 2, 3, 5, 8])
def test_sealed_equals_uncompacted_sharded(tmp_path, shards):
    """Sharded: per-shard cold views (stable crc32 series hash) answer
    like one uncompacted database for every shard count."""
    now = now_ns()
    pts = _dataset(now)
    seal_t = now - 1800 * S
    ref = Database("ref")
    ref.write(pts)
    srv = TSDBServer(persist_dir=str(tmp_path / "db"), cold=True,
                     shards=shards)
    srv.write(pts)
    srv.enforce_retention(max_age_ns=1800 * S)
    _assert_db_parity(srv.db(), ref, seal_t)
    for spec in _specs(now):
        assert QueryEngine(ref).query(spec).to_json() == \
            QueryEngine(srv.db()).query(spec).to_json(), spec.metrics
    srv.close()
    # recover into a DIFFERENT shard count: views re-filter by the
    # current hash, every sealed series served by exactly one shard
    other = 3 if shards != 3 else 4
    rec = TSDBServer(persist_dir=str(tmp_path / "db"), cold=True,
                     shards=other)
    rec.load_persisted()
    _assert_db_parity(rec.db(), ref, seal_t)
    rec.close()


def test_sealed_equals_uncompacted_http_federated(tmp_path):
    """Two sealed LMS instances behind /query/v2 pushdown answer like
    one uncompacted local database holding the union."""
    now = now_ns()
    pts = _dataset(now)
    ref = Database("ref")
    ref.write(pts)
    routers = []
    for i in range(2):
        srv = TSDBServer(persist_dir=str(tmp_path / f"i{i}"), cold=True,
                         shards=2)
        routers.append(MetricsRouter(srv))
    for p in pts:       # each host's series lives on exactly one instance
        routers[int(p.tags["hostname"][1:]) % 2].backend.write([p])
    for r in routers:
        r.backend.enforce_retention(max_age_ns=1800 * S)
        assert r.backend.store().stats()["cold"]["chunks"] >= 1
    with LMSHttpServer(routers[0]) as sa, LMSHttpServer(routers[1]) as sb:
        fed = FederatedQuery([HttpQueryClient(sa.url),
                              HttpQueryClient(sb.url)])
        eng = QueryEngine(fed)
        for spec in _specs(now):
            assert QueryEngine(ref).query(spec).to_json() == \
                eng.query(spec).to_json(), spec.metrics
        # /meta?what=cold surfaces the sealed tier remotely
        meta = json.loads(urllib.request.urlopen(
            f"{sa.url}/meta?what=cold").read())["cold"]
        assert meta["chunks"] >= 1 and meta["points"] > 0
        assert meta["compression_ratio"] > 1.0
        assert meta["time_range"][0] <= meta["time_range"][1]
    for r in routers:
        r.backend.close()


def test_seal_bumps_watermark_and_planner_reports_cold(tmp_path):
    """Sealing must invalidate the watermark-keyed result cache (the
    data moved tiers) and the planner must report the tiers a raw plan
    spans — ["hot", "cold"] once the range straddles the seal."""
    now = now_ns()
    srv = TSDBServer(persist_dir=str(tmp_path / "db"), cold=True)
    srv.write(_dataset(now))
    db = srv.db()
    spec = _specs(now)[1]                      # raw plan, full range
    eng = QueryEngine(db)
    before = eng.query(spec)
    assert eng.query(spec) is before           # cached
    assert before.meta["tiers"] == ["hot"]
    v0 = db.data_version("hpm")
    srv.enforce_retention(max_age_ns=1800 * S)
    assert db.data_version("hpm") != v0        # seal moved data
    after = eng.query(spec)
    assert after is not before                 # cache invalidated...
    assert after.to_json() == before.to_json()  # ...same bytes
    assert after.meta["tiers"] == ["hot", "cold"]
    # rollup-served plans never touch the cold tier
    roll = eng.query(_specs(now)[0])
    assert roll.meta["tiers"] == ["rollup"]
    # plan_tiers is pure planner metadata — consistent with the range
    cold_only = make_plan(_specs(now)[4], db.rollup_config)
    assert plan_tiers(cold_only, db) == ["hot", "cold"]
    assert db.cold_time_range("hpm") is not None
    srv.close()


def test_orphan_chunk_dropped_on_recovery(tmp_path):
    """A chunk present on disk but never committed by a snapshot (crash
    between chunk write and snapshot rename) is dropped at recovery —
    its points are still in the snapshot/WAL, so keeping it would
    double-count."""
    now = now_ns()
    pts = _dataset(now)
    ref = Database("ref")
    ref.write(pts)
    d = str(tmp_path / "db")
    srv = TSDBServer(persist_dir=d, cold=True)
    srv.write(pts)
    srv.enforce_retention(max_age_ns=1800 * S)
    srv.close()
    # simulate the crash window: an extra chunk no snapshot committed
    orphan = ColdStore(os.path.join(d, "global", "cold"))
    orphan.append_chunk([("hpm", {"hostname": "h0", "jobid": "j0"},
                          [now - 10 * S], {"v": [123.0]})])
    rec = TSDBServer(persist_dir=d, cold=True)
    stats = rec.load_persisted()
    assert stats["global"]["cold_orphans_dropped"] == 1
    _assert_db_parity(rec.db(), ref, now - 1800 * S)
    rec.close()


# --------------------------------------------------------------------------
# retention reporting (the silent-data-loss fix) — with and without cold
# --------------------------------------------------------------------------


def test_retention_reports_drops_without_cold(tmp_path):
    """``enforce_retention(max_age_ns)`` with NO cold tier still drops —
    but now reports what it dropped, both in its return value and
    cumulatively in ``persistence_stats()`` (callers could previously
    not tell retention ran at all)."""
    now = now_ns()
    srv = TSDBServer(persist_dir=str(tmp_path / "db"))     # cold OFF
    srv.write(_dataset(now))
    before = srv.db().stored_points()
    report = srv.enforce_retention(max_age_ns=1800 * S)
    dropped = report["global"]["raw_points_dropped"]
    assert dropped > 0
    assert report["global"]["points_sealed"] == 0
    assert srv.db().stored_points() == before - dropped
    ps = srv.persistence_stats()["databases"]["global"]["retention"]
    assert ps["raw_points_dropped"] == dropped
    assert ps["sweeps"] == 1 and ps["seals"] == 0
    assert "cold" not in srv.persistence_stats()["databases"]["global"]
    srv.close()
    # the in-memory Database reports the same shape
    db = Database("mem")
    db.write(_dataset(now))
    r = db.enforce_retention(max_age_ns=1800 * S)
    assert r["raw_points_dropped"] == dropped
    # and a sweep that finds nothing is explicit about it
    assert db.enforce_retention(max_age_ns=3 * 3600 * S) == \
        {"raw_points_dropped": 0, "rollup_windows_dropped": 0}


def test_cold_requires_persist_dir():
    with pytest.raises(ValueError):
        TSDBServer(cold=True)


# --------------------------------------------------------------------------
# crash tier: SIGKILL mid-seal (ci_check.sh step 4)
# --------------------------------------------------------------------------

_SEAL_CRASH_WRITER = r"""
import sys, time
sys.path.insert(0, {src!r})
from repro.core.line_protocol import Point
from repro.core.tsdb import TSDBServer

srv = TSDBServer(persist_dir={d!r}, shards={shards}, fsync="batch",
                 cold=True)
srv.load_persisted()
b = 0
print("READY", flush=True)
while True:
    # whole batches of 50 -> recovered counts are multiples of 50; the
    # ancient timestamps make every resident point sealable, so the
    # frequent retention sweeps keep a seal in flight for the SIGKILL
    srv.write([Point("m", {{"hostname": f"h{{b % 4}}"}},
                     {{"v": float(b * 50 + i)}},
                     (b * 50 + i) * 10**6) for i in range(50)])
    b += 1
    if b % 5 == 0:
        srv.enforce_retention(max_age_ns=10**9)
"""


@pytest.mark.crash
@pytest.mark.parametrize("shards", [1, 4])
def test_sigkill_mid_seal_recovers(tmp_path, shards):
    """Kill -9 a writer whose retention sweeps continuously seal, then
    recover: every point is observed exactly once — in the retained raw
    tier or the sealed chunk, never both (stored == written, no
    double-count) and never neither (no loss); recovery never raises
    and is deterministic.  Bounded by LMS_CRASH_ITERS."""
    iters = int(os.environ.get("LMS_CRASH_ITERS", "3"))
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    d = str(tmp_path / "wal")
    rng = random.Random(100 + shards)
    for it in range(iters):
        proc = subprocess.Popen(
            [sys.executable, "-c",
             _SEAL_CRASH_WRITER.format(src=os.path.abspath(src), d=d,
                                       shards=shards)],
            stdout=subprocess.PIPE)
        assert proc.stdout.readline().strip() == b"READY"
        time.sleep(rng.uniform(0.05, 0.4))
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        rec = TSDBServer(persist_dir=d, shards=shards, cold=True)
        rec.load_persisted()
        db = rec.db("global")
        n = db.point_count()
        assert n % 50 == 0                   # whole records only
        # THE seal-crash invariant: raw-or-sealed, exactly once
        assert db.stored_points() == n
        if n:
            out = db.aggregate("m", "v", agg="count",
                               group_by_tag="hostname")
            assert sum(out.values()) == float(n)
            assert all(c % 50 == 0 for c in out.values())
        sums = db.aggregate("m", "v", agg="sum", group_by_tag="hostname")
        rec.close()
        # deterministic: a second recovery agrees
        rec2 = TSDBServer(persist_dir=d, shards=shards, cold=True)
        rec2.load_persisted()
        assert rec2.db("global").point_count() == n
        assert rec2.db("global").stored_points() == n
        assert rec2.db("global").aggregate(
            "m", "v", agg="sum", group_by_tag="hostname") == sums
        if it % 2 == 0:      # exercise snapshot+replay recovery too
            rec2.snapshot()
        rec2.close()
