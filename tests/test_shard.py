"""Sharded TSDB: sharded == unsharded for every query, under any stream.

The contract of ``repro.core.shard``: a ``ShardedDatabase`` fed any point
stream answers every query (``select``, scalar and windowed ``aggregate``,
rollup-served post-retention windows) identically to a single unsharded
``Database`` fed the same stream — for any shard count, batch split,
out-of-order timestamps and sparse/non-numeric fields.  Plus the
concurrency stress tier (``-m stress``): parallel batched writers, query
threads and a retention reaper against the sharded store with monotonic
router counters and no lost points.
"""

import os
import random
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.line_protocol import Point, encode_batch
from repro.core.rollup import ROLLUP_AGGS
from repro.core.router import MetricsRouter
from repro.core.shard import ShardedDatabase, shard_index
from repro.core.tsdb import Database, TSDBServer, _tags_key

S = 1_000_000_000
WINDOWS = (S, 10 * S, 60 * S, 120 * S)


def _random_stream(rng, n, hosts=4, t_span_s=300):
    """Out-of-order, sparse-fielded stream with non-numeric noise."""
    pts = []
    for _ in range(n):
        fields = {}
        if rng.random() < 0.9:
            fields["v"] = rng.uniform(-100, 100)
        if rng.random() < 0.25:
            fields["w"] = float(rng.randint(-5, 5))
        if rng.random() < 0.1:
            fields["note"] = "evt"        # strings never aggregate
        if rng.random() < 0.1:
            fields["flag"] = True         # bools never aggregate
        if not fields:
            fields["v"] = 1.0
        pts.append(Point("m", {"hostname": f"h{rng.randrange(hosts)}"},
                         fields, rng.randrange(t_span_s * S)))
    return pts


def _write_in_batches(db, pts, rng):
    i = 0
    while i < len(pts):
        k = rng.randint(1, 64)
        db.write(pts[i:i + k])
        i += k


def _series_map(series_list):
    """tags-key -> (times, values); series keys are unique per database
    *and* per sharded database (a key lives on exactly one shard)."""
    out = {}
    for s in series_list:
        key = _tags_key(s.tags)
        assert key not in out, "duplicate series key across shards"
        out[key] = (s.times, s.values)
    return out


def _assert_windows_equal(sharded, reference):
    assert set(sharded) == set(reference)
    for g in reference:
        assert sharded[g][0] == reference[g][0], g
        assert sharded[g][1] == pytest.approx(reference[g][1],
                                              rel=1e-9, abs=1e-9)


def _assert_equivalent(sh, ref):
    """Full query-surface equivalence between a ShardedDatabase and a
    reference Database holding the same points."""
    assert sh.point_count() == ref.point_count()
    assert sh.stored_points() == ref.stored_points()
    assert sh.measurements() == ref.measurements()
    assert sh.field_keys("m") == ref.field_keys("m")
    assert sh.tag_values("m", "hostname") == ref.tag_values("m", "hostname")
    assert _series_map(sh.select("m")) == _series_map(ref.select("m"))
    # range-bounded select
    assert _series_map(sh.select("m", ["v"], None, 50 * S, 200 * S)) == \
        _series_map(ref.select("m", ["v"], None, 50 * S, 200 * S))
    for agg in ROLLUP_AGGS:
        for group_by in (None, "hostname"):
            scalar = sh.aggregate("m", "v", agg=agg, group_by_tag=group_by)
            want = ref.aggregate("m", "v", agg=agg, group_by_tag=group_by)
            assert set(scalar) == set(want), (agg, group_by)
            for g in want:
                assert scalar[g] == pytest.approx(want[g], rel=1e-9,
                                                  abs=1e-9), (agg, group_by)
            for window in WINDOWS:
                _assert_windows_equal(
                    sh.aggregate("m", "v", agg=agg, window_ns=window,
                                 group_by_tag=group_by),
                    ref.aggregate("m", "v", agg=agg, window_ns=window,
                                  group_by_tag=group_by))


@pytest.mark.parametrize("shards", list(range(1, 9)))
def test_sharded_equals_unsharded(shards):
    rng = random.Random(shards)
    pts = _random_stream(rng, 1500)
    ref = Database("ref")
    sh = ShardedDatabase("s", shards=shards)
    _write_in_batches(ref, pts, random.Random(99))
    _write_in_batches(sh, pts, random.Random(7))    # different batch splits
    _assert_equivalent(sh, ref)


@pytest.mark.parametrize("shards", [1, 3, 8])
def test_sharded_rollups_survive_retention(shards):
    """Post-retention, rollup-served windows still merge exactly across
    shards (each shard trims and rolls up independently)."""
    rng = random.Random(shards + 100)
    pts = _random_stream(rng, 2000, hosts=3)
    ref = Database("ref")
    sh = ShardedDatabase("s", shards=shards)
    ref.write(pts)
    _write_in_batches(sh, pts, rng)
    ref.enforce_retention(max_points_per_series=4)
    sh.enforce_retention(max_points_per_series=4)
    assert sh.stored_points() == ref.stored_points()
    for agg in ROLLUP_AGGS:
        for window in (10 * S, 60 * S):
            _assert_windows_equal(
                sh.aggregate("m", "v", agg=agg, window_ns=window,
                             group_by_tag="hostname", use_rollups=True),
                ref.aggregate("m", "v", agg=agg, window_ns=window,
                              group_by_tag="hostname", use_rollups=True))
    # rollup_series federates by concatenation: one rollup view per series
    assert len(sh.rollup_series("m", "v")) == len(ref.rollup_series("m", "v"))
    assert sh.rollup_window_count("m", "v") == ref.rollup_window_count(
        "m", "v")


def test_sharded_aggregate_partials_nest():
    """A ShardedDatabase's merged partials are themselves mergeable —
    federations nest (shards inside instances inside deployments)."""
    from repro.core.shard import FederatedQuery
    rng = random.Random(5)
    pts = _random_stream(rng, 800)
    half = len(pts) // 2
    a = ShardedDatabase("a", shards=3)
    b = ShardedDatabase("b", shards=2)
    a.write(pts[:half])
    b.write(pts[half:])
    ref = Database("ref")
    ref.write(pts)
    fed = FederatedQuery([a, b])
    for agg in ("mean", "count", "last"):
        got = fed.aggregate("m", "v", agg=agg, group_by_tag="hostname")
        want = ref.aggregate("m", "v", agg=agg, group_by_tag="hostname")
        assert set(got) == set(want)
        for g in want:
            assert got[g] == pytest.approx(want[g], rel=1e-9, abs=1e-9)
    _assert_windows_equal(
        fed.aggregate("m", "v", agg="sum", window_ns=10 * S),
        ref.aggregate("m", "v", agg="sum", window_ns=10 * S))


def test_federated_view_is_rollup_aware():
    """A FederatedQuery view exposes rollup_config, so rule evaluation
    and dashboards stay on the rollup-served path (and keep answering
    after raw retention) instead of silently degrading to truncated raw
    data (regression: the view used to hide the backends' rollups)."""
    from repro.core.analysis import default_rules, evaluate_rules_on_db
    from repro.core.shard import FederatedQuery
    a = ShardedDatabase("a", shards=2)
    b = Database("b")
    # mfu pinned below the compute_break floor for > the rule timeout
    pts = [Point("hpm", {"hostname": f"h{i % 2}"}, {"mfu": 0.001}, i * S)
           for i in range(200)]
    a.write([p for p in pts if p.tags["hostname"] == "h0"])
    b.write([p for p in pts if p.tags["hostname"] == "h1"])
    fed = FederatedQuery([a, b])
    assert fed.rollup_config is not None
    for db in (a, b):
        db.enforce_retention(max_points_per_series=2)
    # forced rollups must NOT raise "rollups disabled", and findings
    # span the full (retention-dropped) history on both backends
    findings = evaluate_rules_on_db(fed, default_rules(), use_rollups=True)
    hosts = {f.host for f in findings if f.rule == "compute_break"}
    assert hosts == {"h0", "h1"}
    assert all(f.duration_s > 60 for f in findings
               if f.rule == "compute_break")


def test_shard_index_stable_and_total():
    """crc32 routing: deterministic across processes, every key routed."""
    key = _tags_key({"hostname": "h1", "jobid": "j"})
    assert shard_index("m", key, 4) == shard_index("m", key, 4)
    idx = {shard_index("m", _tags_key({"hostname": f"h{i}"}), 4)
           for i in range(64)}
    assert idx <= set(range(4)) and len(idx) == 4   # all shards reachable


def test_sharded_forced_rollup_unservable_raises():
    sh = ShardedDatabase("s", shards=2)
    sh.write([Point("m", {"hostname": "h"}, {"v": float(i)}, i * S)
              for i in range(10)])
    with pytest.raises(ValueError):
        sh.aggregate("m", "v", agg="sum", window_ns=S // 2,
                     use_rollups=True)
    # auto falls back to the (sharded) raw rescan
    out = sh.aggregate("m", "v", agg="sum", window_ns=S // 2)
    assert sum(sum(v) for _, v in out.values()) == pytest.approx(45.0)


def test_shard_count_validation():
    with pytest.raises(ValueError):
        ShardedDatabase("s", shards=0)
    with pytest.raises(ValueError):
        TSDBServer(shards=0)


# -- property tier (hypothesis; skips cleanly when not installed) -------------


_point_strategy = st.tuples(
    st.integers(min_value=0, max_value=200 * S),          # timestamp
    st.integers(min_value=0, max_value=3),                # host index
    st.floats(min_value=-1e6, max_value=1e6,
              allow_nan=False, allow_infinity=False, width=32))


@pytest.mark.stress
@settings(max_examples=int(os.environ.get("LMS_PROPERTY_EXAMPLES", "30")),
          deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.lists(_point_strategy, min_size=1, max_size=200))
def test_property_sharded_equals_unsharded(shards, raw_pts):
    """For ANY stream and ANY shard count 1-8: sharded == unsharded,
    including out-of-order timestamps and post-retention rollup windows."""
    pts = [Point("m", {"hostname": f"h{h}"}, {"v": v}, ts)
           for ts, h, v in raw_pts]
    ref = Database("ref")
    sh = ShardedDatabase("s", shards=shards)
    ref.write(pts)
    _write_in_batches(sh, pts, random.Random(len(pts)))
    for agg in ROLLUP_AGGS:
        scalar = sh.aggregate("m", "v", agg=agg, group_by_tag="hostname")
        want = ref.aggregate("m", "v", agg=agg, group_by_tag="hostname")
        assert set(scalar) == set(want)
        for g in want:
            assert scalar[g] == pytest.approx(want[g], rel=1e-9, abs=1e-9)
        _assert_windows_equal(
            sh.aggregate("m", "v", agg=agg, window_ns=10 * S),
            ref.aggregate("m", "v", agg=agg, window_ns=10 * S))
    ref.enforce_retention(max_points_per_series=2)
    sh.enforce_retention(max_points_per_series=2)
    _assert_windows_equal(
        sh.aggregate("m", "v", agg="count", window_ns=60 * S,
                     use_rollups=True),
        ref.aggregate("m", "v", agg="count", window_ns=60 * S,
                      use_rollups=True))


# -- stress tier --------------------------------------------------------------


@pytest.mark.stress
def test_sharded_concurrent_stress():
    """N batched writers + M query threads + a retention reaper against a
    4-shard backend: no exceptions, no lost points, RouterStats counters
    monotonic throughout.  LMS_STRESS_SCALE (float) scales the workload
    for the bounded CI tier-2 run."""
    scale = float(os.environ.get("LMS_STRESS_SCALE", "1"))
    n_batches = max(2, int(60 * scale))
    batch = 40
    writers = 4
    hosts = [f"h{i}" for i in range(2 * writers)]
    server = TSDBServer(shards=4)
    router = MetricsRouter(server, per_job_db=True)
    router.job_start("j1", "alice", hosts)
    db = server.db("global")
    errors: list = []
    done = threading.Event()

    def writer(w):
        try:
            for b in range(n_batches):
                base = (w * n_batches + b) * batch
                router.write_lines(encode_batch([
                    Point("hpm", {"hostname": hosts[2 * w + (i % 2)]},
                          {"mfu": 0.4, "step": float(base + i)},
                          (base + i) * 10_000_000)
                    for i in range(batch)]))
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    def querier():
        try:
            while not done.is_set():
                db.select("hpm", ["mfu"], {"jobid": "j1"})
                db.aggregate("hpm", "mfu", agg="mean", window_ns=S)
                db.aggregate("hpm", "step", agg="count",
                             group_by_tag="hostname")
                db.rollup_aggregate("hpm", "mfu", agg="max",
                                    window_ns=10 * S)
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    def reaper():
        try:
            while not done.is_set():
                db.enforce_retention(max_points_per_series=200)
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    def monitor():
        try:
            prev = router.stats.snapshot()
            while not done.is_set():
                cur = router.stats.snapshot()
                for k, v in prev.items():
                    assert cur[k] >= v, f"counter {k} went backwards"
                # snapshots are consistent cuts (stats updated atomically
                # per batch), so the cross-counter invariant always holds
                assert cur["points_in"] == \
                    cur["points_out"] + cur["dropped_no_host"]
                prev = cur
                done.wait(0.001)
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    wthreads = [threading.Thread(target=writer, args=(w,))
                for w in range(writers)]
    others = [threading.Thread(target=querier) for _ in range(2)] + \
        [threading.Thread(target=reaper), threading.Thread(target=monitor)]
    for t in others + wthreads:
        t.start()
    for t in wthreads:
        t.join(timeout=120)
    done.set()
    for t in others:
        t.join(timeout=30)
    assert not errors, errors
    total = writers * n_batches * batch
    snap = router.stats.snapshot()
    assert snap["points_in"] == total
    assert snap["points_out"] == total
    assert snap["parse_errors"] == 0 and snap["dropped_no_host"] == 0
    # global db: every metric point + the job_start event, nothing lost
    assert db.point_count() == total + 1
    assert db.stored_points() <= total + 1
    # rollups saw every point even though retention culled raw storage
    counted = db.aggregate("hpm", "mfu", agg="count", window_ns=60 * S,
                           use_rollups=True)
    assert sum(sum(v) for _, v in counted.values()) == total
    # per-job duplicate database is sharded too, and complete
    assert server.db("job_j1").point_count() == total
