"""Derived-metric query engine (``repro.core.query``).

Contracts under test:

* **Derivation parity** — vectorized query-time derivation
  (``CompiledFormula.eval_columns``) equals per-window scalar
  ``eval_formula``, including skip semantics (missing input / division by
  zero), for arbitrary window columns (hypothesis property + seeded
  fallback).
* **Planner tier selection** — a window nesting into a rollup tier plans
  onto the rollup path (and keeps answering after raw retention); a
  misaligned window falls back to a raw rescan with the same
  window-granularity range semantics.
* **Cache** — results are cached per (plan fingerprint, ingest
  watermark): repeat queries are hits, ingest into a touched measurement
  (and retention) invalidates, ingest into *other* measurements does not.
* **Execution transparency** — one spec answers byte-identically local,
  sharded (sub-plans per shard, merged ``WindowAgg`` partials) and
  HTTP-federated (``POST /query/v2`` whole-spec pushdown).
* Satellites: precompiled formulas (module parse cache), ``PerfGroup.
  derive`` skip recording, ``ThresholdRule.expr`` derived rule inputs,
  ``HostAgent`` per-interval rate fields with counter-reset guards.
"""

import json
import random
import urllib.error
import urllib.request

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import MonitoringStack
from repro.core.analysis import AnalysisEngine, ThresholdRule, \
    evaluate_rules_on_db
from repro.core.host_agent import HostAgent
from repro.core.httpd import HttpQueryClient, LMSHttpServer
from repro.core.line_protocol import Point
from repro.core.perf_groups import (GROUPS, HBM_BW, CompiledFormula,
                                    compile_formula, derive_all,
                                    eval_formula, formula_for,
                                    register_group)
from repro.core.query import (QueryEngine, QuerySpec,
                              derived_rollup_series, derived_select_series,
                              make_plan)
from repro.core.router import MetricsRouter
from repro.core.rollup import RollupConfig
from repro.core.shard import FederatedQuery, ShardedDatabase
from repro.core.tsdb import Database, TSDBServer

S = 1_000_000_000


# --------------------------------------------------------------------------
# dataset helpers — float-exact values (binary fractions) so federated
# merge order cannot perturb sums and byte-identical comparisons hold
# --------------------------------------------------------------------------


def _raw_event_points(n_steps=120, hosts=4):
    """hpm points carrying ONLY raw events (no derived metric stored) +
    system points for cross-measurement joins."""
    pts = []
    for i in range(n_steps):
        for h in range(hosts):
            tags = {"hostname": f"h{h}", "jobid": f"j{h % 2}"}
            pts.append(Point("hpm", tags,
                             {"hlo_bytes": float((h + 1) * 2 ** 30),
                              "hlo_flops": float((h + 1) * 2 ** 40),
                              "step_time_s": 0.5 + 0.25 * (i % 2)},
                             i * S))
            pts.append(Point("system", tags,
                             {"cpu_load_1m": 1.0 + 0.5 * h}, i * S))
    return pts


def _write(db, pts, batch=64):
    for i in range(0, len(pts), batch):
        db.write(pts[i:i + batch])


# --------------------------------------------------------------------------
# compiled formulas (satellite: precompile once, record skips)
# --------------------------------------------------------------------------


def test_formula_parse_cache_returns_same_object():
    a = compile_formula("hlo_bytes / step_time_s / HBM_BW")
    b = compile_formula("hlo_bytes / step_time_s / HBM_BW")
    assert a is b
    assert a.names == ("hlo_bytes", "step_time_s", "HBM_BW")


def test_eval_formula_unchanged_semantics():
    assert eval_formula("a + 2 * b", {"a": 1, "b": 3}) == 7.0
    assert eval_formula("min(a, b)", {"a": 4, "b": 3}) == 3.0
    assert eval_formula("HBM_BW / 1e9", {}) == pytest.approx(819.0)
    # env shadows hardware constants, like it always did
    assert eval_formula("HBM_BW", {"HBM_BW": 2.0}) == 2.0
    with pytest.raises(KeyError):
        eval_formula("missing + 1", {})
    with pytest.raises(ValueError):
        compile_formula("__import__('os')")


def test_dotted_cross_measurement_names():
    cf = compile_formula("hpm.mfu / system.cpu_load_1m")
    assert cf.names == ("hpm.mfu", "system.cpu_load_1m")
    assert cf.eval({"hpm.mfu": 1.0, "system.cpu_load_1m": 2.0}) == 0.5


def test_derive_records_skipped_metrics():
    skipped = []
    out = GROUPS["MEM"].derive({"hlo_bytes": 1e9, "step_time_s": 0.0},
                               skipped=skipped)
    # step_time_s == 0 -> division by zero; hbm_bytes_in_use missing
    assert "mem_gb_per_s" not in out
    reasons = dict(skipped)
    assert reasons["mem_gb_per_s"] == "division by zero"
    assert "hbm_bytes_in_use" in reasons["hbm_used_gb"]
    # derive_all threads the same recording through every group
    skipped2 = []
    derive_all({"step_time_s": 1.0}, skipped=skipped2)
    assert ("gflops_per_s", "missing event 'hlo_flops'") in skipped2
    # strict still raises
    with pytest.raises(ZeroDivisionError):
        GROUPS["MEM"].derive({"hlo_bytes": 1e9, "step_time_s": 0.0},
                             strict=True)


def test_formula_for_and_register_group():
    assert formula_for("hbm_bw_util") == "hlo_bytes / step_time_s / HBM_BW"
    assert formula_for("MEM.hbm_bw_util") == \
        "hlo_bytes / step_time_s / HBM_BW"
    assert formula_for("nope") is None
    register_group("""
    GROUP QTEST
    EVENTSET
      a
    METRICS
      qtest_double  a * 2
    """)
    try:
        assert formula_for("qtest_double") == "a * 2"
        spec = QuerySpec("m", ("@QTEST.qtest_double",))
        assert spec.metrics == (("qtest_double", "a * 2"),)
    finally:
        del GROUPS["QTEST"]


# --------------------------------------------------------------------------
# parity property: vectorized == per-window scalar eval_formula
# --------------------------------------------------------------------------

_PARITY_FORMULAS = (
    "a / b",
    "a + 2 * b - c",
    "min(a, b) / max(c, 1)",
    "a / (b - b)",                    # always divides by zero
    "a / step_time_s / HBM_BW",
    "-a ** 2 + abs(c)",
)


def _check_parity(formula, cols, n):
    cf = compile_formula(formula)
    vec = cf.eval_columns(cols, n)
    assert len(vec) == n
    for i in range(n):
        env = {k: col[i] for k, col in cols.items()
               if col[i] is not None}
        try:
            expect = eval_formula(formula, env)
            if isinstance(expect, complex):     # domain error -> skipped
                expect = None
        except (KeyError, ZeroDivisionError, OverflowError):
            expect = None
        assert vec[i] == expect or (
            expect != expect and vec[i] != vec[i])    # NaN == NaN


def _random_cols(rng, n):
    cols = {}
    for name in ("a", "b", "c", "step_time_s"):
        if rng.random() < 0.8:
            cols[name] = [
                None if rng.random() < 0.3
                else rng.choice([0.0, 0.25, -1.5, 3.0, rng.random()])
                for _ in range(n)]
    return cols


def test_domain_errors_skip_the_window():
    """Complex results and overflow must skip (None), never leak a
    non-float into JSON results or threshold comparisons."""
    cf = compile_formula("(a - b) ** 0.5")
    assert cf.eval_columns({"a": [1.0, 3.0], "b": [3.0, 1.0]}, 2) == \
        [None, pytest.approx(2 ** 0.5)]
    cf = compile_formula("a ** b")
    assert cf.eval_columns({"a": [9.0], "b": [1e9]}, 1) == [None]
    # through the full engine (windowed and scalar forms)
    db = Database("t")
    db.write([Point("hpm", {"hostname": "h0"}, {"a": 1.0, "b": 3.0},
                    i * S) for i in range(3)])
    for spec in (QuerySpec("hpm", ("m=(a - b) ** 0.5",), window_ns=S),
                 QuerySpec("hpm", ("m=(a - b) ** 0.5",))):
        res = QueryEngine(db).query(spec)
        assert all("m" not in g for g in res.groups.values())
        json.dumps(res.to_dict())           # JSON-safe, no complex


def test_vectorized_equals_scalar_eval_seeded():
    rng = random.Random(1234)
    for _ in range(200):
        n = rng.randrange(0, 12)
        cols = _random_cols(rng, n)
        for formula in _PARITY_FORMULAS:
            _check_parity(formula, cols, n)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 15))
def test_property_vectorized_equals_scalar_eval(seed, n):
    rng = random.Random(seed)
    cols = _random_cols(rng, n)
    for formula in _PARITY_FORMULAS:
        _check_parity(formula, cols, n)


# --------------------------------------------------------------------------
# planner: tier selection
# --------------------------------------------------------------------------


def test_planner_aligned_window_uses_rollups():
    cfg = RollupConfig()
    spec = QuerySpec("hpm", ("@hbm_bw_util",), window_ns=10 * S)
    plan = make_plan(spec, cfg)
    assert plan.use_rollups and plan.tier_ns == 10 * S
    # a coarser multiple nests too (60s tier under a 120s window)
    plan = make_plan(QuerySpec("hpm", ("x",), window_ns=120 * S), cfg)
    assert plan.use_rollups and plan.tier_ns == 60 * S


def test_planner_misaligned_window_falls_back_to_raw():
    cfg = RollupConfig()
    plan = make_plan(QuerySpec("hpm", ("x",), window_ns=int(1.5 * S)), cfg)
    assert not plan.use_rollups and plan.tier_ns is None
    # no rollups at all -> raw
    plan = make_plan(QuerySpec("hpm", ("x",), window_ns=10 * S), None)
    assert not plan.use_rollups
    # scalar specs always scan raw
    plan = make_plan(QuerySpec("hpm", ("x",)), cfg)
    assert not plan.use_rollups


def test_planner_inputs_resolution():
    spec = QuerySpec("hpm", ("r=hlo_flops / system.cpu_load_1m / HBM_BW",
                             "step_time_s"))
    plan = make_plan(spec, RollupConfig())
    assert plan.inputs == (("hpm", "hlo_flops"),
                           ("system", "cpu_load_1m"),
                           ("hpm", "step_time_s"))
    assert plan.measurements == ("hpm", "system")


def test_misaligned_raw_equals_aligned_rollup_content():
    """The raw fallback uses the same window-granularity range semantics
    as the rollup path: the same grid, expanded to whole windows."""
    db = Database("t")
    _write(db, _raw_event_points())
    aligned = QuerySpec("hpm", ("step_time_s",), window_ns=10 * S,
                        t_min=15 * S, t_max=94 * S)
    raw = QuerySpec("hpm", ("step_time_s",), window_ns=10 * S,
                    t_min=15 * S, t_max=94 * S, agg="mean",
                    group_by="hostname")
    eng = QueryEngine(db)
    res = eng.query(aligned)
    (times, _vals) = res.column("step_time_s")
    # whole windows: the window containing t_min and t_max both included
    assert times[0] == 10 * S and times[-1] == 90 * S
    # force raw by breaking tier nesting is covered above; here compare
    # rollup-planned vs raw-collected content through a raw-only database
    db_raw = Database("raw", rollup_config=None)
    _write(db_raw, _raw_event_points())
    res_raw = QueryEngine(db_raw).query(aligned)
    assert res_raw.to_json() == res.to_json()


def test_post_retention_served_from_rollup_tier():
    """Raw points trimmed away: the aligned plan answers identically
    from the surviving rollup windows."""
    db = Database("t")
    _write(db, _raw_event_points())
    spec = QuerySpec("hpm", ("@hbm_bw_util", "step_time_s"),
                     window_ns=10 * S, group_by="jobid")
    before = QueryEngine(db).query(spec).to_json()
    db.enforce_retention(max_points_per_series=1)
    assert db.stored_points() < 20
    after = QueryEngine(db).query(spec).to_json()
    assert after == before
    # the raw-only twin loses the history
    db_raw = Database("raw", rollup_config=None)
    _write(db_raw, _raw_event_points())
    db_raw.enforce_retention(max_points_per_series=1)
    res = QueryEngine(db_raw).query(spec)
    got = sum(len(m["times"]) for g in res.groups.values()
              for m in g.values())
    assert got < 20


# --------------------------------------------------------------------------
# cache: watermark-keyed LRU
# --------------------------------------------------------------------------


def test_cache_hit_and_invalidation_on_ingest():
    db = Database("t")
    _write(db, _raw_event_points())
    eng = QueryEngine(db)
    spec = QuerySpec("hpm", ("@hbm_bw_util",), window_ns=10 * S,
                     group_by="hostname")
    r1 = eng.query(spec)
    r2 = eng.query(spec)
    assert r2 is r1                      # O(1) repeat render
    assert eng.cache_info()["cache_hits"] == 1
    # ingest into a touched measurement invalidates...
    db.write([Point("hpm", {"hostname": "h0", "jobid": "j0"},
                    {"hlo_bytes": float(2 ** 30), "step_time_s": 0.5},
                    500 * S)])
    r3 = eng.query(spec)
    assert r3 is not r1
    assert r3.column("hbm_bw_util", "h0")[0][-1] == 500 * S
    # ...ingest into an unrelated measurement does not
    db.write([Point("other", {"hostname": "h0"}, {"v": 1.0}, 1 * S)])
    assert eng.query(spec) is r3
    # a retention sweep that finds nothing expired keeps the cache warm
    db.enforce_retention(max_points_per_series=10 ** 9)
    assert eng.query(spec) is r3
    # retention that actually drops data invalidates (data moved)
    db.enforce_retention(max_points_per_series=1)
    r4 = eng.query(spec)
    assert r4 is not r3 and r4.to_json() == r3.to_json()


def test_watermark_failure_degrades_to_uncached():
    """A backend whose watermark probe fails (older remote without
    /meta?what=data_version) must still answer — uncached, never a
    crash."""
    db = Database("t")
    _write(db, _raw_event_points(n_steps=10))

    class View:
        rollup_config = db.rollup_config

        def aggregate_partials(self, *a, **k):
            return db.aggregate_partials(*a, **k)

        def data_version(self, measurement=None):
            raise ValueError("remote query failed: unknown meta "
                             "'data_version'")

    eng = QueryEngine(View())
    spec = QuerySpec("hpm", ("step_time_s",), window_ns=10 * S)
    res = eng.query(spec)
    assert res.groups and eng.query(spec) is not res    # runs, uncached


def test_cache_lru_eviction_and_plan_reuse():
    db = Database("t")
    _write(db, _raw_event_points(n_steps=20))
    eng = QueryEngine(db, cache_size=2)
    specs = [QuerySpec("hpm", ("step_time_s",), window_ns=w)
             for w in (S, 10 * S, 60 * S)]
    for spec in specs:
        eng.query(spec)
    info = eng.cache_info()
    assert info["cached_results"] == 2 and info["cached_plans"] == 3
    # distinct specs -> distinct fingerprints; same spec -> same plan
    assert eng.plan(specs[0]) is eng.plan(
        QuerySpec("hpm", ("step_time_s",), window_ns=S))


# --------------------------------------------------------------------------
# spec wire form
# --------------------------------------------------------------------------


def test_spec_roundtrip_and_fingerprint():
    spec = QuerySpec("hpm", ("@hbm_bw_util", "s=step_time_s * 2", "step"),
                     tags={"jobid": "j1"}, t_min=S, t_max=90 * S,
                     window_ns=10 * S, group_by="hostname",
                     order_by="hbm_bw_util", limit=3)
    back = QuerySpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    assert back.fingerprint() == spec.fingerprint()
    # group references resolve into the fingerprint (formula text), so a
    # changed group definition cannot serve a stale cached result
    assert dict(spec.metrics)["hbm_bw_util"] == \
        "hlo_bytes / step_time_s / HBM_BW"


def test_spec_validation():
    with pytest.raises(ValueError):
        QuerySpec("hpm", ())
    with pytest.raises(ValueError):
        QuerySpec("hpm", ("a", "a"))
    with pytest.raises(ValueError):
        QuerySpec("hpm", ("a",), agg="median")
    with pytest.raises(ValueError):
        QuerySpec("hpm", ("a",), order_by="b")
    with pytest.raises(ValueError):
        QuerySpec("hpm", ("@no_such_metric",))


# --------------------------------------------------------------------------
# execution transparency: local == sharded == HTTP-federated
# --------------------------------------------------------------------------

_EQ_SPECS = [
    QuerySpec("hpm", ("@hbm_bw_util", "step_time_s"), window_ns=10 * S,
              group_by="hostname"),
    QuerySpec("hpm", ("@hbm_bw_util",), window_ns=10 * S,
              group_by="jobid", order_by="hbm_bw_util", limit=3,
              t_min=10 * S, t_max=110 * S),
    # cross-measurement join: bytes per unit of host load
    QuerySpec("hpm", ("bpl=hlo_bytes / system.cpu_load_1m",),
              window_ns=60 * S, group_by="hostname",
              order_by="bpl", limit=2),
    QuerySpec("hpm", ("@hbm_bw_util",), group_by="jobid"),    # scalar
    QuerySpec("hpm", ("@gflops_per_s",), window_ns=int(1.5 * S),
              group_by="hostname"),                           # raw plan
]


def test_sharded_equals_unsharded():
    pts = _raw_event_points()
    single = Database("one")
    _write(single, pts)
    for shards in (2, 4, 7):
        sharded = ShardedDatabase("many", shards=shards)
        _write(sharded, pts)
        for spec in _EQ_SPECS:
            a = QueryEngine(single).query(spec)
            b = QueryEngine(sharded).query(spec)
            assert a.to_json() == b.to_json(), (shards, spec.metrics)


def test_http_federated_equals_local():
    """Two LMS instances (each sharded), spec pushed down via
    /query/v2 — byte-identical to one local database holding the union,
    with pushdown round-trips cached on the remote."""
    pts = _raw_event_points()
    single = Database("one")
    _write(single, pts)
    routers = [MetricsRouter(TSDBServer(shards=2)) for _ in range(2)]
    for p in pts:       # each host's series lives on exactly one instance
        routers[int(p.tags["hostname"][1:]) % 2].backend.write([p])
    with LMSHttpServer(routers[0]) as sa, LMSHttpServer(routers[1]) as sb:
        fed = FederatedQuery([HttpQueryClient(sa.url),
                              HttpQueryClient(sb.url)])
        eng = QueryEngine(fed)
        for spec in _EQ_SPECS:
            a = QueryEngine(single).query(spec)
            b = eng.query(spec)
            assert a.to_json() == b.to_json(), spec.metrics
        # remote watermarks unchanged -> the federated engine serves the
        # repeat from its local cache
        spec = _EQ_SPECS[0]
        assert eng.query(spec) is eng.query(spec)
        # full server-side execution (mode=result) agrees per instance
        client = HttpQueryClient(sa.url)
        remote = client.query(spec)
        local = QueryEngine(routers[0].backend.db("global")).query(spec)
        assert remote.to_json() == local.to_json()
        # the remote's own engine cached the executed spec
        meta = json.loads(urllib.request.urlopen(
            f"{sa.url}/meta?what=query_cache").read())["query_cache"]
        assert meta["queries"] >= 1
        # data_version is remote-readable (the local cache key half)
        assert client.data_version("hpm") == \
            routers[0].backend.db("global").data_version("hpm")


def test_derived_metric_from_raw_events_grouped_topk_post_retention():
    """THE acceptance query: ``hbm_bw_util`` was never stored (points
    carry raw events only); over a t_min/t_max range, grouped by jobid,
    top-3 — answerable from the rollup tiers alone after raw retention,
    locally and over HTTP."""
    server = TSDBServer(shards=4)
    db = server.db("global")
    _write(db, _raw_event_points())
    assert "hbm_bw_util" not in db.field_keys("hpm")
    spec = QuerySpec("hpm", ("@hbm_bw_util",), t_min=10 * S, t_max=110 * S,
                     window_ns=10 * S, group_by="jobid",
                     order_by="hbm_bw_util", limit=3)
    eng = QueryEngine(db)
    before = eng.query(spec)
    # j1 hosts (h1, h3) move more bytes -> ranked first
    assert list(before.groups) == ["j1", "j0"]
    expect = (2 ** 30 * (2 + 4) / 2) / 0.625 / HBM_BW
    got = before.column("hbm_bw_util", "j1")[1]
    assert got[0] == pytest.approx(expect)
    # raw points gone -> identical answer from the rollup tiers
    db.enforce_retention(max_points_per_series=1)
    after = eng.query(spec)
    assert after.to_json() == before.to_json()
    # and over the wire
    router = MetricsRouter(server)
    with LMSHttpServer(router) as srv:
        remote = HttpQueryClient(srv.url).query(spec)
        assert remote.to_json() == before.to_json()


# --------------------------------------------------------------------------
# derived rule inputs (ThresholdRule.expr) through the analysis engine
# --------------------------------------------------------------------------


def _bw_rule():
    # hbm_bw_util is never emitted by these points; the rule derives it
    return ThresholdRule("low_bw", "hpm", "hbm_bw_util", "<", 0.001,
                         min_duration_s=20.0, severity="warning",
                         expr=formula_for("hbm_bw_util"))


def test_derived_rule_series_and_offline_eval():
    db = Database("t")
    pts = []
    for i in range(90):
        bytes_ = 2 ** 30 if i < 40 else 2 ** 10     # collapses at i=40
        pts.append(Point("hpm", {"hostname": "h0", "jobid": "j"},
                         {"hlo_bytes": float(bytes_), "step_time_s": 1.0},
                         i * S))
    _write(db, pts)
    series = derived_rollup_series(db, "hpm", "hbm_bw_util",
                                   formula_for("hbm_bw_util"))
    assert len(series) == 1
    assert series[0].values["hbm_bw_util"][0] == \
        pytest.approx(2 ** 30 / HBM_BW)
    # raw twin agrees on a rollup-disabled database
    db_raw = Database("r", rollup_config=None)
    _write(db_raw, pts)
    raw = derived_select_series(db_raw, "hpm", "hbm_bw_util",
                                formula_for("hbm_bw_util"))
    assert raw[0].values["hbm_bw_util"] == series[0].values["hbm_bw_util"]
    findings = evaluate_rules_on_db(db, [_bw_rule()], jobid="j")
    assert findings and findings[0].rule == "low_bw"
    assert findings[0].start_ns == 40 * S


def test_analysis_engine_fires_on_derived_metric():
    server = TSDBServer()
    router = MetricsRouter(server)
    engine = AnalysisEngine([_bw_rule()], backend=server, auto_tick=False)
    router.subscribe(engine)
    router.jobs.on_end(engine.on_job_end)
    router.job_start("j", "u", ["h0"])
    pts = [Point("hpm", {"hostname": "h0"},
                 {"hlo_bytes": float(2 ** 10), "step_time_s": 1.0}, i * S)
           for i in range(60)]
    for i in range(0, len(pts), 20):
        router.write(pts[i:i + 20])
    engine.flush(final=True)
    assert engine.alerts, "derived-metric rule must fire"
    a = engine.alerts[0]
    assert a.rule == "low_bw" and a.host == "h0" and a.jobid == "j"
    # parity with the offline scan over the same derived series
    offline = evaluate_rules_on_db(server.db("global"), [_bw_rule()],
                                   jobid="j")
    assert offline[0].start_ns == a.start_ns
    engine.close()


# --------------------------------------------------------------------------
# host agent rate fields (satellite)
# --------------------------------------------------------------------------


class _Router:
    def __init__(self):
        self.points = []

    def write(self, p):
        self.points.append(p)


def test_host_agent_emits_interval_rates():
    agent = HostAgent(_Router(), hostname="h0")
    agent._rate_fields({"net_rx_bytes": 1000.0, "cpu_user_s": 1.0}, 10.0)
    rates = agent._rate_fields({"net_rx_bytes": 3000.0, "cpu_user_s": 1.5},
                               12.0)
    assert rates["net_rx_bytes_per_s"] == pytest.approx(1000.0)
    assert rates["cpu_user_frac"] == pytest.approx(0.25)
    # counter reset: negative delta skipped, baseline renewed
    rates = agent._rate_fields({"net_rx_bytes": 100.0, "cpu_user_s": 1.6},
                               14.0)
    assert "net_rx_bytes_per_s" not in rates
    assert rates["cpu_user_frac"] == pytest.approx(0.05)
    rates = agent._rate_fields({"net_rx_bytes": 300.0, "cpu_user_s": 1.7},
                               16.0)
    assert rates["net_rx_bytes_per_s"] == pytest.approx(100.0)


def test_host_agent_collect_system_carries_rates():
    agent = HostAgent(_Router(), hostname="h0")
    p1 = agent.collect_system()
    assert "cpu_user_frac" not in p1.fields          # no baseline yet
    p2 = agent.collect_system()
    assert "cpu_user_frac" in p2.fields
    assert p2.fields["cpu_user_frac"] >= 0.0
    assert "net_rx_bytes_per_s" in p2.fields or \
        "net_rx_bytes" not in p2.fields


def test_data_version_distinct_across_incarnations():
    """A restarted (re-created) database must not re-count its way back
    to a previously seen watermark with different data underneath — the
    per-incarnation epoch keeps cache keys disjoint, even when the
    process seeds the global random module deterministically."""
    random.seed(7)
    a = Database("t")
    a.write([Point("m", {"hostname": "h"}, {"v": 1.0}, S)])
    random.seed(7)
    b = Database("t")
    b.write([Point("m", {"hostname": "h"}, {"v": 2.0}, S)])
    assert a.data_version("m") != b.data_version("m")


def test_formula_cache_bounded_lru():
    """The parse cache is bounded (remote specs carry caller-written
    formula text) and LRU-by-recency, so a hot formula that keeps being
    touched stays resident under distinct-formula floods."""
    info = compile_formula.cache_info()
    assert info.maxsize == 4096
    hot = compile_formula("a + 314159")
    for i in range(50):
        compile_formula(f"a + {i} * 271828")
        assert compile_formula("a + 314159") is hot
    # compile errors are never cached; they raise on every call
    for _ in range(2):
        with pytest.raises(ValueError):
            compile_formula("getattr(a, 'x')")


def test_derived_select_series_over_http_client():
    """ThresholdRule.expr raw-path inputs must stay federation-
    transparent: the remote select wire form is single-field."""
    server = TSDBServer()
    db = server.db("global")
    db.write([Point("hpm", {"hostname": "h0"},
                    {"a": 6.0, "b": 2.0 + (i % 2)}, i * S)
              for i in range(4)])
    with LMSHttpServer(MetricsRouter(server)) as srv:
        remote = HttpQueryClient(srv.url)
        got = derived_select_series(remote, "hpm", "r", "a / b")
        local = derived_select_series(db, "hpm", "r", "a / b")
        assert [s.values for s in got] == [s.values for s in local]
        assert got[0].values["r"] == [3.0, 2.0, 3.0, 2.0]


def test_unknown_db_name_is_404_not_registered():
    stack = MonitoringStack.inprocess(out_dir="/tmp/lms_q404")
    with LMSHttpServer(stack.router) as srv:
        body = json.dumps({"db": "nope",
                           "spec": {"measurement": "m",
                                    "metrics": [["v", None]]}}).encode()
        req = urllib.request.Request(f"{srv.url}/query/v2", data=body,
                                     method="POST")
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(req)
        assert e.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(
                f"{srv.url}/meta?what=query_cache&db=nope")
        assert e.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(
                f"{srv.url}/meta?what=data_version&db=nope")
        assert e.value.code == 404
    assert "nope" not in stack.backend.databases()
    stack.close()


def test_dashboard_fallback_engines_bounded():
    """Per-render throwaway views must not pin an engine + caches each
    for the process lifetime; same view keeps its engine."""
    from repro.core import DashboardAgent
    backend = TSDBServer()
    agent = DashboardAgent(backend, out_dir="/tmp/lms_qdash")
    views = [Database(f"v{i}") for i in range(20)]
    engines = [agent._engine(v) for v in views]
    assert len(agent._engines) <= agent.MAX_FALLBACK_ENGINES
    assert agent._engine(views[-1]) is engines[-1]       # reused
    # the backend's own databases go through the shared registry
    db = backend.db("global")
    assert agent._engine(db, "global") is backend.query_engine("global")


# --------------------------------------------------------------------------
# stack integration: dashboards render through the cached engine
# --------------------------------------------------------------------------


def test_dashboard_renders_through_query_engine(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    hosts = ["h0", "h1"]
    with stack.job("jq", user="u", hosts=hosts) as job:
        agents = [stack.host_agent(h, hlo_flops=1e15, model_flops=8e14,
                                   hlo_bytes=1e12, collective_bytes=1e11,
                                   tokens_per_step=1e6) for h in hosts]
        for s in range(120):
            for a in agents:
                a.collect_step(step=s, step_time_s=1.0, ts=s * S)
    dash = stack.dashboards.build_dashboard(job)
    html = stack.dashboards.render_html(job, dash)
    assert "svg" in html
    # renders go through the backend's SHARED engine registry — the same
    # cache /query/v2 uses — not a private dashboard-only engine
    eng = stack.backend.query_engine("global")
    assert eng.stats["queries"] > 0
    assert stack.dashboards._engine(stack.backend.db("global"),
                                    "global") is eng
    # an unchanged re-render is served from the cache
    before = dict(eng.stats)
    stack.dashboards.render_html(job, dash)
    assert eng.stats["cache_hits"] > before["cache_hits"]
    assert eng.stats["cache_misses"] == before["cache_misses"]
    stack.close()
