"""HLO cost walker: trip counts, dot FLOPs, collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (HloAnalyzer, analyze_hlo,
                                       cost_analysis_dict,
                                       parse_computations)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    c = _compile(f, jax.ShapeDtypeStruct((64, 32), jnp.float32),
                 jax.ShapeDtypeStruct((32, 16), jnp.float32))
    got = analyze_hlo(c.as_text())["per_device"]["flops"]
    assert got == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_scan_trip_count_multiplies():
    def body(x, _):
        return jnp.tanh(x @ x), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    res = analyze_hlo(c.as_text())
    assert list(res["trip_counts"].values()) == [7.0]
    # 7 iterations x 2*32^3 dot flops (+ elementwise)
    assert res["per_device"]["flops"] >= 7 * 2 * 32**3
    assert res["per_device"]["flops"] < 1.3 * 7 * 2 * 32**3
    # vs. the uncorrected cost_analysis, which counts the body once
    assert cost_analysis_dict(c)["flops"] < 2 * 2 * 32**3 + 5000


def test_nested_scan_trip_counts():
    def inner(x, _):
        return x @ x, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    res = analyze_hlo(c.as_text())
    assert res["per_device"]["flops"] >= 15 * 2 * 16**3


def test_bytes_reasonable():
    def f(a):
        return a * 2.0
    c = _compile(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
    b = analyze_hlo(c.as_text())["per_device"]["bytes"]
    # one read + one write = 8 KiB
    assert 4096 <= b <= 4 * 8192


def test_parse_computations_shapes():
    text = """HloModule m, num_partitions=4

%foo (p: f32[2,3]) -> f32[2,3] {
  %p = f32[2,3]{1,0} parameter(0)
  ROOT %t = f32[2,3]{1,0} tanh(%p)
}

ENTRY %main (a: f32[2,3]) -> f32[2,3] {
  %a = f32[2,3]{1,0} parameter(0)
  ROOT %c = f32[2,3]{1,0} fusion(%a), kind=kLoop, calls=%foo
}
"""
    comps, np_ = parse_computations(text)
    assert np_ == 4
    assert set(comps) == {"foo", "main"}
    an = HloAnalyzer(text)
    cost = an.analyze()
    assert cost.flops == pytest.approx(5 * 6)      # tanh = 5 flops/elem


def test_collective_accounting_sharded():
    """psum over an 8-partition mesh (requires >1 device via sub-mesh trick:
    single-device fallback just checks zero collectives)."""
    ndev = len(jax.devices())
    if ndev == 1:
        def f(x):
            return x + 1
        c = _compile(f, jax.ShapeDtypeStruct((8,), jnp.float32))
        res = analyze_hlo(c.as_text())
        assert res["per_device"]["collective_operand_bytes"] == 0
    else:
        pytest.skip("multi-device path covered by test_multidevice")
