"""SSM numerics: chunked Mamba2/RWKV6 vs sequential oracles + invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # minimal images: property tests skip, rest run
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.kernels.ref import ssd_ref, wkv6_ref
from repro.models import ssm as ssm_mod


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# -- Mamba2 SSD ---------------------------------------------------------------


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential(rng, chunk):
    b, l, h, p, n = 2, 64, 3, 8, 4
    x = _rand(rng, b, l, h, p)
    a = -jnp.abs(_rand(rng, b, l, h)) * 0.2
    bm = _rand(rng, b, l, h, n)
    cm = _rand(rng, b, l, h, n)
    y, state = ssm_mod.ssd_chunked(x, a, bm, cm, chunk=chunk)
    want = ssd_ref(x.transpose(0, 2, 1, 3), a.transpose(0, 2, 1),
                   bm.transpose(0, 2, 1, 3), cm.transpose(0, 2, 1, 3)
                   ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_size_invariance(rng):
    """The chunk size is a tiling choice — results must not depend on it."""
    b, l, h, p, n = 1, 96, 2, 8, 4
    x = _rand(rng, b, l, h, p)
    a = -jnp.abs(_rand(rng, b, l, h)) * 0.3
    bm = _rand(rng, b, l, h, n)
    cm = _rand(rng, b, l, h, n)
    y1, s1 = ssm_mod.ssd_chunked(x, a, bm, cm, chunk=8)
    y2, s2 = ssm_mod.ssd_chunked(x, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


def test_ssd_state_carry_prefill_decode(rng):
    """prefill(0..L) state == prefill(0..L/2) -> chunked continue."""
    b, l, h, p, n = 1, 32, 2, 4, 4
    x = _rand(rng, b, l, h, p)
    a = -jnp.abs(_rand(rng, b, l, h)) * 0.2
    bm = _rand(rng, b, l, h, n)
    cm = _rand(rng, b, l, h, n)
    y_full, s_full = ssm_mod.ssd_chunked(x, a, bm, cm, chunk=8)
    half = l // 2
    y1, s1 = ssm_mod.ssd_chunked(x[:, :half], a[:, :half], bm[:, :half],
                                 cm[:, :half], chunk=8)
    y2, s2 = ssm_mod.ssd_chunked(x[:, half:], a[:, half:], bm[:, half:],
                                 cm[:, half:], chunk=8, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(decay=st.floats(min_value=0.01, max_value=30.0),
       seed=st.integers(0, 100))
def test_ssd_no_overflow_property(decay, seed):
    """No decay magnitude may produce NaN/Inf (the <=0-exponent invariant)."""
    rng = np.random.default_rng(seed)
    b, l, h, p, n = 1, 32, 1, 4, 4
    x = _rand(rng, b, l, h, p)
    a = -jnp.abs(_rand(rng, b, l, h)) * decay
    bm = _rand(rng, b, l, h, n)
    cm = _rand(rng, b, l, h, n)
    y, s = ssm_mod.ssd_chunked(x, a, bm, cm, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(s)))


# -- RWKV6 WKV ---------------------------------------------------------------


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_wkv6_chunked_matches_sequential(rng, chunk):
    b, l, h, d = 2, 64, 2, 8
    r = _rand(rng, b, l, h, d)
    k = _rand(rng, b, l, h, d)
    v = _rand(rng, b, l, h, d)
    logw = -jnp.abs(_rand(rng, b, l, h, d)) * 0.5
    u = _rand(rng, h, d) * 0.5
    y, _ = ssm_mod.wkv6_chunked(r, k, v, logw, u, chunk=chunk)
    want, _ = wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_wkv6_state_carry(rng):
    b, l, h, d = 1, 32, 2, 8
    r, k, v = (_rand(rng, b, l, h, d) for _ in range(3))
    logw = -jnp.abs(_rand(rng, b, l, h, d)) * 0.3
    u = _rand(rng, h, d)
    y_full, s_full = ssm_mod.wkv6_chunked(r, k, v, logw, u, chunk=8)
    half = l // 2
    y1, s1 = ssm_mod.wkv6_chunked(r[:, :half], k[:, :half], v[:, :half],
                                  logw[:, :half], u, chunk=8)
    y2, s2 = ssm_mod.wkv6_chunked(r[:, half:], k[:, half:], v[:, half:],
                                  logw[:, half:], u, chunk=8, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(decay=st.floats(min_value=0.01, max_value=50.0),
       seed=st.integers(0, 100))
def test_wkv6_no_overflow_property(decay, seed):
    rng = np.random.default_rng(seed)
    b, l, h, d = 1, 16, 1, 4
    r, k, v = (_rand(rng, b, l, h, d) for _ in range(3))
    logw = -jnp.abs(_rand(rng, b, l, h, d)) * decay
    u = _rand(rng, h, d)
    y, s = ssm_mod.wkv6_chunked(r, k, v, logw, u, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(s)))


def test_rwkv_decode_matches_chunked(rng):
    """Recurrent decode path == chunked path, token by token."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    from repro.models.params import init_params
    params = init_params(ssm_mod.rwkv6_specs(cfg), seed=1)
    b, l = 2, 12
    x = 0.1 * _rand(np.random.default_rng(0), b, l, cfg.d_model)

    y_chunk, _ = ssm_mod.rwkv6_time_mix(params, x, cfg, mode="train")

    cache = ssm_mod.rwkv6_init_cache(cfg, b)
    outs = []
    for t in range(l):
        y_t, partial = ssm_mod.rwkv6_time_mix(params, x[:, t:t + 1], cfg,
                                              mode="decode", cache=cache)
        cache = {**cache, **partial}
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_chunk),
                               rtol=5e-3, atol=5e-3)


def test_mamba2_decode_matches_chunked(rng):
    cfg = get_config("zamba2-7b", smoke=True)
    from repro.models.params import init_params
    params = init_params(ssm_mod.mamba2_specs(cfg), seed=1)
    b, l = 2, 12
    x = 0.1 * _rand(np.random.default_rng(0), b, l, cfg.d_model)

    y_chunk, _ = ssm_mod.mamba2_block(params, x, cfg, mode="train")

    cache = ssm_mod.mamba2_init_cache(cfg, b)
    outs = []
    for t in range(l):
        y_t, cache = ssm_mod.mamba2_block(params, x[:, t:t + 1], cfg,
                                          mode="decode", cache=cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_chunk),
                               rtol=5e-3, atol=5e-3)
