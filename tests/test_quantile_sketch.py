"""Mergeable quantile sketches + the pluggable aggregate family.

What this module holds as properties (ISSUE 9):

* rank-accuracy — sketch p50/p95/p99 within 2% *relative value error* of
  the exact nearest-rank answer on adversarial distributions (constants,
  heavy tails, negatives, counter resets, zero-mixed);
* merge algebra — sketch merge is commutative and associative (bin-wise
  integer addition), so any batching/sharding order gives the same bins;
* parity by construction — p95 answers are identical local vs sharded
  (1-8 shards) vs HTTP-federated, survive raw retention and cold sealing,
  and are restart-exact through a WAL snapshot;
* versioned wire form — old 6-field scalar states/dicts still decode and
  a sketchless peer degrades gracefully (scalars exact, quantiles None);
* the empty-window mean regression (``value("mean")`` on count 0 is
  ``None``, never ZeroDivisionError) through /query and /query/v2;
* /meta?what=rollups + HttpQueryClient fail-fast validation;
* the per-job fingerprint fleet rule end-to-end through /alerts.
"""

import json
import math
import random
import urllib.request

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import (MonitoringStack, Point, QuerySpec, RollupConfig,
                        now_ns)
from repro.core.httpd import HttpQueryClient, LMSHttpServer
from repro.core.query import QueryEngine
from repro.core.rollup import (QUANTILE_AGGS, QuantileSketch, SCALAR_AGGS,
                               SketchAgg, WindowAgg, agg_from_state,
                               quantile_of)
from repro.core.router import MetricsRouter
from repro.core.shard import (ShardedDatabase, windowagg_from_dict,
                              windowagg_to_dict)
from repro.core.tsdb import Database, TSDBServer

S = 10 ** 9
CFG = RollupConfig(sketch_fields={"m": "*"})
TIER = CFG.tiers_ns[0]


def _exact_q(vals, q):
    s = sorted(vals)
    return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]


def _stream(rng, n, hosts=2):
    return [Point("m", {"hostname": f"h{rng.randrange(hosts)}"},
                  {"v": rng.lognormvariate(0, 2) - 0.5},
                  rng.randrange(0, 200) * S)
            for _ in range(n)]


def _write_in_batches(db, pts, rng):
    pts = list(pts)
    while pts:
        k = rng.randrange(1, min(64, len(pts)) + 1)
        db.write(pts[:k])
        pts = pts[k:]


# -- satellite 1: empty-window mean regression --------------------------------


def test_mean_of_empty_window_is_none():
    assert WindowAgg().value("mean") is None
    # count-0 state (pre-refactor snapshots can carry these)
    wa = agg_from_state([0, 0.0, None, None, None, None])
    assert wa.value("mean") is None
    assert wa.value("p95") is None      # quantile of sketchless: None


def _db_with_empty_window(backend):
    """Install a series whose rollups hold a count-0 window next to a
    real one — the shape an old snapshot (or a buggy writer) produces."""
    db = backend.db("global")
    tier = db.rollup_config.tiers_ns[0]
    db.restore_series([{
        "m": "m", "tags": {"hostname": "h0"},
        "times": [5 * S], "values": {"v": [3.0]},
        "rollups": {"v": {str(tier): {
            "0": [1, 3.0, 3.0, 3.0, 5 * S, 3.0],
            str(tier): [0, 0.0, None, None, None, None]}}}}])
    return db


def test_empty_window_mean_through_query_endpoints():
    backend = TSDBServer()
    router = MetricsRouter(backend)
    db = _db_with_empty_window(backend)
    tier = db.rollup_config.tiers_ns[0]
    # local: the empty window is skipped, never a ZeroDivisionError
    out = db.aggregate("m", "v", agg="mean", window_ns=tier,
                       use_rollups=True)
    assert out[""] == ([0], [pytest.approx(3.0)])
    with LMSHttpServer(router) as srv:
        # /query (GET form)
        with urllib.request.urlopen(
                f"{srv.url}/query?m=m&field=v&agg=mean"
                f"&window_ns={tier}&rollups=force") as r:
            got = json.load(r)["result"]
        assert got[""] == [[0], [3.0]]
        # /query/v2 (QuerySpec pushdown)
        client = HttpQueryClient(srv.url)
        res = client.query(QuerySpec("m", ("v",), window_ns=tier))
        m = res.groups[""]["v"]
        assert m["times"] == [0]
        assert m["values"] == pytest.approx([3.0])


# -- rank accuracy on adversarial distributions -------------------------------


def _dist(name, rng, n=4000):
    if name == "constant":
        return [7.25] * n
    if name == "heavy_tail":
        return [rng.paretovariate(1.3) for _ in range(n)]
    if name == "negative":
        return [-abs(rng.lognormvariate(2, 1.5)) for _ in range(n)]
    if name == "counter_reset":
        # monotone counter that wraps to 0 every ~500 samples
        out, c = [], 0.0
        for i in range(n):
            c = 0.0 if i % 500 == 499 else c + rng.random() * 10
            out.append(c)
        return out
    if name == "zero_mixed":
        return [0.0 if rng.random() < 0.3
                else rng.gauss(0, 100) for _ in range(n)]
    raise AssertionError(name)


def _assert_rank_close(approx, exact, rel=0.02):
    assert approx == pytest.approx(exact, rel=rel, abs=1e-9)


@pytest.mark.parametrize("dist", ["constant", "heavy_tail", "negative",
                                  "counter_reset", "zero_mixed"])
def test_sketch_rank_error_within_2pct(dist):
    rng = random.Random(hash(dist) & 0xffff)
    vals = _dist(dist, rng)
    sk = QuantileSketch(CFG.sketch_rel_acc, CFG.sketch_max_bins)
    for v in vals:
        sk.insert(v)
    assert sk.count() == len(vals)
    for qname in QUANTILE_AGGS:
        q = quantile_of(qname)
        _assert_rank_close(sk.quantile(q), _exact_q(vals, q))


def test_sketch_skips_non_finite():
    sk = QuantileSketch(0.01, 2048)
    for v in (1.0, float("nan"), float("inf"), float("-inf"), 2.0, 3.0):
        sk.insert(v)
    assert sk.count() == 3
    _assert_rank_close(sk.quantile(0.5), 2.0)


def test_sketch_bin_cap_collapses_not_grows():
    sk = QuantileSketch(0.01, max_bins=16)
    rng = random.Random(3)
    vals = [rng.lognormvariate(0, 6) for _ in range(5000)]
    for v in vals:
        sk.insert(v)
    assert len(sk.pos) <= 16
    assert sk.count() == 5000
    # collapse eats the *smallest* keys, folding their mass upward — so
    # accuracy degrades (the documented trade for bounded memory) but the
    # structure stays sane: monotone, positive, biased toward the tail,
    # never under-reporting the high quantiles
    assert sk.quantile(0.99) >= sk.quantile(0.5) > 0
    assert sk.quantile(0.99) >= _exact_q(vals, 0.99) * 0.98
    # a production-sized budget keeps the same stream within the bound
    big = QuantileSketch(0.01, max_bins=2048)
    for v in vals:
        big.insert(v)
    _assert_rank_close(big.quantile(0.99), _exact_q(vals, 0.99))


# -- merge algebra -------------------------------------------------------------


def _merged(sketches):
    out = QuantileSketch(CFG.sketch_rel_acc, CFG.sketch_max_bins)
    for s in sketches:
        out.merge(s)
    return out


def _sketch_of(vals):
    sk = QuantileSketch(CFG.sketch_rel_acc, CFG.sketch_max_bins)
    for v in vals:
        sk.insert(v)
    return sk


def _state_key(sk):
    st8 = sk.to_state()
    return (st8["z"], tuple(sorted(st8["p"].items())),
            tuple(sorted(st8["n"].items())))


def test_sketch_merge_commutative_associative():
    rng = random.Random(11)
    chunks = [[rng.gauss(0, 50) for _ in range(rng.randrange(1, 400))]
              for _ in range(5)]
    sks = [_sketch_of(c) for c in chunks]
    orders = [sks, sks[::-1], [sks[2], sks[0], sks[4], sks[1], sks[3]]]
    keys = {_state_key(_merged(o)) for o in orders}
    assert len(keys) == 1               # bins identical, any merge order
    # associativity: ((a+b)+c) == (a+(b+c)) at the bin level
    ab = _merged(sks[:2]); ab.merge(sks[2])
    bc = _merged(sks[1:3])
    a_bc = _merged([sks[0]]); a_bc.merge(bc)
    assert _state_key(ab) == _state_key(a_bc)
    flat = [v for c in chunks for v in c]
    for qname in QUANTILE_AGGS:
        q = quantile_of(qname)
        _assert_rank_close(_merged(sks).quantile(q), _exact_q(flat, q))


def test_mixed_version_merge_degrades_gracefully():
    """Merging a sketchless peer's partial keeps scalars exact and turns
    quantiles into None — never a wrong number."""
    sk = SketchAgg(0.01, 2048)
    for i in range(100):
        sk.update(i * S, float(i))
    old = WindowAgg()                   # what an old peer federates
    for i in range(50):
        old.update(i * S, 1000.0 + i)
    merged = sk.fresh()
    merged.merge(sk)
    merged.merge(old)
    assert merged.count == 150
    assert merged.value("max") == 1049.0
    assert merged.value("mean") == pytest.approx(
        (sum(range(100)) + sum(1000.0 + i for i in range(50))) / 150)
    assert merged.value("p95") is None  # tainted, not fabricated


# -- property tier (hypothesis; skips cleanly when not installed) -------------


_floats = st.floats(min_value=-1e9, max_value=1e9,
                    allow_nan=False, allow_infinity=False, width=32)


@pytest.mark.stress
@settings(max_examples=50, deadline=None)
@given(st.lists(_floats, min_size=1, max_size=300),
       st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_property_merge_order_invariant(vals, seed):
    rng = random.Random(seed)
    cuts = sorted(rng.randrange(len(vals) + 1) for _ in range(3))
    parts = [vals[a:b] for a, b in
             zip([0] + cuts, cuts + [len(vals)])]
    sks = [_sketch_of(p) for p in parts]
    shuffled = sks[:]
    rng.shuffle(shuffled)
    assert _state_key(_merged(sks)) == _state_key(_merged(shuffled))
    assert _state_key(_merged(sks)) == _state_key(_sketch_of(vals))


@pytest.mark.stress
@settings(max_examples=50, deadline=None)
@given(st.lists(_floats, min_size=1, max_size=500))
def test_property_rank_error_bound(vals):
    sk = _sketch_of(vals)
    for qname in QUANTILE_AGGS:
        q = quantile_of(qname)
        _assert_rank_close(sk.quantile(q), _exact_q(vals, q))


# -- scalar aggregates must not move ------------------------------------------


def test_scalar_aggs_byte_identical_with_and_without_sketches():
    rng = random.Random(21)
    pts = _stream(rng, 1200)
    plain = Database("plain")
    sketched = Database("sk", CFG)
    plain.write(pts)
    sketched.write(pts)
    for agg in SCALAR_AGGS:
        assert sketched.aggregate("m", "v", agg=agg,
                                  group_by_tag="hostname") == \
            plain.aggregate("m", "v", agg=agg, group_by_tag="hostname")
        assert sketched.aggregate("m", "v", agg=agg, window_ns=10 * S) == \
            plain.aggregate("m", "v", agg=agg, window_ns=10 * S)
    # quantiles on an unsketched database: empty result, not an error
    assert plain.aggregate("m", "v", agg="p95") == {}


# -- federation / retention / cold / restart parity ---------------------------


@pytest.mark.parametrize("shards", list(range(1, 9)))
def test_p95_local_sharded_http_identical(shards):
    rng = random.Random(shards)
    pts = _stream(rng, 600)
    ref = Database("ref", CFG)
    sh = ShardedDatabase("s", shards=shards, rollup_config=CFG)
    ref.write(pts)
    _write_in_batches(sh, pts, random.Random(7 + shards))
    for qname in QUANTILE_AGGS:
        want = ref.aggregate("m", "v", agg=qname, group_by_tag="hostname")
        assert sh.aggregate("m", "v", agg=qname,
                            group_by_tag="hostname") == want
        assert sh.aggregate("m", "v", agg=qname, window_ns=10 * S) == \
            ref.aggregate("m", "v", agg=qname, window_ns=10 * S)
    # the scalar p95 matches the exact raw answer within the rank bound
    by_host: dict = {}
    for p in pts:
        by_host.setdefault(p.tags["hostname"], []).append(p.fields["v"])
    got = sh.aggregate("m", "v", agg="p95", group_by_tag="hostname")
    for h, vals in by_host.items():
        _assert_rank_close(got[h], _exact_q(vals, 0.95))


def test_p95_http_federated_equals_local():
    backend = TSDBServer(rollup_config=CFG)
    router = MetricsRouter(backend)
    rng = random.Random(5)
    pts = _stream(rng, 500)
    backend.db("global").write(pts)
    ref = Database("ref", CFG)
    ref.write(pts)
    with LMSHttpServer(router) as srv:
        client = HttpQueryClient(srv.url)
        assert client.rollup_config.sketched("m", "v")
        for win in (None, 10 * S):
            assert client.aggregate("m", "v", agg="p95",
                                    group_by_tag="hostname",
                                    window_ns=win) == \
                ref.aggregate("m", "v", agg="p95",
                              group_by_tag="hostname", window_ns=win)


def test_p95_survives_retention_served_from_rollups():
    rng = random.Random(13)
    pts = _stream(rng, 2000, hosts=1)
    vals = [p.fields["v"] for p in pts]
    db = Database("d", CFG)
    db.write(pts)
    exact = {q: _exact_q(vals, quantile_of(q)) for q in QUANTILE_AGGS}
    db.enforce_retention(max_points_per_series=4)
    assert db.stored_points() <= 4
    for qname, want in exact.items():
        out = db.aggregate("m", "v", agg=qname, use_rollups=True)
        _assert_rank_close(out[""], want)


def test_p95_over_cold_sealed_raw_scan(tmp_path):
    """A raw rescan over cold-sealed history rebuilds sketch-carrying
    aggregates (RollupConfig.new_agg), so use_rollups=False answers the
    same quantiles as the hot path did."""
    server = TSDBServer(persist_dir=str(tmp_path), cold=True,
                        rollup_config=CFG)
    rng = random.Random(17)
    now = now_ns()
    pts = [Point("m", {"hostname": "h0"}, {"v": rng.paretovariate(1.5)},
                 now - (800 - i) * S) for i in range(800)]
    vals = [p.fields["v"] for p in pts]
    server.write(pts, "global")
    db = server.db("global")
    hot = db.aggregate("m", "v", agg="p95", use_rollups=False)[""]
    report = server.enforce_retention(max_age_ns=400 * S)
    assert report["global"]["points_sealed"] > 0    # older half sealed
    cold = db.aggregate("m", "v", agg="p95", use_rollups=False)[""]
    assert cold == hot
    _assert_rank_close(cold, _exact_q(vals, 0.95))
    server.close()


@pytest.mark.parametrize("shards", [1, 3, 8])
def test_snapshot_recover_quantiles_restart_exact(tmp_path, shards):
    cfg = CFG
    a = TSDBServer(persist_dir=str(tmp_path), shards=shards,
                   rollup_config=cfg)
    rng = random.Random(shards + 40)
    pts = _stream(rng, 700)
    a.write(pts, "global")
    before = {(q, w): a.db("global").aggregate(
        "m", "v", agg=q, group_by_tag="hostname", window_ns=w)
        for q in QUANTILE_AGGS for w in (None, 10 * S)}
    a.snapshot()
    a.close()
    b = TSDBServer(persist_dir=str(tmp_path), shards=shards,
                   rollup_config=cfg)
    b.load_persisted()
    for (q, w), want in before.items():
        assert b.db("global").aggregate(
            "m", "v", agg=q, group_by_tag="hostname",
            window_ns=w) == want
    b.close()


# -- versioned wire form -------------------------------------------------------


def test_wire_form_versioning():
    # old 6-element state list decodes as a scalar aggregate
    wa = agg_from_state([3, 6.0, 1.0, 3.0, 2 * S, 3.0])
    assert type(wa) is WindowAgg and wa.count == 3
    # sketch-carrying state round-trips
    sk = SketchAgg(0.01, 2048)
    for i in range(200):
        sk.update(i * S, float(i + 1))
    back = agg_from_state(sk.state())
    assert back.state() == sk.state()
    assert back.value("p95") == sk.value("p95")
    # HTTP dict form: scalar dicts carry no sketch key (old peers can
    # ignore nothing), sketch dicts round-trip, old dicts still decode
    plain_d = windowagg_to_dict(WindowAgg())
    assert "sketch" not in plain_d
    d = windowagg_to_dict(sk)
    assert "sketch" in d
    rt = windowagg_from_dict(json.loads(json.dumps(d)))
    assert rt.value("p95") == sk.value("p95") and rt.count == sk.count
    old_d = {k: v for k, v in d.items() if k != "sketch"}
    old_wa = windowagg_from_dict(old_d)
    assert type(old_wa) is WindowAgg and old_wa.count == sk.count


# -- quantiles in the query/rules layer ---------------------------------------


def test_p95_in_queryspec_expression():
    db = Database("d", RollupConfig(sketch_fields={"hpm": ["flops"]}))
    rng = random.Random(9)
    flops = [abs(rng.gauss(100, 30)) for _ in range(300)]
    db.write([Point("hpm", {"hostname": "h0"}, {"flops": v}, i * S)
              for i, v in enumerate(flops)])
    spec = QuerySpec("hpm", ("tail=p95(flops) / 1e3",), window_ns=60 * S,
                     group_by="hostname")
    res = QueryEngine(db).query(spec)
    m = res.groups["h0"]["tail"]
    assert len(m["times"]) == 5
    for w0, got in zip(m["times"], m["values"]):
        window = flops[w0 // S:(w0 + 60 * S) // S]
        _assert_rank_close(got, _exact_q(window, 0.95) / 1e3)


def test_p95_in_threshold_rule_expr():
    from repro.core.analysis import ThresholdRule, evaluate_rules_on_db
    db = Database("d", RollupConfig(sketch_fields={"hpm": "*"}))
    # 1-in-10 steps stalls at 40s from t=30s on: the per-window p95 sees
    # the stall (40.0) while the per-window mean smears it to ~4.9
    pts = []
    for sec in range(120):
        for k in range(10):
            bad = 40.0 if (sec >= 30 and k == 9) else 1.0
            pts.append(Point("hpm", {"hostname": "h0"},
                             {"step_time_s": bad},
                             sec * S + k * (S // 10)))
    db.write(pts)
    tail = ThresholdRule("tail_latency", "hpm", "p95_step", ">", 10.0,
                         min_duration_s=30, expr="p95(step_time_s)")
    mean = ThresholdRule("mean_latency", "hpm", "step_time_s", ">", 10.0,
                         min_duration_s=30)
    findings = evaluate_rules_on_db(db, [tail, mean], use_rollups=True)
    assert any(f.rule == "tail_latency" for f in findings)
    assert not any(f.rule == "mean_latency" for f in findings)
    hit = next(f for f in findings if f.rule == "tail_latency")
    assert hit.duration_s >= 30


# -- /meta family + client fail-fast ------------------------------------------


def test_meta_rollups_and_client_validation():
    backend = TSDBServer(rollup_config=CFG)
    router = MetricsRouter(backend)
    backend.db("global").write([Point("m", {"hostname": "h"},
                                      {"v": 1.0, "u": 2.0}, S)])
    with LMSHttpServer(router) as srv:
        with urllib.request.urlopen(
                f"{srv.url}/meta?what=rollups") as r:
            meta = json.load(r)["rollups"]
        assert set(meta["aggs"]) >= set(SCALAR_AGGS) | set(QUANTILE_AGGS)
        assert meta["tiers_ns"] == list(CFG.tiers_ns)
        assert meta["sketch"]["gamma"] == pytest.approx(CFG.sketch_gamma)
        assert meta["sketch"]["fields"] == {"m": "*"}
        client = HttpQueryClient(srv.url)
        with pytest.raises(ValueError, match="median"):
            client.aggregate("m", "v", agg="median")
        # p95 on a measurement with no sketches: fail fast client-side
        with pytest.raises(ValueError, match="sketch_fields"):
            client.aggregate("hpm", "mfu", agg="p95")
        # sketched field passes validation and answers (within the
        # sketch's 1% relative value accuracy)
        _assert_rank_close(client.aggregate("m", "v", agg="p95")[""], 1.0)
        # old servers (no rollups meta): validation is skipped, not fatal
        client2 = HttpQueryClient(srv.url)
        client2._rollups_meta = None
        _assert_rank_close(client2.aggregate("m", "v", agg="p95")[""], 1.0)


# -- job fingerprints + the fleet rule ----------------------------------------


def _run_fp_job(stack, jid, scale):
    hosts = ["h0", "h1"]
    with stack.job(jid, user="alice", hosts=hosts,
                   tags={"jobname": "train"}):
        agents = [stack.host_agent(h, hlo_flops=5e14, model_flops=4e14,
                                   hlo_bytes=2e11, collective_bytes=1e10,
                                   tokens_per_step=1024) for h in hosts]
        t0 = now_ns()
        for step in range(25):
            for a in agents:
                a.collect_step(step=step, step_time_s=5.0 * scale,
                               extra_events={"data_wait_s": 0.1},
                               ts=t0 + step * 5 * S)


def test_fingerprint_fleet_rule_end_to_end(tmp_path):
    """Four healthy runs of a job family build the baseline; a fifth,
    pathological run (>3 sigma off the family's p95 fingerprint) is
    flagged through the normal /alerts surface."""
    stack = MonitoringStack.inprocess(
        out_dir=str(tmp_path), serve_http=True,
        rollup_config=RollupConfig(sketch_fields={"hpm": "*",
                                                  "system": "*"}))
    for i in range(4):
        _run_fp_job(stack, f"j{i}", 1.0)
    assert not [a for a in stack.analysis.alerts
                if a.rule == "fingerprint_outlier"]
    _run_fp_job(stack, "jbad", 40.0)
    hits = [a for a in stack.analysis.alerts
            if a.rule == "fingerprint_outlier"]
    assert len(hits) == 1 and hits[0].jobid == "jbad"
    assert stack.analysis.stats["fingerprints_written"] == 5
    assert stack.analysis.stats["fingerprint_outliers"] == 1
    with urllib.request.urlopen(f"{stack.http.url}/alerts") as r:
        rows = [a for a in json.load(r)["alerts"]
                if a["rule"] == "fingerprint_outlier"]
    assert rows and rows[0]["jobid"] == "jbad"
    assert "p95" in rows[0]["evidence"]


def test_fingerprint_persisted_and_loadable(tmp_path):
    from repro.core import job_fingerprint, load_fingerprints
    stack = MonitoringStack.inprocess(
        out_dir=str(tmp_path),
        rollup_config=RollupConfig(sketch_fields={"hpm": "*"}))
    _run_fp_job(stack, "j1", 1.0)
    db = stack.backend.db("global")
    fps = load_fingerprints(db, family="train")
    assert [e["jobid"] for e in fps] == ["j1"]
    fp = fps[0]["fingerprint"]
    assert "mfu" in fp and set(fp["mfu"]) == set(QUANTILE_AGGS)
    # recomputing from the rollups reproduces the persisted vector
    live = job_fingerprint(db, "j1")
    assert live["mfu"] == pytest.approx(fp["mfu"])
