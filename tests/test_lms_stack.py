"""MonitoringStack integration + dashboard agent + usermetric + perf groups."""

import json
import os

import pytest

from repro.core import (GROUPS, MonitoringStack, PerfGroup, Point, UserMetric,
                        now_ns, parse_group)
from repro.core.perf_groups import eval_formula


def _run_job(stack, *, idle_host=None, steps=40):
    hosts = [f"h{i}" for i in range(4)]
    with stack.job("j1", user="alice", hosts=hosts,
                   tags={"arch": "demo"}) as job:
        agents = [stack.host_agent(h, hlo_flops=5e14, model_flops=4e14,
                                   hlo_bytes=2e11, collective_bytes=1e10,
                                   tokens_per_step=1024) for h in hosts]
        um = stack.usermetric(host=hosts[0])
        um.event("run_state", "starting")
        t0 = now_ns()
        for step in range(steps):
            ts = t0 + step * 5 * 10**9
            for i, a in enumerate(agents):
                stt = 500.0 if (agents[i].hostname == idle_host
                                and step > 10) else 5.0
                a.collect_step(step=step, step_time_s=stt,
                               extra_events={"data_wait_s": 0.1}, ts=ts)
            um.metric("pressure", 42.0 + step, ts=ts)
        um.event("run_state", "finished")
        um.flush()
    return job


def test_healthy_job_no_findings(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    _run_job(stack)
    assert stack.findings() == []


def test_pathological_job_detected_live(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    seen = []
    stack.on_finding(seen.append)
    _run_job(stack, idle_host="h3")
    assert any(f.rule == "compute_break" and f.host == "h3"
               for f in stack.findings())
    assert seen, "on_finding callback must fire for instant feedback"


def test_dashboard_generation(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    job = _run_job(stack, idle_host="h3")
    path = stack.dashboards.write_dashboard(job)
    dash = json.load(open(path))["dashboard"]
    assert dash["header"]["status"] == "unhealthy"
    assert any(a["rule"] == "compute_break" for a in dash["header"]["analysis"])
    rows = {r["title"] for r in dash["rows"]}
    assert "HPM" in rows and "Analysis" in rows
    # app-level measurement got its own auto-generated row (paper §IV)
    assert any(r.startswith("app:pressure") for r in rows)
    html = open(os.path.join(str(tmp_path), "job_j1.html")).read()
    assert "polyline" in html and "unhealthy" in html


def test_admin_view(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    _run_job(stack, idle_host="h3")
    path = stack.dashboards.write_admin_view(stack.router.jobs.all_jobs())
    view = json.load(open(path))
    assert len(view["jobs"]) == 1
    assert view["jobs"][0]["status"] == "unhealthy"
    assert view["jobs"][0]["alerts"] >= 1


def test_per_job_database_duplication(tmp_path):
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    _run_job(stack)
    assert "job_j1" in stack.backend.databases()
    assert stack.backend.db("job_j1").point_count() > 0


def test_usermetric_batching():
    batches = []
    um = UserMetric(lambda pts: batches.append(list(pts)), batch_size=10,
                    flush_interval_s=9999, hostname="h")
    for i in range(25):
        um.metric("m", float(i))
    um.flush()
    assert [len(b) for b in batches] == [10, 10, 5]
    assert um.stats["sent_points"] == 25
    # default + per-call tags
    um2_pts = []
    um2 = UserMetric(um2_pts.extend, default_tags={"jobid": "x"},
                     hostname="h9")
    um2.metric("m", 1.0, tags={"thread": "7"})
    um2.flush()
    assert um2_pts[0].tags == {"hostname": "h9", "jobid": "x", "thread": "7"}


def test_usermetric_region_timing():
    pts = []
    um = UserMetric(pts.extend, hostname="h")
    with um.region("phase1"):
        pass
    um.flush()
    assert pts[0].measurement == "phase1_time_s"
    assert pts[0].fields["value"] >= 0


def test_parse_custom_group():
    g = parse_group("""
    GROUP CUSTOM
    DESC my metrics
    EVENTSET
      ev_a
      ev_b
    METRICS
      ratio   ev_a / ev_b
      scaled  ev_a * 2.0 + min(ev_b, 10)
    """)
    assert isinstance(g, PerfGroup)
    out = g.derive({"ev_a": 6.0, "ev_b": 3.0})
    assert out == {"ratio": 2.0, "scaled": 15.0}
    # missing events skip metrics (non-strict)
    assert g.derive({"ev_a": 6.0}) == {}


def test_formula_eval_safety():
    with pytest.raises(Exception):
        eval_formula("__import__('os').system('true')", {})
    with pytest.raises(Exception):
        eval_formula("a.b", {"a": 1})
    assert eval_formula("PEAK_FLOPS / PEAK_FLOPS", {}) == 1.0


def test_builtin_groups_exist():
    assert {"FLOPS", "MEM", "ICI", "GOODPUT"} <= set(GROUPS)
