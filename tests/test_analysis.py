"""Pathological-job rules, pattern decision tree, roofline analyzer."""

import pytest

from repro.core.analysis import (DEFAULT_TREE, RooflineAnalyzer,
                                 StreamAnalyzer, ThresholdRule, classify_job,
                                 default_rules, evaluate_rule,
                                 evaluate_rules_on_db)
from repro.core.line_protocol import Point
from repro.core.perf_groups import HBM_BW, ICI_BW, PEAK_FLOPS, derive_all
from repro.core.tsdb import Database

S = 1_000_000_000   # ns


def test_threshold_timeout_fig4():
    """Paper Fig. 4: metric below threshold for > timeout => finding."""
    rule = ThresholdRule("break", "hpm", "mfu", "<", 0.05, 600.0)
    times = [i * 60 * S for i in range(40)]             # one point a minute
    values = [0.5] * 10 + [0.01] * 15 + [0.5] * 15      # 15 min dip
    fs = evaluate_rule(rule, times, values, "h0")
    assert len(fs) == 1
    assert fs[0].duration_s >= 600
    # a dip shorter than the timeout is NOT a finding
    values = [0.5] * 10 + [0.01] * 5 + [0.5] * 25
    assert evaluate_rule(rule, times, values) == []


def test_nan_counts_as_below():
    rule = ThresholdRule("break", "hpm", "loss", "<", 1e9, 1.0)
    assert rule.check(float("nan"))


def test_open_ended_finding():
    rule = ThresholdRule("break", "hpm", "mfu", "<", 0.05, 600.0)
    times = [i * 60 * S for i in range(20)]
    values = [0.01] * 20                                 # never recovers
    fs = evaluate_rule(rule, times, values)
    assert len(fs) == 1


def test_stream_analyzer_fires_once():
    an = StreamAnalyzer([ThresholdRule("idle", "hpm", "mfu", "<", 0.05,
                                       60.0)])
    for i in range(30):
        an.observe(Point("hpm", {"hostname": "h0"}, {"mfu": 0.01},
                         i * 10 * S))
    assert len(an.findings) == 1
    assert an.findings[0].host == "h0"
    # recovery resets the state -> a second episode fires again
    an.observe(Point("hpm", {"hostname": "h0"}, {"mfu": 0.9}, 301 * S))
    for i in range(30):
        an.observe(Point("hpm", {"hostname": "h0"}, {"mfu": 0.01},
                         (310 + i * 10) * S))
    assert len(an.findings) == 2


def test_rules_on_db_group_by_host():
    db = Database("t")
    for host, mfu in (("h0", 0.5), ("h1", 0.001)):
        db.write([Point("hpm", {"hostname": host, "jobid": "j"},
                        {"mfu": mfu}, i * 120 * S) for i in range(10)])
    fs = evaluate_rules_on_db(db, default_rules(), jobid="j")
    assert {f.host for f in fs if f.rule == "compute_break"} == {"h1"}


def test_decision_tree_branches():
    cases = [
        ({"data_stall_frac": 0.5}, "ingest-bound"),
        ({"straggler_skew": 0.3}, "load-imbalance"),
        ({"collective_frac": 0.6}, "collective-bound"),
        ({"memory_frac": 0.8, "useful_flop_ratio": 0.3},
         "recompute-heavy memory-bound"),
        ({"memory_frac": 0.8, "useful_flop_ratio": 0.9}, "memory-bound"),
        ({"memory_frac": 0.2, "collective_frac": 0.1, "mfu": 0.1},
         "latency/overhead-bound"),
        ({"memory_frac": 0.2, "collective_frac": 0.1, "mfu": 0.6},
         "compute-bound"),
    ]
    for metrics, want in cases:
        out = classify_job(metrics)
        assert out["pattern"] == want, (metrics, out)
        assert out["remedy"]
        assert out["path"]


def test_roofline_terms():
    an = RooflineAnalyzer()
    r = an.analyze(arch="a", shape="s", mesh="m", chips=256,
                   hlo_flops=256 * PEAK_FLOPS,          # 1 s of compute
                   hbm_bytes=256 * HBM_BW * 2,          # 2 s of memory
                   collective_bytes=256 * ICI_BW * 0.5,
                   model_flops=128 * PEAK_FLOPS)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.bound_s == pytest.approx(2.0)
    assert r.useful_flop_ratio == pytest.approx(0.5)
    cls = r.classify()
    assert cls["pattern"] in ("memory-bound", "recompute-heavy memory-bound")


def test_perf_groups_derive():
    raw = {"hlo_flops": 1e15, "model_flops": 8e14, "step_time_s": 2.0,
           "hlo_bytes": 1e12, "collective_bytes": 1e11,
           "tokens_per_step": 1e6, "data_wait_s": 0.2,
           "hbm_bytes_in_use": 8e9}
    d = derive_all(raw)
    assert d["gflops_per_s"] == pytest.approx(5e5)
    assert d["mfu"] == pytest.approx(8e14 / 2.0 / PEAK_FLOPS)
    assert d["useful_flop_ratio"] == pytest.approx(0.8)
    assert d["tokens_per_s"] == pytest.approx(5e5)
    assert d["data_stall_frac"] == pytest.approx(0.1)
    assert d["hbm_used_gb"] == pytest.approx(8.0)
