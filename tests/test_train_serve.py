"""End-to-end: monitored training loop (+failure/restart) and serving."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, TrainConfig, get_config
from repro.core import MonitoringStack
from repro.models.transformer import init_model_params
from repro.serve.engine import ServingEngine
from repro.train.loop import InjectedFailure, TrainResult, train

TINY = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def test_train_loss_decreases(tmp_path):
    cfg = get_config("lms-demo", smoke=True)
    tcfg = TrainConfig(total_steps=8, warmup_steps=1, learning_rate=5e-3)
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    losses = []
    r = train(cfg, tcfg, TINY, stack=stack,
              step_callback=lambda s, m: losses.append(float(m["loss"])))
    assert r.steps_run == 8
    assert losses[-1] < losses[0]
    db = stack.backend.db("global")
    assert "hpm" in db.measurements() and "train" in db.measurements()
    # HPM points carry derived perf-group metrics with job tags
    s = db.select("hpm", ["mfu"])[0]
    assert "jobid" in s.tags


def test_failure_injection_and_resume(tmp_path):
    cfg = get_config("lms-demo", smoke=True)
    ck = str(tmp_path / "ck")
    tcfg = TrainConfig(total_steps=6, warmup_steps=1, ckpt_dir=ck,
                       ckpt_interval=2)
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "l1"))
    with pytest.raises(InjectedFailure):
        train(cfg, tcfg, TINY, stack=stack, fail_at_step=4, job_id="j")
    # restart resumes from the last atomic checkpoint and finishes
    stack2 = MonitoringStack.inprocess(out_dir=str(tmp_path / "l2"))
    r = train(cfg, tcfg, TINY, stack=stack2, job_id="j2")
    assert r.resumed_from == 4
    assert r.final_step == 6
    assert not math.isnan(r.last_loss)
    # restart event recorded for the dashboards
    ev = stack2.backend.db("global").select("run_state")
    texts = [v for s in ev for v in s.values["event"]]
    assert any("starting" in t and "step 4" in t for t in texts)


def test_deterministic_replay_after_resume(tmp_path):
    """Data source is step-keyed: a resumed run sees the same batches."""
    from repro.data import SyntheticTokenSource
    src = SyntheticTokenSource(100, seed=0)
    a = src.batch(5, 4, 8)
    b = src.batch(5, 4, 8)
    np.testing.assert_array_equal(a, b)


def test_serving_engine(tmp_path):
    cfg = get_config("lms-demo", smoke=True)
    params = init_model_params(cfg, seed=0)
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    with stack.job("serve1", user="u", hosts=["h0"]):
        um = stack.usermetric(host="h0")
        eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                            usermetric=um, jit=False)
        rids = [eng.submit(np.arange(1, 5 + i), max_new_tokens=6)
                for i in range(5)]
        done = eng.run_until_empty()
        um.flush()
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)
    assert all(r.first_token_at is not None for r in done)
    db = stack.backend.db("global")
    assert "serve_request" in db.measurements()
    assert "serve_decode" in db.measurements()
    # per-request latency metrics tagged with the job
    s = db.select("serve_request")[0]
    assert s.tags["jobid"] == "serve1"


def test_serving_greedy_consistency():
    """Engine output == manual prefill+argmax loop (same params)."""
    cfg = get_config("lms-demo", smoke=True)
    params = init_model_params(cfg, seed=0)
    from repro.models.transformer import forward, init_cache
    prompt = np.arange(1, 9, dtype=np.int32)

    eng = ServingEngine(cfg, params, max_batch=1, max_len=32, jit=False)
    eng.submit(prompt, max_new_tokens=4)
    out = eng.run_until_empty()[0].output

    cache = init_cache(cfg, 1, 32)
    logits, cache, _ = forward(params, cfg, tokens=jnp.asarray(prompt)[None],
                               mode="prefill", cache=cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        logits, cache, _ = forward(params, cfg,
                                   tokens=jnp.asarray([[toks[-1]]]),
                                   mode="decode", cache=cache,
                                   pos=jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    assert out == toks


def test_straggler_finding_triggers_elastic_halt(tmp_path):
    """Monitoring is load-bearing: a sustained straggler finding (emitted by
    a simulated peer host) halts the loop so the launcher can restart
    elastically without the slow host."""
    from repro.core import Point, now_ns

    cfg = get_config("lms-demo", smoke=True)
    tcfg = TrainConfig(total_steps=50, warmup_steps=1,
                       halt_on_straggler=True)
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))

    t0 = now_ns()

    def inject_straggler(step, metrics):
        # a peer host reports sustained step-time skew (simulated timeline
        # so the 30 s timeout of the rule elapses immediately)
        stack.router.write(Point(
            "hpm", {"hostname": "peer-h9"},
            {"straggler_skew": 0.5}, t0 + step * 40 * 10 ** 9))

    r = train(cfg, tcfg, TINY, stack=stack, step_callback=inject_straggler,
              job_id="strag")
    assert r.steps_run < 50, "loop should halt early"
    assert any(f.rule == "step_time_straggler" for f in r.findings)
    ev = stack.backend.db("global").select("run_state")
    texts = [v for s in ev for v in s.values["event"]]
    assert any("halt: straggler:peer-h9" in t for t in texts)


def test_train_markers_roofline_end_to_end(tmp_path):
    """ROADMAP item 3 acceptance: train with markers on, then one
    roofline QuerySpec answers per-region fractions from the TSDB."""
    from repro.core.marker import MARKER_MEASUREMENT, roofline_spec

    cfg = get_config("lms-demo", smoke=True)
    tcfg = TrainConfig(total_steps=6, warmup_steps=1)
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path))
    try:
        r = train(cfg, tcfg, TINY, stack=stack, job_id="mk-e2e")
        assert r.steps_run == 6
        db = stack.backend.db("global")
        regions = set(db.tag_values(MARKER_MEASUREMENT, "region"))
        assert {"train_step", "data_wait"} <= regions
        # marker points get job enrichment like every other measurement
        s = db.select(MARKER_MEASUREMENT, ["time_s"],
                      tags={"region": "train_step"})[0]
        assert s.tags.get("jobid") == "mk-e2e"
        # the one canonical spec, served by the query engine
        res = stack.backend.query_engine("global").query(
            roofline_spec("mk-e2e"))
        g = res.groups["train_step"]
        fracs = [v for v in g["roofline_frac"]["values"] if v is not None]
        assert fracs and all(f > 0.0 for f in fracs)
        # data_wait carries no flops/bytes: timing only, no roofline
        assert "roofline_frac" not in res.groups["data_wait"]
    finally:
        stack.close()
