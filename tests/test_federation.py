"""Federated scatter-gather over HTTP: full-stack e2e on a sharded
backend, plus cross-instance federation via ``HttpQueryClient``.

The multi-node story (``docs/ARCHITECTURE.md``): inside one LMS instance
the backend shards; across instances, ``FederatedQuery`` fans ``/query``
partials requests to each router and merges them with the same WindowAgg
semantics the shards use — so the whole deployment answers like one
database.
"""

import json
import urllib.request

import pytest

from repro.core import MonitoringStack
from repro.core.httpd import HttpQueryClient, HttpSink, LMSHttpServer
from repro.core.line_protocol import Point
from repro.core.shard import FederatedQuery, ShardedDatabase
from repro.core.tsdb import Database

S = 1_000_000_000


def _get_json(url: str) -> dict:
    with urllib.request.urlopen(url) as r:
        return json.loads(r.read())


def test_http_end_to_end_sharded_stack(tmp_path):
    """job_start -> batched /write from several hosts -> /query with and
    without window_ns -> dashboard -> job_end, all against a 4-shard
    backend: tag enrichment and job annotations must survive sharding."""
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "dash"),
                                      shards=4)
    hosts = [f"h{i}" for i in range(3)]
    db = stack.backend.db("global")
    assert isinstance(db, ShardedDatabase)
    with LMSHttpServer(stack.router) as srv:
        sink = HttpSink(srv.url)
        sink.job_start("jF", "ada", hosts, {"arch": "demo"})
        for h_i, h in enumerate(hosts):     # one batched POST per host
            sink.write([Point("hpm", {"hostname": h},
                              {"mfu": 0.3 + 0.1 * h_i, "step": float(s)},
                              s * S)
                        for s in range(30)])
        base = (f"{srv.url}/query?m=hpm&field=mfu&group_by=hostname"
                f"&tag_jobid=jF")
        # scalar /query scatter-gathers across the shards
        out = _get_json(base + "&agg=mean")["result"]
        assert set(out) == set(hosts)
        assert out["h1"] == pytest.approx(0.4)
        # windowed /query (rollup-served through the federation)
        out = _get_json(base + f"&agg=mean&window_ns={10 * S}")["result"]
        starts, vals = out["h2"]
        assert starts == [0, 10 * S, 20 * S]
        assert vals == pytest.approx([0.5, 0.5, 0.5])
        # mergeable partials (the cross-instance scatter wire form)
        resp = _get_json(base + f"&partials=1&window_ns={10 * S}")
        assert resp["windowed"] is True
        assert resp["partials"]["h0"][str(10 * S)]["count"] == 10
        # tag enrichment survived sharding: every series carries job tags
        series = db.select("hpm", ["mfu"], {"jobid": "jF"})
        assert len(series) == len(hosts)
        for s in series:
            assert s.tags["username"] == "ada" and s.tags["arch"] == "demo"
        # dashboard agent reads through the federated view
        job = stack.router.jobs.get("jF")
        dash = stack.dashboards.build_dashboard(job)
        titles = [r["title"] for r in dash["dashboard"]["rows"]]
        assert "HPM" in titles
        assert dash["dashboard"]["annotations"]["targets"][0][
            "tags"]["jobid"] == "jF"
        html = stack.dashboards.render_html(job, dash)
        assert "svg" in html
        sink.job_end("jF")
    # job annotations (start + end events) survive sharding
    ev = db.select("job_event", None, {"jobid": "jF"})
    vals = sorted(v for s in ev for v in s.values["event"])
    assert vals == ["end", "start"]
    # analysis layer is shard-transparent too (no findings on healthy data)
    from repro.core.analysis import default_rules, evaluate_rules_on_db
    assert evaluate_rules_on_db(db, default_rules(), jobid="jF") == []


def test_federated_query_across_router_instances(tmp_path):
    """Two independent LMS router instances (each itself sharded), hosts
    split between them; FederatedQuery over HttpQueryClients answers
    exactly like one database holding the union of the points."""
    stacks = [MonitoringStack.inprocess(out_dir=str(tmp_path / f"d{i}"),
                                        shards=2) for i in range(2)]
    ref = Database("ref")
    pts_per_host = 40
    all_hosts = [f"h{i}" for i in range(4)]
    for inst, stack in enumerate(stacks):
        for h in all_hosts[inst * 2:(inst + 1) * 2]:
            pts = [Point("hpm", {"hostname": h},
                         {"mfu": 0.2 + 0.05 * int(h[1:]) + 0.001 * s,
                          "step": float(s)}, s * S)
                   for s in range(pts_per_host)]
            stack.router.write(pts)
            ref.write(pts)
    with LMSHttpServer(stacks[0].router) as sa, \
            LMSHttpServer(stacks[1].router) as sb:
        fed = FederatedQuery([HttpQueryClient(sa.url),
                              HttpQueryClient(sb.url)])
        # scalar: mean merges as (sum, count); last as lexicographic (t, v)
        for agg in ("mean", "max", "min", "sum", "count", "last"):
            got = fed.aggregate("hpm", "mfu", agg=agg,
                                group_by_tag="hostname")
            want = ref.aggregate("hpm", "mfu", agg=agg,
                                 group_by_tag="hostname")
            assert set(got) == set(all_hosts)
            for g in want:
                assert got[g] == pytest.approx(want[g], rel=1e-9), (agg, g)
        # windowed: rollup-tier summaries merged across instances
        got = fed.aggregate("hpm", "mfu", agg="max", window_ns=10 * S)
        want = ref.aggregate("hpm", "mfu", agg="max", window_ns=10 * S)
        assert got[""][0] == want[""][0]
        assert got[""][1] == pytest.approx(want[""][1])
        # select fans out; each host's series comes from exactly one side
        series = fed.select("hpm", ["mfu"], {"hostname": "h2"})
        assert len(series) == 1 and len(series[0].times) == pts_per_host
        # fields=None returns every field (events!), not a silent miss on
        # a fabricated "value" field; multi-field is a loud error
        [s] = fed.select("hpm", None, {"hostname": "h2"})
        assert set(s.values) == {"mfu", "step"}
        with pytest.raises(ValueError):
            fed.select("hpm", ["mfu", "step"], {"hostname": "h2"})
        # meta queries federate as unions / sums — remote included
        assert "hpm" in fed.measurements()
        assert "mfu" in fed.field_keys("hpm")
        assert fed.tag_values("hpm", "hostname") == all_hosts
        assert fed.point_count() == ref.point_count()
        # rollup-served windows keep answering after raw retention upstream
        for stack in stacks:
            stack.backend.db("global").enforce_retention(
                max_points_per_series=2)
        after = fed.aggregate("hpm", "mfu", agg="count", window_ns=10 * S,
                              use_rollups=True)
        assert sum(after[""][1]) == len(all_hosts) * pts_per_host
        # a forced-rollup window no tier serves raises remotely like locally
        with pytest.raises(ValueError):
            fed.aggregate("hpm", "mfu", agg="sum", window_ns=S // 2,
                          use_rollups=True)


def test_http_query_client_roundtrips_partials(tmp_path):
    """decode(encode(partials)) over a live server equals the local
    partials — count/sum/min/max/last_t/last_v all intact."""
    stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "d"), shards=3)
    pts = [Point("m", {"hostname": f"h{i % 2}"}, {"v": float(i)},
                 i * S) for i in range(25)]
    stack.router.write(pts)
    db = stack.backend.db("global")
    with LMSHttpServer(stack.router) as srv:
        client = HttpQueryClient(srv.url)
        local = db.aggregate_partials("m", "v", group_by_tag="hostname",
                                      window_ns=10 * S)
        remote = client.aggregate_partials("m", "v",
                                           group_by_tag="hostname",
                                           window_ns=10 * S)
        assert set(remote) == set(local)
        for g in local:
            assert set(remote[g]) == set(local[g])
            for w0, wa in local[g].items():
                rw = remote[g][w0]
                assert (rw.count, rw.sum, rw.min, rw.max, rw.last_t,
                        rw.last_v) == (wa.count, wa.sum, wa.min, wa.max,
                                       wa.last_t, wa.last_v)
        # scalar partials too
        local_s = db.aggregate_partials("m", "v")
        remote_s = client.aggregate_partials("m", "v")
        assert remote_s[""].count == local_s[""].count == 25
        assert remote_s[""].sum == local_s[""].sum
        # rollup partials with the default (finest-tier) window must come
        # back window-shaped, not scalar-shaped (regression: the client
        # used to route this through the raw scalar scan)
        local_r = db.rollup_window_partials("m", "v")
        remote_r = client.rollup_window_partials("m", "v")
        assert set(remote_r[""]) == set(local_r[""])      # window starts
        fed = FederatedQuery([client])
        got = fed.rollup_aggregate("m", "v", agg="count")
        want = db.rollup_aggregate("m", "v", agg="count")
        assert got == want


def test_remote_backend_full_rollup_surface(tmp_path):
    """Mixed local+remote federations drive the whole rollup-aware read
    path — rule evaluation and dashboard tier selection need
    rollup_config / rollup_series / rollup_window_count on remotes too
    (regression: HttpQueryClient used to expose none of them)."""
    from repro.core.analysis import default_rules, evaluate_rules_on_db
    remote_stack = MonitoringStack.inprocess(out_dir=str(tmp_path / "r"),
                                             shards=2)
    local = Database("local")
    bad = [Point("hpm", {"hostname": "h_remote"}, {"mfu": 0.001}, i * S)
           for i in range(120)]
    remote_stack.router.write(bad)
    local.write([Point("hpm", {"hostname": "h_local"}, {"mfu": 0.001},
                       i * S) for i in range(120)])
    with LMSHttpServer(remote_stack.router) as srv:
        client = HttpQueryClient(srv.url)
        # remote config is fetched and cached; federation exposes it
        assert client.rollup_config is not None
        fed = FederatedQuery([local, client])
        assert fed.rollup_config is not None
        # per-series rollup readout across the wire
        series = fed.rollup_series("hpm", "mfu")
        assert {s.tags["hostname"] for s in series} == \
            {"h_local", "h_remote"}
        assert fed.rollup_window_count("hpm", "mfu") == \
            local.rollup_window_count("hpm", "mfu") * 2
        # forced rollup-backed rule evaluation sees BOTH sides' breakage,
        # even after raw retention upstream
        remote_stack.backend.db("global").enforce_retention(
            max_points_per_series=2)
        findings = evaluate_rules_on_db(fed, default_rules(),
                                        use_rollups=True)
        hosts = {f.host for f in findings if f.rule == "compute_break"}
        assert hosts == {"h_local", "h_remote"}
