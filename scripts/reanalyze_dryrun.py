"""Re-run the HLO cost walker over saved dry-run HLO (no recompilation) and
rewrite the per-cell JSONs (hlo_analysis + roofline sections)."""
import glob
import gzip
import json
import sys

sys.path.insert(0, "src")

from repro.core.analysis import RooflineAnalyzer
from repro.launch.hlo_analysis import analyze_hlo


def main():
    for path in sorted(glob.glob("results/dryrun/*/*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        hlo_path = path.replace(".json", ".hlo.txt.gz")
        try:
            text = gzip.open(hlo_path, "rt").read()
        except FileNotFoundError:
            print(f"no HLO for {path}; skipping")
            continue
        hlo = analyze_hlo(text)
        rec["hlo_analysis"] = hlo
        chips = rec["roofline"]["chips"]
        model_flops = rec["roofline"]["model_flops"]
        roof = RooflineAnalyzer().analyze(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=chips, hlo_flops=hlo["global"]["flops"],
            hbm_bytes=hlo["global"]["bytes_fused"],
            collective_bytes=hlo["global"]["collective_wire_bytes"],
            model_flops=model_flops)
        rec["roofline"].update({
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "dominant": roof.dominant,
            "bound_step_s": roof.bound_s, "hlo_flops": roof.hlo_flops,
            "useful_flop_ratio": roof.useful_flop_ratio,
            "collective_operand_bytes_global":
                hlo["global"]["collective_operand_bytes"],
            "classification": roof.classify(),
        })
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        r = rec["roofline"]
        print(f"{rec['mesh']:11s} {rec['arch']:24s} {rec['shape']:12s} "
              f"c={r['compute_s']:.3f} m={r['memory_s']:.3f} "
              f"x={r['collective_s']:.3f} dom={r['dominant']}")


if __name__ == "__main__":
    main()
