#!/usr/bin/env bash
# CI gate: the static invariant analyzer (zero unsuppressed findings on
# src/repro/core), clean test collection (hard requirement — a module
# that fails to import takes its whole file's tests with it silently),
# the fast unit tier under a timeout, the bounded stress/property tier,
# the bounded crash-injection tier (SIGKILL a writer subprocess
# mid-write, recover, check invariants), then the dynamic race tier
# (run the stack under repro.core.locktrace and cross-check observed
# lock orders against the static lock graph).  See tests/README.md.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[1/6] invariant analyzer (scripts/lms_lint.py src/repro/core)"
python scripts/lms_lint.py src/repro/core

echo "[2/6] collection gate (pytest --collect-only)"
python -m pytest --collect-only -q tests/ > /dev/null

echo "[3/6] fast unit tier (timeout ${CI_FAST_TIMEOUT:-600}s)"
timeout "${CI_FAST_TIMEOUT:-600}" python -m pytest -q \
    -m "not stress and not crash and not race" \
    tests/test_line_protocol.py \
    tests/test_tsdb.py \
    tests/test_rollup.py \
    tests/test_shard.py \
    tests/test_wal.py \
    tests/test_router.py \
    tests/test_ingest.py \
    tests/test_federation.py \
    tests/test_lms_stack.py \
    tests/test_query.py \
    tests/test_analysis.py \
    tests/test_analysis_engine.py \
    tests/test_coldstore.py \
    tests/test_analyzer.py

echo "[4/6] stress/property tier (bounded; timeout ${CI_STRESS_TIMEOUT:-600}s)"
# Bounded example counts keep CI deterministic-ish and quick; raise the
# bounds locally to soak (LMS_STRESS_SCALE=10 LMS_PROPERTY_EXAMPLES=500).
LMS_STRESS_SCALE="${LMS_STRESS_SCALE:-1}" \
LMS_PROPERTY_EXAMPLES="${LMS_PROPERTY_EXAMPLES:-30}" \
timeout "${CI_STRESS_TIMEOUT:-600}" python -m pytest -q -m stress tests/

echo "[5/6] crash-injection tier (bounded; timeout ${CI_CRASH_TIMEOUT:-300}s)"
# Real SIGKILLs against a WAL writer subprocess; raise LMS_CRASH_ITERS
# locally to soak (LMS_CRASH_ITERS=20).
LMS_CRASH_ITERS="${LMS_CRASH_ITERS:-3}" \
timeout "${CI_CRASH_TIMEOUT:-300}" python -m pytest -q -m crash tests/

echo "[6/6] race tier (timeout ${CI_RACE_TIMEOUT:-300}s)"
timeout "${CI_RACE_TIMEOUT:-300}" python -m pytest -q -m race tests/

echo "ci_check: OK"
