#!/usr/bin/env bash
# CI gate: clean test collection (hard requirement — a module that fails
# to import takes its whole file's tests with it silently), the fast
# unit tier under a timeout, then the bounded stress/property tier.
# See tests/README.md for the tier layout.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[1/3] collection gate (pytest --collect-only)"
python -m pytest --collect-only -q tests/ > /dev/null

echo "[2/3] fast unit tier (timeout ${CI_FAST_TIMEOUT:-600}s)"
timeout "${CI_FAST_TIMEOUT:-600}" python -m pytest -q -m "not stress" \
    tests/test_line_protocol.py \
    tests/test_tsdb.py \
    tests/test_rollup.py \
    tests/test_shard.py \
    tests/test_router.py \
    tests/test_federation.py \
    tests/test_lms_stack.py \
    tests/test_analysis.py

echo "[3/3] stress/property tier (bounded; timeout ${CI_STRESS_TIMEOUT:-600}s)"
# Bounded example counts keep CI deterministic-ish and quick; raise the
# bounds locally to soak (LMS_STRESS_SCALE=10 LMS_PROPERTY_EXAMPLES=500).
LMS_STRESS_SCALE="${LMS_STRESS_SCALE:-1}" \
LMS_PROPERTY_EXAMPLES="${LMS_PROPERTY_EXAMPLES:-30}" \
timeout "${CI_STRESS_TIMEOUT:-600}" python -m pytest -q -m stress tests/

echo "ci_check: OK"
