#!/usr/bin/env bash
# CI gate: clean test collection (hard requirement — a module that fails
# to import takes its whole file's tests with it silently) plus the fast
# unit tier under a timeout.  See tests/README.md for the tier layout.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[1/2] collection gate (pytest --collect-only)"
python -m pytest --collect-only -q tests/ > /dev/null

echo "[2/2] fast unit tier (timeout ${CI_FAST_TIMEOUT:-600}s)"
timeout "${CI_FAST_TIMEOUT:-600}" python -m pytest -q \
    tests/test_line_protocol.py \
    tests/test_tsdb.py \
    tests/test_rollup.py \
    tests/test_router.py \
    tests/test_lms_stack.py \
    tests/test_analysis.py

echo "ci_check: OK"
