#!/usr/bin/env bash
# CI gate: the static invariant analyzer (zero unsuppressed findings on
# src/repro/core), clean test collection (hard requirement — a module
# that fails to import takes its whole file's tests with it silently),
# the fast unit tier under a timeout, the bounded stress/property tier,
# the bounded crash-injection tier (SIGKILL a writer subprocess
# mid-write, recover, check invariants), the dynamic race tier
# (run the stack under repro.core.locktrace and cross-check observed
# lock orders against the static lock graph), then the benchmarks
# (quantile sketches: rollup-served p95 vs raw rescan + the >=90%
# sketched-ingest retention bar; markers: <=5% instrumented-step
# overhead + rollup-served roofline query speedup — printed for the
# reviewer).  See tests/README.md.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[1/7] invariant analyzer (scripts/lms_lint.py src/repro/core)"
python scripts/lms_lint.py src/repro/core

echo "[2/7] collection gate (pytest --collect-only)"
python -m pytest --collect-only -q tests/ > /dev/null

echo "[3/7] fast unit tier (timeout ${CI_FAST_TIMEOUT:-600}s)"
timeout "${CI_FAST_TIMEOUT:-600}" python -m pytest -q \
    -m "not stress and not crash and not race" \
    tests/test_line_protocol.py \
    tests/test_tsdb.py \
    tests/test_rollup.py \
    tests/test_shard.py \
    tests/test_wal.py \
    tests/test_router.py \
    tests/test_ingest.py \
    tests/test_federation.py \
    tests/test_lms_stack.py \
    tests/test_query.py \
    tests/test_analysis.py \
    tests/test_analysis_engine.py \
    tests/test_coldstore.py \
    tests/test_analyzer.py \
    tests/test_quantile_sketch.py \
    tests/test_marker.py

echo "[4/7] stress/property tier (bounded; timeout ${CI_STRESS_TIMEOUT:-600}s)"
# Bounded example counts keep CI deterministic-ish and quick; raise the
# bounds locally to soak (LMS_STRESS_SCALE=10 LMS_PROPERTY_EXAMPLES=500).
LMS_STRESS_SCALE="${LMS_STRESS_SCALE:-1}" \
LMS_PROPERTY_EXAMPLES="${LMS_PROPERTY_EXAMPLES:-30}" \
timeout "${CI_STRESS_TIMEOUT:-600}" python -m pytest -q -m stress tests/

echo "[5/7] crash-injection tier (bounded; timeout ${CI_CRASH_TIMEOUT:-300}s)"
# Real SIGKILLs against a WAL writer subprocess; raise LMS_CRASH_ITERS
# locally to soak (LMS_CRASH_ITERS=20).
LMS_CRASH_ITERS="${LMS_CRASH_ITERS:-3}" \
timeout "${CI_CRASH_TIMEOUT:-300}" python -m pytest -q -m crash tests/

echo "[6/7] race tier (timeout ${CI_RACE_TIMEOUT:-300}s)"
timeout "${CI_RACE_TIMEOUT:-300}" python -m pytest -q -m race tests/

echo "[7/7] benchmarks (timeout ${CI_BENCH_TIMEOUT:-600}s)"
# bench_quantile_sketch prints the rollup-served p95 vs raw-rescan
# ratio and the sketched ingest retention (target >=90% of scalar-only
# ingest); bench_marker_roofline prints the marked-vs-unmarked train
# step delta (<=5% bar) and the rollup-served roofline query speedup.
# Timing bars are advisory on shared CI hardware, so the gate is that
# the benchmarks run to completion, not the ratios themselves.
timeout "${CI_BENCH_TIMEOUT:-600}" python -m benchmarks.run \
    bench_quantile_sketch bench_marker_roofline

echo "ci_check: OK"
