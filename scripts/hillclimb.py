import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb harness: lower a (cell x variant), report the three
roofline terms + memory.  Results append to results/hillclimb.jsonl.

    PYTHONPATH=src python scripts/hillclimb.py granite-3-8b train_4k \
        baseline recursive remat_none nm4
"""

import dataclasses
import json
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import SHAPES, TrainConfig, get_config  # noqa: E402
from repro.core.perf_groups import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402
from repro.launch.dryrun import default_train_cfg, model_flops_for  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_bundle, lower_bundle  # noqa: E402


def variant_cfg(name: str, base: TrainConfig) -> TrainConfig:
    v = dataclasses.replace(base)
    for part in name.split("+"):
        if part == "baseline":
            pass
        elif part == "recursive":
            v.attn_impl = "recursive"
        elif part.startswith("remat_"):
            v.remat_policy = part[len("remat_"):]
        elif part.startswith("nm"):
            v.num_microbatches = int(part[2:])
        elif part.startswith("unroll"):
            v.scan_unroll = int(part[len("unroll"):])
        elif part.startswith("opt_"):
            v.optimizer = part[len("opt_"):]
        elif part == "gradbf16":
            v.grad_sync_dtype = "bfloat16"
        elif part == "sp":
            v.seq_parallel = True
        elif part == "moea2a":
            pass  # handled at model-config level in run()
        else:
            raise ValueError(f"unknown variant part {part!r}")
    return v


def run(arch: str, shape_name: str, variant: str, multi_pod=False) -> dict:
    cfg = get_config(arch)
    if "moea2a" in variant.split("+"):
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl="a2a"))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dp = chips // mesh.devices.shape[-1]
    tc = variant_cfg(variant, default_train_cfg(cfg, shape, dp))

    t0 = time.monotonic()
    bundle = build_bundle(cfg, shape, mesh, train_cfg=tc)
    compiled = lower_bundle(bundle, mesh).compile()
    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text())
    g = hlo["per_device"]
    out = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "chips": chips,
        "compute_s": g["flops"] / PEAK_FLOPS,
        "memory_s": g["bytes_fused"] / HBM_BW,
        "collective_s": g["collective_wire_bytes"] / ICI_BW,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "useful": model_flops_for(cfg, shape) / chips / g["flops"]
        if g["flops"] else 0.0,
        "compile_s": round(time.monotonic() - t0, 1),
        "train_cfg": {"nm": tc.num_microbatches, "remat": tc.remat_policy,
                      "attn": tc.attn_impl, "opt": tc.optimizer},
    }
    return out


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["baseline"]
    os.makedirs("results", exist_ok=True)
    for v in variants:
        r = run(arch, shape, v)
        with open("results/hillclimb.jsonl", "a") as f:
            f.write(json.dumps(r) + "\n")
        print(f"{r['arch']:18s} {r['shape']:12s} {v:28s} "
              f"c={r['compute_s']:8.3f} m={r['memory_s']:8.3f} "
              f"x={r['collective_s']:8.3f} temp={r['temp_gb']:6.1f}GB "
              f"useful={r['useful']:.3f}", flush=True)


if __name__ == "__main__":
    main()
