"""Dev harness: run every smoke arch through train/prefill/decode on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SMOKE_SHAPE, get_config
from repro.models.transformer import (
    forward, init_cache, init_model_params, loss_fn, model_specs)
from repro.models.params import param_count


def smoke_batch(cfg, b, s):
    key = jax.random.key(0)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        p = cfg.vlm_num_patches
        batch["patches"] = jnp.zeros((b, p, cfg.d_model), jnp.float32)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s)[None, :, None], (b, s, 3)).astype(jnp.int32)
    if cfg.family == "encdec":
        batch["src_frames"] = jnp.zeros((b, cfg.encdec_source_len,
                                         cfg.d_model), jnp.float32)
    return batch


def main():
    archs = sys.argv[1:] or ASSIGNED_ARCHS + ["lms-demo"]
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    for name in archs:
        cfg = get_config(name, smoke=True)
        params = init_model_params(cfg, seed=0)
        n = param_count(model_specs(cfg))
        batch = smoke_batch(cfg, b, s)

        total, metrics = loss_fn(params, cfg, batch)
        assert jnp.isfinite(total), (name, "train loss NaN")

        # prefill + decode consistency check at tiny scale
        cache = init_cache(cfg, b, s + 4)
        extras = {k: v for k, v in batch.items()
                  if k not in ("tokens", "labels")}
        logits_p, cache, _ = forward(params, cfg, tokens=batch["tokens"],
                                     mode="prefill", cache=cache,
                                     extras=extras)
        dec_extras = dict(extras)
        dec_extras.pop("patches", None)
        if "mrope_pos" in dec_extras:
            dec_extras["mrope_pos"] = jnp.full((b, 1, 3), s, jnp.int32)
        logits_d, cache, _ = forward(params, cfg,
                                     tokens=batch["tokens"][:, :1],
                                     mode="decode", cache=cache,
                                     pos=jnp.int32(s), extras=dec_extras)
        assert jnp.all(jnp.isfinite(logits_d.astype(jnp.float32))), name
        print(f"OK {name:24s} params={n/1e6:8.2f}M loss={float(total):.3f}")


if __name__ == "__main__":
    main()
