#!/usr/bin/env python
"""CLI front-end for the LMS invariant analyzer (``repro.analyzer``).

Runs every static pass (lock-discipline, lock-order, durability,
thread-lifecycle, http-surface) over the given files/directories and
reports the findings.

Usage::

    python scripts/lms_lint.py src/repro/core            # human output
    python scripts/lms_lint.py --json src/repro/core     # machine output
    python scripts/lms_lint.py --show-suppressed src/repro/core
    python scripts/lms_lint.py --lock-graph src/repro/core

Exit status: 0 when every finding is suppressed (with a reason), 1 when
any unsuppressed finding remains, 2 on usage/parse errors.  The JSON
output is stable (``version`` field, findings sorted by path/line/rule)
so CI can diff it; see ``Report.to_dict``.

Suppression syntax, checked by the analyzer itself::

    self._attr = x  # lms: unlocked(single-threaded until start())
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.analyzer import analyze_paths, expand_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="lms_lint",
        description="repo-specific invariant analyzer "
                    "(locks, durability, threads, HTTP surface)")
    ap.add_argument("paths", nargs="+",
                    help="python files or directories to analyze")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON (stable schema)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings (human mode)")
    ap.add_argument("--lock-graph", action="store_true",
                    help="print the inferred lock-order graph and exit")
    args = ap.parse_args(argv)

    try:
        files = expand_paths(args.paths)
    except OSError as e:
        print(f"lms_lint: {e}", file=sys.stderr)
        return 2
    if not files:
        print("lms_lint: no .py files under the given paths",
              file=sys.stderr)
        return 2
    try:
        report = analyze_paths(args.paths)
    except SyntaxError as e:
        print(f"lms_lint: parse error: {e}", file=sys.stderr)
        return 2

    unsuppressed = report.unsuppressed()

    if args.json:
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
        return 1 if unsuppressed else 0

    if args.lock_graph:
        print(f"lock nodes ({len(report.lock_nodes)}):")
        for node, kind in sorted(report.lock_nodes.items()):
            print(f"  {node}  [{kind}]")
        print(f"lock edges ({len(report.lock_edges)}):")
        for (src, dst), sites in sorted(report.lock_edges.items()):
            p, ln, note = sites[0]
            print(f"  {src} -> {dst}  "
                  f"({os.path.basename(p)}:{ln}, {note})")
        return 1 if unsuppressed else 0

    shown = report.findings if args.show_suppressed else unsuppressed
    for f in sorted(shown, key=lambda f: (f.path, f.line, f.rule)):
        print(f)
    n_sup = len(report.findings) - len(unsuppressed)
    print(f"lms_lint: {len(files)} files, "
          f"{len(unsuppressed)} unsuppressed finding(s), "
          f"{n_sup} suppressed", file=sys.stderr)
    return 1 if unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
