"""Data pipeline: synthetic + memmap token streams with prefetch."""

from repro.data.pipeline import (DataLoader, MemmapTokenSource,
                                 SyntheticTokenSource, make_batch_fn)

__all__ = ["DataLoader", "MemmapTokenSource", "SyntheticTokenSource",
           "make_batch_fn"]
