"""Token data pipeline.

* :class:`SyntheticTokenSource` — deterministic Zipf-ish token stream keyed
  by (seed, step); reproducible across restarts regardless of host count, so
  checkpoint-resume replays the exact same batches (important for the
  fault-tolerance tests).
* :class:`MemmapTokenSource` — flat binary token file (uint16/uint32)
  sampled in windows; the production path for real corpora.
* :class:`DataLoader` — per-host sharding (each process materializes only
  its rows of the global batch) + background prefetch thread.  The measured
  queue-wait time is exported as the ``data_wait_s`` raw event, which is what
  the LMS GOODPUT group and the "ingest-bound" branch of the pattern tree
  consume — the input pipeline is a monitored subsystem, as in the paper.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import numpy as np


class SyntheticTokenSource:
    """Deterministic pseudo-corpus: tokens ~ clipped Zipf, documents of
    varying length separated by token 0 (acts as BOS)."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.seed = seed
        self.zipf_a = zipf_a

    def batch(self, step: int, batch_size: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        toks = rng.zipf(self.zipf_a, size=(batch_size, seq_len + 1))
        toks = np.minimum(toks, self.vocab_size - 1).astype(np.int32)
        # sprinkle document boundaries
        doc = rng.random((batch_size, seq_len + 1)) < (1.0 / 512)
        toks = np.where(doc, 0, toks)
        return toks


class MemmapTokenSource:
    """Windows from a flat binary token file."""

    def __init__(self, path: str, dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        n = len(self.tokens) - (seq_len + 1)
        starts = rng.integers(0, max(n, 1), size=batch_size)
        return np.stack([
            np.asarray(self.tokens[s:s + seq_len + 1], dtype=np.int32)
            for s in starts])


def make_batch_fn(source, cfg, shape, extras_fn: Optional[Callable] = None):
    """step -> host-local batch dict {"tokens", "labels", extras...}."""
    def fn(step: int, host_rows: slice) -> dict:
        toks = source.batch(step, shape.global_batch, shape.seq_len)
        toks = toks[host_rows]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if extras_fn is not None:
            batch.update(extras_fn(step, toks.shape[0]))
        return batch
    return fn


class DataLoader:
    """Background-prefetching, host-sharded loader.

    host_index/host_count shard the *rows* of the global batch; on a real
    multi-host pod each process constructs only its slice and the launcher
    assembles the global array via ``jax.make_array_from_process_local_data``.
    """

    def __init__(self, batch_fn: Callable, *, host_index: int = 0,
                 host_count: int = 1, global_batch: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % max(host_count, 1) == 0, \
            "global batch must divide host count"
        rows = global_batch // host_count
        self._slice = slice(host_index * rows, (host_index + 1) * rows)
        self._batch_fn = batch_fn
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self.wait_time_s = 0.0          # exported as data_wait_s
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._batch_fn(step, self._slice)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        t0 = time.monotonic()
        step, batch = self._q.get()
        self.wait_time_s = time.monotonic() - t0
        return step, batch

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
