"""Production meshes.

``make_production_mesh`` is a *function* (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis (DP spans pod x data; TP spans model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: Optional[int] = None, *, model: int = 0):
    """Elastic mesh for whatever devices this process actually has.

    Picks the largest power-of-two TP ("model") axis <= requested (or 1/4 of
    the device count) and puts the rest on "data" — the restart path after a
    node failure builds its mesh through here.
    """
    n = devices if devices is not None else len(jax.devices())
    if model <= 0:
        model = 1
        while model * model * 4 <= n:
            model *= 2
    while n % model != 0:
        model //= 2
    return jax.make_mesh((n // model, model), ("data", "model"))
