"""Serving driver: ``python -m repro.launch.serve --arch lms-demo --smoke``.

Loads (or random-inits) weights, starts a monitored ServingEngine, runs a
synthetic request workload, and writes the job dashboard.  On a pod slice
this driver is launched per-host with the serve rule table (TP-sharded
bf16 weights); the CPU demo path serves the reduced config.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-serve")
    ap.add_argument("--arch", default="lms-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="",
                    help="restore weights from a training checkpoint")
    ap.add_argument("--lms-out", default="lms_out")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.core import MonitoringStack
    from repro.models.transformer import init_model_params
    from repro.serve.engine import ServingEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_model_params(cfg, seed=0)
    if args.ckpt_dir:
        from repro.ckpt import load_checkpoint
        step, out = load_checkpoint(args.ckpt_dir, {"params": params})
        params = out["params"]
        print(f"restored weights from step {step}")

    stack = MonitoringStack.inprocess(out_dir=args.lms_out)
    rng = np.random.default_rng(0)
    with stack.job(f"serve-{cfg.name}", user="server",
                   hosts=["host0"], tags={"arch": cfg.name}) as job:
        um = stack.usermetric(host="host0")
        eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                            max_len=args.max_len, usermetric=um)
        for _ in range(args.requests):
            plen = int(rng.integers(4, 17))
            eng.submit(rng.integers(1, cfg.vocab_size, plen),
                       max_new_tokens=args.max_new_tokens)
        done = eng.run_until_empty()
        um.flush()

    lat = [r.finished_at - r.submitted_at for r in done]
    ttft = [r.first_token_at - r.submitted_at for r in done]
    print(f"served {len(done)} requests | "
          f"ttft p50 {np.percentile(ttft, 50) * 1e3:.1f}ms | "
          f"latency p50 {np.percentile(lat, 50) * 1e3:.1f}ms "
          f"p99 {np.percentile(lat, 99) * 1e3:.1f}ms")
    p = stack.dashboards.write_dashboard(job)
    print(f"dashboard: {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
