import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init); everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles abstract params / optimizer state / caches / inputs with
     their NamedShardings from the logical-axis rule table,
  3. ``jax.jit(step).lower(...).compile()`` — any sharding mismatch, OOM-at-
     compile or unsupported collective fails the cell (a bug in our system),
  4. records ``memory_analysis()``, ``cost_analysis()``, and the HLO-walker
     costs (trip-count-corrected FLOPs, bytes, collective bytes) plus the
     three-term roofline into ``results/dryrun/<mesh>/<arch>__<shape>.json``.

Usage::

    python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import (ASSIGNED_ARCHS, SHAPES, TrainConfig, get_config,
                           supports_shape)
from repro.core.analysis import RooflineAnalyzer
from repro.launch.hlo_analysis import analyze_hlo, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_bundle, lower_bundle


def default_train_cfg(cfg, shape=None, dp: int = 16) -> TrainConfig:
    """Production defaults by model size (DESIGN.md §6): microbatch count +
    remat policy chosen so saved activations fit v5e HBM alongside the
    (FSDP-sharded) optimizer state; giants drop to factored Adafactor
    without first moment.  ``nm`` is capped so every microbatch still spans
    the full DP axis (global_batch / nm >= dp) — smaller microbatches make
    GSPMD silently replicate compute across the surplus DP shards."""
    n = cfg.param_count()
    if n > 100e9:
        tc = TrainConfig(optimizer="adafactor", beta1=0.0,
                         num_microbatches=32, remat_policy="minimal")
    elif n > 5e9:
        tc = TrainConfig(optimizer="adamw", num_microbatches=16,
                         remat_policy="minimal")
    else:
        tc = TrainConfig(optimizer="adamw", num_microbatches=1,
                         remat_policy="minimal")
    if shape is not None:
        max_nm = max(1, shape.global_batch // max(dp, 1))
        while tc.num_microbatches > max_nm or \
                shape.global_batch % tc.num_microbatches:
            tc.num_microbatches //= 2
        tc.num_microbatches = max(1, tc.num_microbatches)
    return tc


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one new token per row


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = False, save_hlo: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "status": "ok", "time_s": None}

    if not supports_shape(cfg, shape):
        record["status"] = "skipped"
        record["reason"] = ("full-attention arch at 524288-token decode is "
                            "not deployable (O(S^2)); see DESIGN.md §5")
        _write(path, record)
        return record

    t0 = time.monotonic()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        dp = chips // mesh.devices.shape[-1]          # pod x data
        bundle = build_bundle(cfg, shape, mesh,
                              train_cfg=default_train_cfg(cfg, shape, dp))
        lowered = lower_bundle(bundle, mesh)
        compiled = lowered.compile()

        mem = compiled.memory_analysis()
        record["memory_per_device"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
        ca = cost_analysis_dict(compiled)
        record["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "while bodies counted once (uncorrected)",
        }
        hlo_text = compiled.as_text()
        if save_hlo:
            import gzip
            with gzip.open(path.replace(".json", ".hlo.txt.gz"), "wt") as f:
                f.write(hlo_text)
        hlo = analyze_hlo(hlo_text)
        record["hlo_analysis"] = hlo

        # memory term uses the TPU-fusion bytes model (bytes_fused); the raw
        # unfused count stays in hlo_analysis for reference
        model_flops = model_flops_for(cfg, shape)
        roof = RooflineAnalyzer().analyze(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=hlo["global"]["flops"],
            hbm_bytes=hlo["global"]["bytes_fused"],
            collective_bytes=hlo["global"]["collective_wire_bytes"],
            model_flops=model_flops)
        record["roofline"] = {
            "chips": chips,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "bound_step_s": roof.bound_s,
            "model_flops": model_flops,
            "hlo_flops": roof.hlo_flops,
            "useful_flop_ratio": roof.useful_flop_ratio,
            "collective_operand_bytes_global":
                hlo["global"]["collective_operand_bytes"],
            "classification": roof.classify(),
        }
    except Exception as e:                                # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["time_s"] = round(time.monotonic() - t0, 1)
    _write(path, record)
    return record


def _write(path: str, record: dict):
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="dryrun")
    ap.add_argument("--arch", action="append", default=None,
                    help="architecture id(s); default: all assigned")
    ap.add_argument("--shape", action="append", default=None,
                    help="shape name(s); default: all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    archs = args.arch or ASSIGNED_ARCHS
    shapes = args.shape or list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                r = run_cell(arch, shape, multi, args.out,
                             args.skip_existing)
                dom = r.get("roofline", {}).get("dominant", "-")
                print(f"[{r['status']:7s}] {r['mesh']:10s} {arch:24s} "
                      f"{shape:12s} dominant={dom:10s} "
                      f"t={r.get('time_s')}s", flush=True)
                if r["status"] == "error":
                    failures += 1
                    print(r["error"][:500], flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
