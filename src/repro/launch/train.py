"""Training driver: ``python -m repro.launch.train --arch lms-demo ...``.

Runs a *monitored* training job on whatever devices this process has (the
CPU demo path trains lms-demo for a few hundred steps; on a TPU pod slice
the same driver runs per-host under the production mesh).  Features wired
here: elastic mesh construction, LMS stack (+optional HTTP endpoint for
out-of-process collectors), checkpoint auto-resume, failure injection, and
the XLA latency-hiding-scheduler flags for compute/comm overlap on TPU.
"""

from __future__ import annotations

import argparse
import os
import sys


# Compute/comm overlap: these XLA flags enable the latency-hiding scheduler
# on TPU (no-ops on the CPU demo).  Set before jax initializes.
TPU_PERF_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-train")
    ap.add_argument("--arch", default="lms-demo")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--remat", default="none",
                    choices=["none", "minimal", "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8", "bf16"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel axis size (0 = auto)")
    ap.add_argument("--lms-out", default="lms_out")
    ap.add_argument("--lms-http", action="store_true",
                    help="serve the router's HTTP endpoint")
    ap.add_argument("--no-monitor", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (restart-path testing)")
    ap.add_argument("--user", default=os.environ.get("USER", "user"))
    ap.add_argument("--overlap-flags", action="store_true",
                    help="append TPU latency-hiding XLA flags")
    args = ap.parse_args(argv)

    if args.overlap_flags:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") \
            + TPU_PERF_FLAGS

    import jax

    from repro.configs import ShapeConfig, TrainConfig, get_config
    from repro.core import MonitoringStack
    from repro.launch.mesh import make_mesh_for
    from repro.launch.steps import make_pc
    from repro.parallel.sharding import rules_for
    from repro.train.loop import train

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli", seq_len=args.seq_len,
                        global_batch=args.global_batch, kind="train")
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 20),
        optimizer=args.optimizer, num_microbatches=args.microbatches,
        remat_policy=args.remat, grad_compression=args.grad_compression,
        ckpt_dir=args.ckpt_dir, ckpt_interval=args.ckpt_interval,
        monitor=not args.no_monitor)

    ndev = len(jax.devices())
    mesh = pc = None
    if ndev > 1:
        mesh = make_mesh_for(ndev, model=args.tp)
        rules = rules_for("train")
        if args.grad_compression != "none" and "pod" in mesh.axis_names:
            rules = rules.with_overrides(batch=("data",))
        pc = make_pc(rules, mesh)
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    stack = MonitoringStack.inprocess(out_dir=args.lms_out,
                                      serve_http=args.lms_http)
    if args.lms_http:
        print(f"LMS HTTP endpoint: {stack.http.url}")

    losses = []

    def cb(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"grad {float(metrics['grad_norm']):.3f}", flush=True)

    result = train(cfg, tcfg, shape, stack=stack, pc=pc, mesh=mesh,
                   fail_at_step=args.fail_at_step, step_callback=cb,
                   user=args.user)
    print(f"done: steps={result.steps_run} final_loss={result.last_loss:.4f}"
          f" resumed_from={result.resumed_from}")
    for f in result.findings:
        print(f"finding: {f.rule} on {f.host} ({f.duration_s:.0f}s)")

    # end-of-job dashboard (paper Fig. 2/3 artifacts)
    jobs = stack.router.jobs.all_jobs()
    if jobs:
        p = stack.dashboards.write_dashboard(jobs[-1])
        stack.dashboards.write_admin_view(jobs)
        print(f"dashboard: {p}")
    stack.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
