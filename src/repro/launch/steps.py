"""Step builders + abstract input specs for AOT lowering (dry-run + drivers).

Everything here is ShapeDtypeStruct-level: no allocation.  Input specs carry
*logical axes* (same ParamSpec mechanism as model weights), so one rule table
derives every sharding in the 80-compile dry-run matrix.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models.params import ParamSpec, abstract_params, spec
from repro.models.transformer import (cache_specs, forward, model_specs)
from repro.parallel.sharding import (PartitionConstraints, ShardingRules,
                                     logical_to_pspec, rules_for,
                                     shardings_for_specs)
from repro.train.optim import opt_state_specs
from repro.train.step import make_train_step


# --------------------------------------------------------------------------
# Param / cache spec variants
# --------------------------------------------------------------------------


def serve_param_specs(cfg: ModelConfig):
    """Serving weights in bf16 (fp32 master copies are a training concern)."""
    def f(s: ParamSpec) -> ParamSpec:
        if jnp.dtype(s.dtype).kind == "f":
            return ParamSpec(s.shape, s.axes, jnp.bfloat16, s.init, s.scale,
                             s.value)
        return s
    return jax.tree.map(f, model_specs(cfg),
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------------
# Input specs (ParamSpec trees with logical axes)
# --------------------------------------------------------------------------


def _extras_specs(cfg: ModelConfig, shape: ShapeConfig, *, decode: bool):
    out = {}
    if cfg.family == "vlm":
        if not decode:
            p = min(cfg.vlm_num_patches, max(shape.seq_len - 2, 1))
            out["patches"] = spec((shape.global_batch, p, cfg.d_model),
                                  ("batch", None, None), jnp.bfloat16)
        out["mrope_pos"] = spec(
            (shape.global_batch, 1 if decode else shape.seq_len, 3),
            ("batch", None, None), jnp.int32)
    if cfg.family == "encdec" and not decode:
        out["src_frames"] = spec(
            (shape.global_batch, cfg.encdec_source_len, cfg.d_model),
            ("batch", None, None), jnp.bfloat16)
    return out


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    return {"tokens": spec((b, s), ("batch", "seq"), jnp.int32),
            "labels": spec((b, s), ("batch", "seq"), jnp.int32),
            **_extras_specs(cfg, shape, decode=False)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    return {"tokens": spec((b, s), ("batch", "seq"), jnp.int32),
            **_extras_specs(cfg, shape, decode=False)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {"tokens": spec((b, 1), ("batch", None), jnp.int32),
            **_extras_specs(cfg, shape, decode=True)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """The assignment's entry point: stand-ins for every model input of the
    (arch x shape) cell, keyed by step-function argument."""
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


# --------------------------------------------------------------------------
# Assembled lowering bundles
# --------------------------------------------------------------------------


@dataclasses.dataclass
class StepBundle:
    """Everything needed to ``jax.jit(fn, in_shardings=...).lower(*abstract)``."""

    fn: "object"
    abstract_args: tuple
    in_shardings: tuple
    donate_argnums: tuple = ()
    name: str = ""


def _sds(spec_tree):
    return abstract_params(spec_tree)


def _shard(spec_tree, rules, mesh):
    return shardings_for_specs(spec_tree, rules, mesh)


def make_pc(rules: ShardingRules, mesh: Optional[Mesh],
            enable: bool = True,
            seq_parallel: bool = False) -> PartitionConstraints:
    return PartitionConstraints(rules, mesh, enable,
                                seq_parallel=seq_parallel)


def _moe_localized(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Locality-aware MoE dispatch: one dispatch group per DP shard (§Perf:
    keeps the routing sort/scatter shard-local).  When the expert count
    divides the TP axis on a single-pod mesh, upgrade to the shard_map
    ragged all-to-all dispatch (strictly less wire than GSPMD's masked-AR
    scatter; apply_moe re-checks shape divisibility and falls back)."""
    if cfg.moe is None:
        return cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    tp = sizes.get("model", 1)
    impl = "a2a" if ("pod" not in sizes
                     and cfg.moe.num_experts % tp == 0) else "grouped"
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=dp,
                                     impl=impl))


def build_train_bundle(cfg: ModelConfig, shape: ShapeConfig,
                       train_cfg: TrainConfig, mesh: Mesh,
                       rules: Optional[ShardingRules] = None) -> StepBundle:
    rules = rules or rules_for("train")
    cfg = _moe_localized(cfg, mesh)
    pc = make_pc(rules, mesh,
                 seq_parallel=getattr(train_cfg, "seq_parallel", False))
    pspecs = model_specs(cfg)
    ospecs = opt_state_specs(pspecs, train_cfg)
    ispecs = train_input_specs(cfg, shape)
    step_fn, _ = make_train_step(cfg, train_cfg, pc=pc, mesh=mesh)
    scalar = NamedSharding(mesh, P())
    return StepBundle(
        fn=step_fn,
        abstract_args=(_sds(pspecs), _sds(ospecs), _sds(ispecs),
                       jax.ShapeDtypeStruct((), jnp.int32)),
        in_shardings=(_shard(pspecs, rules, mesh),
                      _shard(ospecs, rules, mesh),
                      _shard(ispecs, rules, mesh), scalar),
        donate_argnums=(0, 1),
        name=f"train:{cfg.name}:{shape.name}")


def build_prefill_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                         rules: Optional[ShardingRules] = None) -> StepBundle:
    rules = rules or rules_for("serve")
    cfg = _moe_localized(cfg, mesh)
    pc = make_pc(rules, mesh)
    pspecs = serve_param_specs(cfg)
    cspecs = cache_specs(cfg, shape.global_batch, shape.seq_len)
    ispecs = prefill_input_specs(cfg, shape)

    def prefill(params, tokens, cache, extras):
        logits, cache, _ = forward(params, cfg, tokens=tokens,
                                   mode="prefill", cache=cache, pc=pc,
                                   extras=extras)
        return logits[:, -1], cache

    return StepBundle(
        fn=prefill,
        abstract_args=(_sds(pspecs), _sds(ispecs)["tokens"], _sds(cspecs),
                       {k: v for k, v in _sds(ispecs).items()
                        if k != "tokens"}),
        in_shardings=(_shard(pspecs, rules, mesh),
                      _shard(ispecs, rules, mesh)["tokens"],
                      _shard(cspecs, rules, mesh),
                      {k: v for k, v in _shard(ispecs, rules, mesh).items()
                       if k != "tokens"}),
        donate_argnums=(2,),
        name=f"prefill:{cfg.name}:{shape.name}")


def build_decode_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        rules: Optional[ShardingRules] = None) -> StepBundle:
    rules = rules or rules_for("serve")
    cfg = _moe_localized(cfg, mesh)
    pc = make_pc(rules, mesh)
    pspecs = serve_param_specs(cfg)
    # decode against a full cache of seq_len (+1 slot for the new token)
    cspecs = cache_specs(cfg, shape.global_batch, shape.seq_len)
    ispecs = decode_input_specs(cfg, shape)
    scalar = NamedSharding(mesh, P())

    # cache-write policy (§Perf): when kv_heads takes the TP axis the cache
    # sequence dim is unsharded -> in-place DUS (cheapest); when the seq dim
    # carries the TP axis instead (kv_heads not divisible), a dynamic-index
    # DUS would force collectives, so use the elementwise one-hot write.
    tp = mesh.devices.shape[list(mesh.axis_names).index("model")] \
        if "model" in mesh.axis_names else 1
    kv_sharded = (cfg.attention_type != "mla"
                  and cfg.num_kv_heads % tp == 0 and cfg.num_kv_heads >= tp)
    cache_update = "dus" if kv_sharded or tp == 1 else "onehot"

    def decode(params, cache, tokens, pos, extras):
        logits, cache, _ = forward(params, cfg, tokens=tokens, mode="decode",
                                   cache=cache, pos=pos, pc=pc,
                                   extras=extras, cache_update=cache_update)
        return logits[:, -1], cache

    return StepBundle(
        fn=decode,
        abstract_args=(_sds(pspecs), _sds(cspecs), _sds(ispecs)["tokens"],
                       jax.ShapeDtypeStruct((), jnp.int32),
                       {k: v for k, v in _sds(ispecs).items()
                        if k != "tokens"}),
        in_shardings=(_shard(pspecs, rules, mesh),
                      _shard(cspecs, rules, mesh),
                      _shard(ispecs, rules, mesh)["tokens"], scalar,
                      {k: v for k, v in _shard(ispecs, rules, mesh).items()
                       if k != "tokens"}),
        donate_argnums=(1,),
        name=f"decode:{cfg.name}:{shape.name}")


def build_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 train_cfg: Optional[TrainConfig] = None,
                 rules: Optional[ShardingRules] = None) -> StepBundle:
    if shape.kind == "train":
        return build_train_bundle(cfg, shape, train_cfg or TrainConfig(),
                                  mesh, rules)
    if shape.kind == "prefill":
        return build_prefill_bundle(cfg, shape, mesh, rules)
    return build_decode_bundle(cfg, shape, mesh, rules)


def lower_bundle(bundle: StepBundle, mesh: Mesh):
    """jit(...).lower(*abstract) under the mesh context."""
    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     donate_argnums=bundle.donate_argnums)
    with mesh:
        return jitted.lower(*bundle.abstract_args)
