"""Post-SPMD HLO cost walker — the dry-run "profiler" (no hardware needed).

``compiled.cost_analysis()`` counts while-loop bodies **once** (verified in
EXPERIMENTS.md §Dry-run), which under-reports scanned-layer models by ~num
layers; and it reports nothing about collectives.  This walker parses
``compiled.as_text()`` (the post-SPMD, per-partition module) and computes:

* ``flops``       — dot/elementwise/reduce FLOPs, **x while trip counts**
                    (XLA annotates ``known_trip_count`` on scan loops);
* ``bytes``       — fusion-boundary traffic (operands+outputs of top-level
                    ops; fusion internals excluded, matching XLA's model);
* ``collective_bytes`` — assignment definition: sum of *operand* sizes of
  every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute, x trip counts;
* ``wire_bytes``  — algorithm-aware refinement (ring all-reduce counts 2x
  (g-1)/g, all-gather (g-1)/g x output, ...), used for the collective
  roofline term;
* per-collective-type breakdowns and the trip-count table.

All quantities are **per device** (the SPMD module is one partition's
program); multiply by ``num_partitions`` for global numbers.
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_TRANSCENDENTAL = {"exponential", "log", "tanh", "power", "rsqrt", "sqrt",
                   "logistic", "sine", "cosine", "erf", "exponential-minus-one",
                   "log-plus-one", "atan2", "cbrt"}
_ZERO_FLOP = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "copy", "reshape", "transpose", "broadcast",
              "slice", "concatenate", "dynamic-slice",
              "dynamic-update-slice", "iota", "pad", "reverse", "gather",
              "scatter", "copy-start", "copy-done", "partition-id",
              "replica-id", "after-all", "custom-call", "optimization-barrier",
              "infeed", "outfeed", "rng-bit-generator", "convert",
              "bitcast-convert", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute", "select-and-scatter"}
_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "partition-id", "replica-id", "after-all",
             "while", "conditional", "call", "optimization-barrier"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# unary ops the fusion-bytes model traces through (layout/dtype wrappers the
# CPU backend inserts around in-place updates; free or fused on TPU)
_UNARY_THRU = {"convert", "bitcast", "copy", "reshape", "transpose"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class CollectiveRecord:
    opcode: str
    operand_bytes: int
    output_bytes: int
    group_size: int
    count: float = 1.0          # trip multiplier


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_fused: float = 0.0     # TPU-fusion model: elementwise chains fuse
    transcendentals: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    by_collective: dict = field(default_factory=dict)
    collectives: list = field(default_factory=list)
    trip_counts: dict = field(default_factory=dict)
    num_partitions: int = 1

    def add(self, other: "HloCost", factor: float = 1.0):
        self.flops += other.flops * factor
        self.bytes += other.bytes * factor
        self.bytes_fused += other.bytes_fused * factor
        self.transcendentals += other.transcendentals * factor
        self.collective_operand_bytes += \
            other.collective_operand_bytes * factor
        self.collective_wire_bytes += other.collective_wire_bytes * factor
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) \
                + v * factor


def parse_computations(hlo_text: str):
    """-> (computations: name -> [Instr], num_partitions)."""
    num_partitions = 1
    m = re.search(r"num_partitions=(\d+)", hlo_text)
    if m:
        num_partitions = int(m.group(1))
    comps: dict = {}
    cur: Optional[list] = None
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm and line.rstrip().endswith("{"):
            cur = []
            comps[cm.group(2)] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            cur.append(Instr(im.group(1), im.group(2), im.group(3),
                             line.strip()))
    return comps, num_partitions


def _group_size(line: str, num_partitions: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return num_partitions


def _dot_flops(instr: Instr, shapes: dict) -> float:
    out_elems = _shape_elems(instr.type_str)
    ops = _OPERAND_RE.findall(instr.line.split("(", 1)[1])
    lhs_shape = shapes.get(ops[0], []) if ops else []
    m = _LHS_CONTRACT_RE.search(instr.line)
    contract = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    return 2.0 * out_elems * contract


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps, self.num_partitions = parse_computations(hlo_text)
        self._shapes: dict = {}
        for instrs in self.comps.values():
            for i in instrs:
                self._shapes[i.name] = _shape_dims(i.type_str)
        self._memo: dict = {}
        self.trip_counts: dict = {}

    # -- entry ------------------------------------------------------------

    def analyze(self, entry: Optional[str] = None) -> HloCost:
        if entry is None:
            entry = self._find_entry()
        cost = self._comp_cost(entry)
        return HloCost(cost.flops, cost.bytes, cost.bytes_fused,
                       cost.transcendentals,
                       cost.collective_operand_bytes,
                       cost.collective_wire_bytes, dict(cost.by_collective),
                       list(cost.collectives), dict(self.trip_counts),
                       self.num_partitions)

    def _find_entry(self) -> str:
        # the ENTRY computation is the one no other computation references
        referenced = set()
        for instrs in self.comps.values():
            for i in instrs:
                for rx in (_CALLS_RE, _BODY_RE, _COND_RE):
                    for m in rx.finditer(i.line):
                        referenced.add(m.group(1))
        unref = [n for n in self.comps if n not in referenced]
        for name in unref:
            if "main" in name:
                return name
        if unref:
            return unref[0]
        return next(iter(self.comps))

    # -- recursive costing ---------------------------------------------------

    def _comp_cost(self, name: str) -> HloCost:
        if name in self._memo:
            return self._memo[name]
        cost = HloCost()
        self._memo[name] = cost          # cycle guard (shouldn't happen)
        for instr in self.comps.get(name, []):
            self._instr_cost(instr, cost)
        return cost

    @staticmethod
    def _operand_text(line: str) -> str:
        """Text inside the opcode's operand parens (balance-aware)."""
        start = line.find("(", line.find(" = "))
        if start < 0:
            return ""
        depth = 0
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    return line[start + 1:i]
        return line[start + 1:]

    def _operand_bytes(self, instr: Instr) -> int:
        total = 0
        for op in _OPERAND_RE.findall(self._operand_text(instr.line)):
            total += self._def_bytes(op)
        return total

    def _update_operand_bytes(self, instr: Instr) -> int:
        """Bytes of the *update* operand (2nd) of a DUS/scatter."""
        ops = _OPERAND_RE.findall(self._operand_text(instr.line))
        if len(ops) >= 2:
            return self._def_bytes(ops[1])
        return _shape_bytes(instr.type_str)

    def _fusion_effective_operand_bytes(self, instr: Instr,
                                        called: str) -> int:
        """Effective HBM reads of a fusion: a parameter consumed *only* by
        dynamic-slice (or as the in-place target of dynamic-update-slice)
        inside the fusion contributes the sliced sizes, not its full size —
        the layer-scan + gradient-accumulation pattern."""
        usage = self._param_usage(called)
        ops = _OPERAND_RE.findall(self._operand_text(instr.line))
        total = 0
        for i, opname in enumerate(ops):
            eff = usage.get(i)
            if eff is None:
                total += self._def_bytes(opname)
            else:
                total += eff
        return total

    def _fusion_effective_out_bytes(self, called: str,
                                    out_bytes: int) -> int:
        """Fusions whose ROOT is a dynamic-update-slice on a parameter
        alias the buffer in place — the written bytes are the update region,
        not the whole (e.g. layer-stacked gradient) buffer."""
        instrs = self.comps.get(called, [])
        params = {i.name for i in instrs if i.opcode == "parameter"}
        by_name = {i.name: i for i in instrs}
        root = None
        for i in instrs:
            if i.line.startswith("ROOT "):
                root = i
                break
        if root is None:
            root = instrs[-1] if instrs else None
        if root is None:
            return out_bytes

        def unwrap(instr):
            """Follow unary convert/bitcast/copy/reshape wrappers down."""
            seen = 0
            while instr is not None and instr.opcode in _UNARY_THRU \
                    and seen < 8:
                ops = _OPERAND_RE.findall(self._operand_text(instr.line))
                instr = by_name.get(ops[0]) if ops else None
                seen += 1
            return instr

        def dus_eff(instr) -> Optional[int]:
            instr = unwrap(instr)
            if instr is None or instr.opcode != "dynamic-update-slice":
                return None
            ops = _OPERAND_RE.findall(self._operand_text(instr.line))
            tgt = unwrap(by_name.get(ops[0])) if ops else None
            tgt_name = ops[0] if ops else ""
            # target must trace back to a parameter (possibly via wrappers)
            if tgt_name in params or (
                    tgt is not None and tgt.opcode == "parameter"):
                return self._update_operand_bytes(instr)
            return None

        e = dus_eff(root)
        if e is not None:
            return e
        if root.opcode == "tuple":
            total = 0
            for opname in _OPERAND_RE.findall(
                    self._operand_text(root.line)):
                sub = by_name.get(opname)
                se = dus_eff(sub) if sub is not None else None
                total += se if se is not None else self._def_bytes(opname)
            return total
        return out_bytes

    def _param_usage(self, comp_name: str) -> dict:
        """param index -> effective bytes (None = read fully)."""
        if not hasattr(self, "_param_usage_cache"):
            self._param_usage_cache: dict = {}
        if comp_name in self._param_usage_cache:
            return self._param_usage_cache[comp_name]
        out: dict = {}
        instrs = self.comps.get(comp_name, [])
        params = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    params[i.name] = int(m.group(1))
        # consumer map
        consumers: dict = {}
        for i in instrs:
            if i.opcode == "parameter":
                continue
            for opname in _OPERAND_RE.findall(self._operand_text(i.line)):
                consumers.setdefault(opname, []).append(i)

        def eff_bytes(name: str, depth: int = 0) -> Optional[int]:
            """Sliced-traffic of value ``name``; None = read fully."""
            if depth > 8:
                return None
            total = 0
            for c in consumers.get(name, []):
                ops = _OPERAND_RE.findall(self._operand_text(c.line))
                if c.opcode == "dynamic-slice" and ops and ops[0] == name:
                    total += _shape_bytes(c.type_str)
                elif c.opcode == "dynamic-update-slice" and ops and \
                        ops[0] == name:
                    total += self._update_operand_bytes(c)
                elif c.opcode == "gather" and ops and ops[0] == name:
                    total += _shape_bytes(c.type_str)
                elif c.opcode in _UNARY_THRU:
                    sub = eff_bytes(c.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        for pname, pidx in params.items():
            e = eff_bytes(pname)
            if e is not None and consumers.get(pname):
                out[pidx] = e
        self._param_usage_cache[comp_name] = out
        return out

    def _def_bytes(self, opname: str) -> int:
        return self._def_bytes_cache.setdefault(
            opname, _shape_bytes(self._def_types.get(opname, "")))

    def _build_def_types(self):
        self._def_types = {}
        self._def_bytes_cache: dict = {}
        for instrs in self.comps.values():
            for i in instrs:
                self._def_types[i.name] = i.type_str

    def _instr_cost(self, instr: Instr, cost: HloCost):
        if not hasattr(self, "_def_types"):
            self._build_def_types()
        op = instr.opcode
        out_bytes = _shape_bytes(instr.type_str)
        out_elems = _shape_elems(instr.type_str)

        if op == "while":
            trip = 1.0
            m = _TRIP_RE.search(instr.line)
            if m:
                trip = float(m.group(1))
            body = _BODY_RE.search(instr.line)
            cond = _COND_RE.search(instr.line)
            inner = HloCost()
            if body:
                inner.add(self._comp_cost(body.group(1)))
            if cond:
                inner.add(self._comp_cost(cond.group(1)))
            self.trip_counts[instr.name] = trip
            cost.add(inner, trip)
            return

        if op in ("call", "fusion"):
            m = _CALLS_RE.search(instr.line)
            eff_operands = self._operand_bytes(instr)
            eff_out = out_bytes
            if m:
                sub = self._comp_cost(m.group(1))
                # fusion: interior bytes don't touch HBM; flops do count
                cost.flops += sub.flops
                cost.transcendentals += sub.transcendentals
                cost.collective_operand_bytes += sub.collective_operand_bytes
                cost.collective_wire_bytes += sub.collective_wire_bytes
                for k, v in sub.by_collective.items():
                    cost.by_collective[k] = cost.by_collective.get(k, 0) + v
                eff_operands = self._fusion_effective_operand_bytes(
                    instr, m.group(1))
                eff_out = self._fusion_effective_out_bytes(
                    m.group(1), out_bytes)
            cost.bytes += out_bytes + self._operand_bytes(instr)
            cost.bytes_fused += eff_out + eff_operands
            return

        if op == "conditional":
            subs = [self._comp_cost(n) for n in
                    _CALLS_RE.findall(instr.line)] or [HloCost()]
            biggest = max(subs, key=lambda c: c.flops)
            cost.add(biggest)
            cost.bytes += out_bytes
            return

        if op in COLLECTIVE_OPS:
            operand_bytes = self._operand_bytes(instr)
            g = _group_size(instr.line, self.num_partitions)
            frac = (g - 1) / g if g > 1 else 0.0
            if op == "all-reduce":
                wire = 2.0 * frac * operand_bytes
            elif op == "all-gather":
                wire = frac * out_bytes
            elif op == "reduce-scatter":
                wire = frac * operand_bytes
            elif op == "all-to-all":
                wire = frac * operand_bytes
            else:                       # collective-permute
                wire = float(operand_bytes)
            cost.collective_operand_bytes += operand_bytes
            cost.collective_wire_bytes += wire
            cost.by_collective[op] = cost.by_collective.get(op, 0.0) \
                + operand_bytes
            cost.collectives.append(CollectiveRecord(
                op, operand_bytes, out_bytes, g))
            cost.bytes += out_bytes + operand_bytes
            cost.bytes_fused += out_bytes + operand_bytes
            return

        # ---- plain ops ----------------------------------------------------
        # hbm_real: ops that necessarily move HBM traffic even after TPU
        # producer-consumer fusion (matmuls, reductions, data reshuffles);
        # bare elementwise/copy/layout ops at the top level are artifacts of
        # the CPU backend's weaker fusion and are excluded from bytes_fused.
        hbm_real = op in ("dot", "reduce", "reduce-window", "sort", "gather",
                          "scatter", "dynamic-slice", "dynamic-update-slice",
                          "concatenate", "pad", "rng-bit-generator",
                          "convolution")
        if op == "dot":
            cost.flops += _dot_flops(instr, self._shapes)
        elif op in ("reduce", "reduce-window"):
            cost.flops += self._operand_elems_first(instr)
        elif op == "sort":
            n = self._operand_elems_first(instr)
            cost.flops += n * max(n.bit_length(), 1)
        elif op in _ZERO_FLOP:
            pass
        elif op in _TRANSCENDENTAL:
            cost.flops += 5.0 * out_elems
            cost.transcendentals += out_elems
        else:                           # generic elementwise
            cost.flops += float(out_elems)

        if op not in _NO_BYTES:
            io = out_bytes + self._operand_bytes(instr)
            cost.bytes += io
            if hbm_real:
                # in-place models: DS/DUS/gather/scatter touch only the
                # sliced region (XLA aliases the big operand in place); the
                # naive operand sum charges e.g. a layer-stacked (L, d, d)
                # weight buffer for every per-layer slice — a 40-96x
                # overcount on scanned models.
                if op == "dynamic-slice":
                    io = 2 * out_bytes
                elif op == "dynamic-update-slice":
                    io = 2 * self._update_operand_bytes(instr)
                elif op == "gather":
                    io = 2 * out_bytes
                elif op == "scatter":
                    io = 3 * self._update_operand_bytes(instr)
                cost.bytes_fused += io

    def _operand_elems_first(self, instr: Instr) -> int:
        ops = _OPERAND_RE.findall(self._operand_text(instr.line))
        if not ops:
            return 0
        dims = self._shapes.get(ops[0], [])
        n = 1
        for d in dims:
            n *= d
        return n


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across JAX versions.

    Older JAX returns a one-element list of dicts (one per program),
    newer JAX returns the dict directly; either way callers want a plain
    dict (empty when XLA reports nothing).
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def analyze_hlo(hlo_text: str) -> dict:
    """-> JSON-able per-device cost dict."""
    an = HloAnalyzer(hlo_text)
    c = an.analyze()
    return {
        "num_partitions": c.num_partitions,
        "per_device": {
            "flops": c.flops,
            "bytes": c.bytes,
            "bytes_fused": c.bytes_fused,
            "transcendentals": c.transcendentals,
            "collective_operand_bytes": c.collective_operand_bytes,
            "collective_wire_bytes": c.collective_wire_bytes,
            "by_collective": c.by_collective,
        },
        "global": {
            "flops": c.flops * c.num_partitions,
            "bytes": c.bytes * c.num_partitions,
            "bytes_fused": c.bytes_fused * c.num_partitions,
            "collective_operand_bytes":
                c.collective_operand_bytes * c.num_partitions,
            "collective_wire_bytes":
                c.collective_wire_bytes * c.num_partitions,
        },
        "trip_counts": c.trip_counts,
    }
