"""Crash-safe durability: segmented WAL + snapshot/compaction.

The paper's stack assumes the metric back-end survives node reboots and
keeps serving job histories ("instant performance feedback" requires the
data to still be there); MPCDF's job-specific monitoring system and
PerSyst both treat durable, restartable storage as table stakes.  This
module is that subsystem for the embedded TSDB, replacing the original
JSONL append path (which interleaved partial lines under concurrent
writers, aborted recovery on a torn trailing line, and grew forever).

Layout (one :class:`DurableStore` per named database)::

    <persist_dir>/<db>/
        snapshot.json                   latest snapshot (atomic replace)
        shard-0000/wal-00000001.log     segmented log, one dir per shard
        shard-0000/wal-00000002.log
        shard-0001/...

* **Records** are length-prefixed and CRC-checked: ``<u32 payload_len,
  u32 crc32>`` + payload, one record per per-shard sub-batch.  The
  payload is the *columnar* form of the batch (``[measurement, tags,
  times, {field: column}]`` per series, ascending times; JSON meta +
  raw int64/float64 blobs, see the codec section) — exactly the column
  segments the in-memory apply materializes anyway, captured from it,
  so logging adds one encode and one buffered write to the hot path and
  replay feeds ``Database.write_columns`` directly.

* **One serialized writer per (shard) database.**  All appends go
  through the shard WAL's lock, and the in-memory apply runs under the
  same lock, so the log order *is* the apply order and concurrent
  writers can never interleave partial records.

* **fsync policy** (``none|batch|always``): ``none`` leaves flushing to
  the OS (fastest, loses the buffered tail on a process crash),
  ``batch`` group-commits — appends accumulate in a 1 MB writer buffer
  and are flushed to the OS page cache every ``flush_bytes`` (256 KB)
  or ``flush_interval_s`` (50 ms), whichever trips first, plus an fsync
  on segment rotation — so a process crash loses at most the commit
  window, and the durable hot path pays ~one write syscall per quarter
  megabyte instead of per batch.  ``always`` flushes *and* fsyncs every
  append (survives power loss, pays a disk round-trip per batch).

* **Background segment rotation**: when the active segment exceeds
  ``segment_max_bytes`` it is sealed and handed to a background sealer
  thread for flush+fsync+close, and appends continue into a fresh
  segment without waiting on the disk.

* **Snapshot + compaction** (:meth:`DurableStore.snapshot`): under a
  write barrier (all shard WAL locks), rotate every shard's segment and
  capture the live column stores plus rollup window state (including
  quantile-sketch bins for fields opted in via
  ``RollupConfig(sketch_fields=...)`` — ``WindowAgg.state()`` is the
  single serialization point, so p50/p95/p99 answers are restart-exact
  too); the snapshot is written atomically (tmp + fsync + rename) and
  every segment it covers is deleted.  Recovery cost is O(live data), not O(all-time
  writes), and :meth:`DurableStore.enforce_retention` drops whole
  expired segments by compacting through a snapshot (so rollup windows
  survive recovery exactly like they survive in-memory retention).

* **Recovery** (:meth:`DurableStore.recover`): load the snapshot, then
  replay segments from the snapshot's per-shard heads.  Torn tails from
  unclean shutdowns are truncated with a warning — never an abort — and
  replay re-hashes every series to the *current* shard layout, so the
  shard count may change between runs; per-shard logs replay in
  parallel.  A recovered database answers every ``select`` /
  ``aggregate`` / ``rollup_*`` query identically to one that never died
  (``tests/test_wal.py`` holds this as a property).

* **Legacy import** (:func:`import_legacy_jsonl`): old ``<db>.jsonl``
  logs are replayed line-by-line — skipping torn/interleaved lines
  instead of raising — written through the WAL (durable in the new
  format), and renamed ``*.jsonl.imported``.
"""

from __future__ import annotations

import array
import json
import logging
import os
import queue
import shutil
import struct
import sys
import threading
import time
import weakref
import zlib

try:
    import fcntl
except ImportError:             # non-POSIX: no advisory locking
    fcntl = None
from collections import defaultdict
from contextlib import ExitStack
from typing import Iterable, Optional

from repro.core.line_protocol import Point, now_ns
from repro.core.shard import shard_index
from repro.core.tsdb import Database, _tags_key

log = logging.getLogger("repro.core.wal")

SEGMENT_MAGIC = b"LMSWAL01"
FSYNC_MODES = ("none", "batch", "always")
DEFAULT_SEGMENT_BYTES = 4 * 1024 * 1024
DEFAULT_FLUSH_BYTES = 256 * 1024
DEFAULT_FLUSH_INTERVAL_S = 0.05
_WRITE_BUFFER_BYTES = 1024 * 1024
SNAPSHOT_FILE = "snapshot.json"

_HEADER = struct.Struct("<II")          # payload length, crc32(payload)
_SHARD_DIR = "shard-{:04d}"


def _fsync_dir(path: str):
    """fsync a directory so renames/creates/unlinks inside it survive
    power loss (no-op on filesystems that reject directory fds)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _segment_name(seq: int) -> str:
    return f"wal-{seq:08d}.log"


def _parse_segment_seq(fn: str) -> Optional[int]:
    if not fn.startswith("wal-") or not fn.endswith(".log"):
        return None
    try:
        return int(fn[len("wal-"):-len(".log")])
    except ValueError:
        return None


def read_segment(path: str):
    """Read one segment: ``(payloads, clean, valid_bytes)``.

    ``clean`` is False when the file ends in a torn record (partial
    header, partial payload, or CRC mismatch) — ``valid_bytes`` is the
    offset of the last complete record, the truncation point.  A file
    missing its magic header (e.g. a crash between create and first
    write) yields no payloads with ``valid_bytes=0``.
    """
    with open(path, "rb") as f:
        data = f.read()
    if not data:
        return [], True, 0
    if not data.startswith(SEGMENT_MAGIC):
        return [], False, 0
    payloads = []
    off = len(SEGMENT_MAGIC)
    end_of_data = len(data)
    clean = True
    while off < end_of_data:
        if off + _HEADER.size > end_of_data:
            clean = False
            break
        ln, crc = _HEADER.unpack_from(data, off)
        start = off + _HEADER.size
        end = start + ln
        if end > end_of_data:
            clean = False
            break
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            clean = False
            break
        payloads.append(payload)
        off = end
    return payloads, clean, off


class _Segment:
    """One sealed segment file."""

    __slots__ = ("seq", "path", "max_ts", "nbytes")

    def __init__(self, seq: int, path: str, max_ts: Optional[int],
                 nbytes: int):
        self.seq = seq
        self.path = path
        self.max_ts = max_ts
        self.nbytes = nbytes


class _Sealer:
    """Background finisher for rotated-out segments: flush + fsync +
    close happen off the append path, so rotation never blocks a writer
    on the disk."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    def submit(self, f, do_fsync: bool):
        with self._lock:
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, daemon=True, name="lms-wal-sealer")
                self._thread.start()
        self._q.put((f, do_fsync))

    def drain(self, timeout_s: float = 10.0):
        """Block until everything submitted so far is flushed + closed."""
        with self._lock:
            if self._thread is None:
                return
        barrier = threading.Event()
        self._q.put(barrier)
        barrier.wait(timeout_s)

    def _run(self):
        while True:
            item = self._q.get()
            if isinstance(item, threading.Event):
                item.set()
                continue
            f, do_fsync = item
            try:
                f.flush()
                if do_fsync:
                    os.fsync(f.fileno())
                    _fsync_dir(os.path.dirname(f.name))
            except (OSError, ValueError):
                pass
            finally:
                try:
                    f.close()
                except OSError:
                    pass


class _FlushRegistry:
    """One process-wide flusher thread servicing every batch-mode WAL:
    the periodic half of group commit (an idle WAL's buffered tail must
    reach the OS within the commit window) without spawning one
    50ms-wakeup thread per database."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stores: "weakref.WeakSet" = weakref.WeakSet()
        self._thread: Optional[threading.Thread] = None

    def register(self, store: "DurableStore"):
        with self._lock:
            self._stores.add(store)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, daemon=True, name="lms-wal-flusher")
                self._thread.start()

    def unregister(self, store: "DurableStore"):
        with self._lock:
            self._stores.discard(store)

    def _run(self):
        while True:
            time.sleep(DEFAULT_FLUSH_INTERVAL_S)
            with self._lock:
                stores = list(self._stores)
            for store in stores:
                for wal in store._wals:
                    try:
                        wal.flush_pending()
                    except (OSError, ValueError):
                        pass


# sealing and periodic flushing are rare/cheap: one thread each for the
# whole process, shared by every DurableStore
_SEALER = _Sealer()
_FLUSHER = _FlushRegistry()


class SegmentedWal:
    """Segmented log for one (shard) database: a single serialized
    writer, length-prefixed CRC-checked records, background rotation.

    ``lock`` is public on purpose: :class:`DurableStore` runs the
    in-memory apply under it, so log order == apply order, and the
    snapshot barrier acquires every shard's lock at once.
    """

    def __init__(self, directory: str, *, fsync: str = "batch",
                 segment_max_bytes: int = DEFAULT_SEGMENT_BYTES,
                 sealer: Optional[_Sealer] = None,
                 flush_bytes: int = DEFAULT_FLUSH_BYTES,
                 flush_interval_s: float = DEFAULT_FLUSH_INTERVAL_S):
        if fsync not in FSYNC_MODES:
            raise ValueError(f"fsync must be one of {FSYNC_MODES}, "
                             f"got {fsync!r}")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.fsync = fsync
        self.segment_max_bytes = int(segment_max_bytes)
        # group commit (fsync="batch"): appends accumulate in the writer
        # buffer and reach the OS when either threshold trips — one
        # write syscall per ~flush_bytes instead of per batch, with the
        # crash-loss window bounded by flush_interval_s
        self.flush_bytes = int(flush_bytes)
        self.flush_interval_s = float(flush_interval_s)
        self._unflushed = 0
        self._last_flush = time.monotonic()
        self._sealer = sealer
        self.lock = threading.RLock()
        self._f = None                      # active segment file object
        self._active_seq = 0
        self._active_bytes = 0
        self._active_max_ts: Optional[int] = None
        self._sealed: list = []
        for fn in sorted(os.listdir(directory)):
            seq = _parse_segment_seq(fn)
            if seq is None:
                continue
            path = os.path.join(directory, fn)
            self._sealed.append(_Segment(seq, path, None,
                                         os.path.getsize(path)))
        self._sealed.sort(key=lambda s: s.seq)
        self._next_seq = self._sealed[-1].seq + 1 if self._sealed else 1
        self.records_appended = 0

    # -- append (the single serialized writer) -------------------------------

    @property
    def next_seq(self) -> int:
        """Seq of the next segment to be created (every record appended
        so far lives in a segment with a strictly smaller seq)."""
        with self.lock:
            return self._next_seq

    def append(self, payload: bytes, max_ts: Optional[int] = None):
        """Append one record, honour the fsync policy, rotate when the
        segment is full.  Callers that must keep the log order equal to
        the in-memory apply order (``DurableStore``) hold :attr:`lock`
        across the apply and this append."""
        with self.lock:
            f = self._ensure_open()
            nbytes = _HEADER.size + len(payload)
            f.write(_HEADER.pack(len(payload), zlib.crc32(payload))
                    + payload)
            self._active_bytes += nbytes
            self.records_appended += 1
            if max_ts is not None and (self._active_max_ts is None or
                                       max_ts > self._active_max_ts):
                self._active_max_ts = max_ts
            if self.fsync == "always":
                f.flush()
                os.fsync(f.fileno())
            elif self.fsync == "batch":
                # group commit: one flush syscall per ~flush_bytes (or
                # per flush_interval_s), not per append
                self._unflushed += nbytes
                if self._unflushed >= self.flush_bytes or \
                        time.monotonic() - self._last_flush \
                        >= self.flush_interval_s:
                    f.flush()
                    self._unflushed = 0
                    self._last_flush = time.monotonic()
            if self._active_bytes >= self.segment_max_bytes:
                self._seal_locked()

    def _ensure_open(self):
        if self._f is None:
            path = os.path.join(self.directory,
                                _segment_name(self._next_seq))
            self._f = open(path, "ab", buffering=_WRITE_BUFFER_BYTES)
            if self._f.tell() == 0:
                self._f.write(SEGMENT_MAGIC)
                if self.fsync == "always":
                    # the new file's directory entry must be as durable
                    # as the fsynced records appended to it
                    self._f.flush()
                    _fsync_dir(self.directory)
            self._active_seq = self._next_seq
            self._next_seq += 1
            self._active_bytes = len(SEGMENT_MAGIC)
            self._active_max_ts = None
            self._unflushed = 0
            self._last_flush = time.monotonic()
        return self._f

    def _seal_locked(self):
        if self._f is None:
            return
        f, self._f = self._f, None
        self._sealed.append(_Segment(
            self._active_seq,
            os.path.join(self.directory, _segment_name(self._active_seq)),
            self._active_max_ts, self._active_bytes))
        if self._sealer is not None:
            self._sealer.submit(f, self.fsync != "none")
        else:
            try:
                f.flush()
                if self.fsync != "none":
                    os.fsync(f.fileno())
                    _fsync_dir(self.directory)
            finally:
                f.close()

    def flush_pending(self):
        """Flush buffered appends to the OS if any are pending — the
        periodic half of group commit, so an idle WAL's tail still
        reaches the page cache within the commit window."""
        with self.lock:
            if self._f is not None and self._unflushed:
                self._f.flush()
                self._unflushed = 0
                self._last_flush = time.monotonic()

    def rotate(self) -> int:
        """Seal the active segment (if any).  Returns the *head*: every
        record appended so far lives in a segment with seq < head."""
        with self.lock:
            self._seal_locked()
            return self._next_seq

    # -- replay ---------------------------------------------------------------

    def replay(self, handler, min_seq: int = 0,
               max_seq: Optional[int] = None) -> dict:
        """Feed every record payload of segments ``min_seq <= seq <
        max_seq`` to ``handler(payload) -> Optional[max_ts]`` in order.
        Torn tails are physically truncated and warned about — recovery
        never aborts on a half-written record."""
        stats = {"segments": 0, "records": 0, "torn_tails": 0}
        with self.lock:
            infos = [s for s in self._sealed
                     if s.seq >= min_seq and
                     (max_seq is None or s.seq < max_seq)]
        for info in infos:
            payloads, clean, valid = read_segment(info.path)
            if not clean:
                stats["torn_tails"] += 1
                log.warning(
                    "WAL segment %s has a torn tail (unclean shutdown); "
                    "truncating to %d valid bytes", info.path, valid)
                try:
                    with open(info.path, "r+b") as f:
                        f.truncate(valid)
                    info.nbytes = valid
                except OSError:
                    pass
            stats["segments"] += 1
            max_ts = info.max_ts
            for payload in payloads:
                stats["records"] += 1
                ts = handler(payload)
                if ts is not None and (max_ts is None or ts > max_ts):
                    max_ts = ts
            info.max_ts = max_ts
        return stats

    # -- compaction -----------------------------------------------------------

    def drop_segments_below(self, head_seq: int) -> int:
        """Delete sealed segments with seq < head (snapshot-covered)."""
        with self.lock:
            doomed = [s for s in self._sealed if s.seq < head_seq]
            self._sealed = [s for s in self._sealed if s.seq >= head_seq]
        n = 0
        for s in doomed:
            try:
                os.remove(s.path)
                n += 1
            except OSError:
                pass
        if n:
            _fsync_dir(self.directory)
        return n

    def ensure_seq_floor(self, head_seq: int):
        """Leave a durable floor on segment numbering: a fully compacted
        directory would make a *future* process restart at seq 1 — below
        the snapshot's covered range — and its records would be skipped
        on the next recovery.  An empty (magic-only) segment at
        ``head_seq`` pins the scan so numbering resumes above it."""
        with self.lock:
            if self._next_seq < head_seq:
                self._next_seq = head_seq
            path = os.path.join(self.directory, _segment_name(head_seq))
            if self._f is None and not os.path.exists(path):
                with open(path, "wb") as f:
                    f.write(SEGMENT_MAGIC)
                    f.flush()
                    os.fsync(f.fileno())
                _fsync_dir(self.directory)
                self._sealed.append(_Segment(head_seq, path, None,
                                             len(SEGMENT_MAGIC)))
                self._next_seq = head_seq + 1

    def expired_segments(self, cutoff_ns: int) -> int:
        """Sealed segments whose newest point is older than the cutoff."""
        with self.lock:
            return sum(1 for s in self._sealed
                       if s.max_ts is not None and s.max_ts < cutoff_ns)

    # -- introspection --------------------------------------------------------

    def segment_count(self) -> int:
        with self.lock:
            return len(self._sealed) + (1 if self._f is not None else 0)

    def wal_bytes(self) -> int:
        with self.lock:
            return sum(s.nbytes for s in self._sealed) + \
                (self._active_bytes if self._f is not None else 0)

    def close(self):
        with self.lock:
            self._seal_locked()


# --------------------------------------------------------------------------
# Batch payload codec (columnar, shared with the in-memory apply)
#
# A record payload is ``<u32 meta_len> + meta_json + numeric_blobs``:
# the JSON meta holds measurement/tags/row-count/column-spec per series,
# while timestamps and homogeneous numeric columns travel as raw
# little-endian int64/float64 arrays (``array`` packs/unpacks them at C
# speed — JSON-encoding 14-digit timestamps was the single largest cost
# on the durable hot path).  Mixed-type columns (bools, strings, None
# holes) fall back to JSON inside the meta, preserving exact types.
# --------------------------------------------------------------------------

_META_LEN = struct.Struct("<I")
_BIG_ENDIAN = sys.byteorder == "big"


_FLOAT_COL = frozenset((float,))
_INT_COL = frozenset((int,))


def _pack_numeric(col: list):
    """``(code, blob)`` for an all-float ('f') or all-int ('i') column,
    or ``(None, None)`` when the column needs the JSON fallback.  The
    type scan runs at C speed (``set(map(type, ...))``) — exact type
    identity, so bools (a subclass of int) and ``None`` holes fall back
    and round-trip with full fidelity."""
    kinds = set(map(type, col))
    try:
        if kinds == _FLOAT_COL:
            a = array.array("d", col)
            code = "f"
        elif kinds == _INT_COL:
            a = array.array("q", col)
            code = "i"
        else:
            return None, None
    except OverflowError:           # int field outside int64
        return None, None
    if _BIG_ENDIAN:
        a.byteswap()
    return code, a.tobytes()


def encode_batch_payload(entries: Iterable) -> bytes:
    """``[(measurement, tags, times, cols), ...]`` -> record payload."""
    meta = []
    blobs = []
    for m, tags, times, cols in entries:
        t = array.array("q", times)
        if _BIG_ENDIAN:
            t.byteswap()
        blobs.append(t.tobytes())
        colspec = []
        for k, col in cols.items():
            code, blob = _pack_numeric(col)
            if code is None:
                colspec.append([k, "j", col])
            else:
                colspec.append([k, code])
                blobs.append(blob)
        meta.append([m, tags, len(times), colspec])
    mb = json.dumps(meta, separators=(",", ":")).encode()
    return _META_LEN.pack(len(mb)) + mb + b"".join(blobs)


def decode_batch_payload(payload: bytes) -> list:
    """Record payload -> ``[[measurement, tags, times, cols], ...]``."""
    (mlen,) = _META_LEN.unpack_from(payload, 0)
    off = _META_LEN.size + mlen
    meta = json.loads(payload[_META_LEN.size:off])
    out = []
    for m, tags, n, colspec in meta:
        t = array.array("q")
        t.frombytes(payload[off:off + 8 * n])
        off += 8 * n
        if _BIG_ENDIAN:
            t.byteswap()
        cols = {}
        for spec in colspec:
            if spec[1] == "j":
                cols[spec[0]] = spec[2]
            else:
                a = array.array("d" if spec[1] == "f" else "q")
                a.frombytes(payload[off:off + 8 * n])
                off += 8 * n
                if _BIG_ENDIAN:
                    a.byteswap()
                cols[spec[0]] = a.tolist()
        out.append([m, tags, t.tolist(), cols])
    return out


class DurableStore:
    """WAL + snapshot durability for one named database.

    ``db`` is a :class:`repro.core.tsdb.Database` or a
    ``repro.core.shard.ShardedDatabase`` (detected by its ``shards``
    list) — sharded databases get one :class:`SegmentedWal` per shard,
    so appends contend only per shard and recovery replays shard logs in
    parallel.  All durable writes must go through :meth:`write` (i.e.
    ``TSDBServer.write``); direct in-memory ``db.write`` calls bypass
    the log, exactly like the pre-WAL persistence path.

    ``cold=True`` adds the compressed cold tier
    (``repro.core.coldstore``): :meth:`enforce_retention` *seals*
    expired raw prefixes into immutable chunks under ``<dir>/cold/``
    instead of dropping them.  The seal rides the snapshot write
    barrier, and the snapshot's ``cold_committed`` field is the crash
    commit point — recovery keeps either the retained raw data or the
    sealed chunk, never both and never neither.  NOTE: once chunks
    exist, keep ``cold`` enabled for this directory; a snapshot written
    without it does not carry ``cold_committed``, so a later
    cold-enabled recovery treats the chunks as uncommitted orphans.
    """

    def __init__(self, db, directory: str, *, fsync: str = "batch",
                 segment_max_bytes: int = DEFAULT_SEGMENT_BYTES,
                 cold: bool = False):
        if fsync not in FSYNC_MODES:
            raise ValueError(f"fsync must be one of {FSYNC_MODES}, "
                             f"got {fsync!r}")
        self.db = db
        self.directory = directory
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._lock_fd = self._acquire_dir_lock(directory)
        shards = getattr(db, "shards", None)
        self._shard_dbs = list(shards) if isinstance(shards, list) else [db]
        self._sealer = _SEALER
        self._wals = [
            SegmentedWal(os.path.join(directory, _SHARD_DIR.format(i)),
                         fsync=fsync, segment_max_bytes=segment_max_bytes,
                         sealer=self._sealer)
            for i in range(len(self._shard_dbs))]
        # segments that existed before this process wrote anything — the
        # replay window for a recover() that races later writes
        self._boot_seqs = [w.next_seq for w in self._wals]
        self._snap_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._appended_batches = 0
        self._appended_points = 0
        self._snapshots = 0
        self._recovered: Optional[dict] = None
        self._cold = None
        if cold:
            from repro.core.coldstore import ColdStore
            self._cold = ColdStore(os.path.join(directory, "cold"))
            n = len(self._shard_dbs)
            for i, sdb in enumerate(self._shard_dbs):
                sdb.attach_cold(self._cold.make_view(i, n))
        # cumulative retention accounting (satellite of the cold tier:
        # retention must never discard silently — persistence_stats()
        # reports what every sweep dropped or sealed)
        self._retention = {"sweeps": 0, "seals": 0, "points_sealed": 0,
                           "raw_points_dropped": 0,
                           "rollup_windows_dropped": 0}
        if fsync == "batch":
            _FLUSHER.register(self)

    @staticmethod
    def _acquire_dir_lock(directory: str):
        """Single-writer enforcement: two processes appending to the
        same WAL directory would interleave buffered writes into the
        same segment files and corrupt each other's records, so the
        second opener fails fast instead (advisory flock; skipped on
        platforms without fcntl)."""
        if fcntl is None:
            return None
        fd = os.open(os.path.join(directory, ".lock"),
                     os.O_CREAT | os.O_RDWR, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            os.close(fd)
            raise RuntimeError(
                f"WAL directory {directory!r} is locked by another "
                "process (two writers would corrupt the log)") from None
        return fd

    # -- write (apply + log under one lock per shard) -------------------------

    def write(self, points: Iterable[Point]):
        by_series, tags_of = Database.group_points(points)
        if not by_series:
            return
        n = len(self._wals)
        total = 0
        if n == 1:
            # single-writer fast path: no shard split
            total = sum(len(items) for items in by_series.values())
            self._apply_and_log(0, by_series, tags_of)
        else:
            per_shard: dict = defaultdict(lambda: ({}, {}))
            for (meas, key), items in by_series.items():
                total += len(items)
                shard_series, tmap = per_shard[shard_index(meas, key, n)]
                shard_series[(meas, key)] = items
                tmap[(meas, key)] = tags_of[(meas, key)]
            for i, (shard_series, tmap) in per_shard.items():
                self._apply_and_log(i, shard_series, tmap)
        with self._stats_lock:
            self._appended_batches += 1
            self._appended_points += total

    def write_columns(self, by_cols: dict, tags_of: dict):
        """Columnar twin of :meth:`write` — the binary ingest plane
        (``repro.core.ingest``) lands here with the batch already in the
        record form (``by_cols[(meas, tags_key)] = (times, {field:
        column})``, ascending per-series times), so durability costs one
        re-encode with the *same* codec the wire used plus one buffered
        append — no grouping, no transpose."""
        if not by_cols:
            return
        n = len(self._wals)
        total = sum(len(times) for times, _ in by_cols.values())
        if n == 1:
            self._apply_and_log_columns(0, by_cols, tags_of)
        else:
            per_shard: dict = defaultdict(lambda: ({}, {}))
            for (meas, key), tc in by_cols.items():
                shard_cols, tmap = per_shard[shard_index(meas, key, n)]
                shard_cols[(meas, key)] = tc
                tmap[(meas, key)] = tags_of[(meas, key)]
            for i, (shard_cols, tmap) in per_shard.items():
                self._apply_and_log_columns(i, shard_cols, tmap)
        with self._stats_lock:
            self._appended_batches += 1
            self._appended_points += total

    def _apply_and_log_columns(self, i: int, by_cols: dict, tags_of: dict):
        """Columnar :meth:`_apply_and_log`: the payload encode is pure
        (input columns only) and runs outside the lock; apply + append
        run under the WAL writer lock so log order == apply order."""
        payload = encode_batch_payload(
            (m, tags_of[(m, key)], times, cols)
            for (m, key), (times, cols) in by_cols.items())
        max_ts = max(times[-1] for times, _ in by_cols.values())
        wal = self._wals[i]
        with wal.lock:
            self._shard_dbs[i].write_columns(by_cols, tags_of)
            wal.append(payload, max_ts)

    def _apply_and_log(self, i: int, by_series: dict, tags_of: dict):
        """Apply one per-shard sub-batch and log it, both under the WAL
        writer lock (log order == apply order, and concurrent writers
        can never interleave partial records).  The apply runs first and
        *captures* the column segments it materialized anyway, so the
        record costs one encode + one buffered append — no second
        transpose.  Apply-before-log is durability-equivalent here: the
        in-memory store dies with the process, so recovery state is
        defined by the log alone either way."""
        wal = self._wals[i]
        with wal.lock:
            by_cols = self._shard_dbs[i].write_grouped(
                by_series, tags_of, capture=True)
            payload = encode_batch_payload(
                (m, tags_of[(m, key)], times, cols)
                for (m, key), (times, cols) in by_cols.items())
            max_ts = max(times[-1] for times, _ in by_cols.values())
            wal.append(payload, max_ts)

    # -- recovery -------------------------------------------------------------

    def recover(self) -> dict:
        """Snapshot restore + WAL replay (see module docstring).  Call
        once, on a freshly constructed store, before serving queries."""
        with self._snap_lock:
            if self._recovered is not None:
                return dict(self._recovered, already_recovered=True)
            stats = {"snapshot_series": 0, "snapshot_points": 0,
                     "segments_replayed": 0, "records_replayed": 0,
                     "points_replayed": 0, "torn_tails": 0,
                     "rehashed": False}
            heads: dict = {}
            snap = self._read_snapshot(stats)
            if self._cold is not None:
                # chunks above the snapshot's commit horizon are orphans
                # from a crash mid-seal: their points are still in the
                # snapshot/WAL, so keeping them would double-count.  An
                # *unreadable* snapshot is the one case where the chunks
                # may be the only surviving copy — keep everything.
                if snap is not None:
                    committed = int(snap.get("cold_committed", 0))
                elif "snapshot_error" in stats:
                    committed = None
                else:
                    committed = 0
                stats["cold_orphans_dropped"] = \
                    self._cold.reconcile(committed)
                stats["cold_chunks"] = self._cold.chunk_count()
            if snap is not None:
                heads = {int(k): v
                         for k, v in snap.get("wal_heads", {}).items()}
                self._restore_snapshot(snap, stats)
            disk = self._disk_shard_dirs()
            stale = sorted(i for i in disk if i >= len(self._wals))
            snap_shards = snap.get("shards") if snap else None
            if stale or (snap_shards is not None and
                         snap_shards != len(self._shard_dbs)):
                stats["rehashed"] = True
            replays = []
            for i in sorted(disk):
                if i < len(self._wals):
                    wal = self._wals[i]
                    max_seq = self._boot_seqs[i]
                else:
                    wal = SegmentedWal(disk[i], fsync=self.fsync)
                    max_seq = None
                replays.append((wal, heads.get(i, 0), max_seq))
            self._replay_all(replays, stats)
            if stale:
                # a shrunk shard layout: fold the orphan logs into a
                # fresh snapshot, then delete them (replaying them again
                # next boot would double-apply)
                self._snapshot_locked()
                for i in stale:
                    shutil.rmtree(disk[i], ignore_errors=True)
            self._recovered = stats
            return stats

    def _replay_all(self, replays: list, stats: dict):
        def run(wal, min_seq, max_seq):
            points = [0]

            def handler(payload):
                max_ts, n = self._apply_payload(payload)
                points[0] += n
                return max_ts
            r = wal.replay(handler, min_seq=min_seq, max_seq=max_seq)
            r["points"] = points[0]
            return r
        if len(replays) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(
                    max_workers=len(replays),
                    thread_name_prefix="lms-wal-recover") as ex:
                results = list(ex.map(lambda a: run(*a), replays))
        else:
            results = [run(*a) for a in replays]
        for r in results:
            stats["segments_replayed"] += r["segments"]
            stats["records_replayed"] += r["records"]
            stats["torn_tails"] += r["torn_tails"]
            stats["points_replayed"] += r.pop("points", 0)

    def _apply_payload(self, payload: bytes):
        """Replay one record: re-hash every series to the *current*
        shard layout and apply columns (no re-sorting, no per-point
        work).  Returns ``(max_ts, n_points)`` — the record's newest
        timestamp feeds segment-retention bookkeeping."""
        n = len(self._shard_dbs)
        per_shard: dict = defaultdict(lambda: ({}, {}))
        max_ts = None
        n_points = 0
        for m, tags, times, cols in decode_batch_payload(payload):
            key = (m, _tags_key(tags))
            i = shard_index(m, key[1], n) if n > 1 else 0
            by_cols, tmap = per_shard[i]
            if key in by_cols:          # same series twice in one record
                old_t, old_c = by_cols[key]
                t2, c2 = Database.transpose_items(
                    [(t, {k: c[j] for k, c in old_c.items()
                          if c[j] is not None})
                     for j, t in enumerate(old_t)] +
                    [(t, {k: c[j] for k, c in cols.items()
                          if c[j] is not None})
                     for j, t in enumerate(times)])
                by_cols[key] = (t2, c2)
            else:
                by_cols[key] = (times, cols)
            tmap[key] = tags
            n_points += len(times)
            if times and (max_ts is None or times[-1] > max_ts):
                max_ts = times[-1]
        for i, (by_cols, tmap) in per_shard.items():
            self._shard_dbs[i].write_columns(by_cols, tmap)
        return max_ts, n_points

    def _read_snapshot(self, stats: dict) -> Optional[dict]:
        path = os.path.join(self.directory, SNAPSHOT_FILE)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                snap = json.load(f)
            if not isinstance(snap, dict) or "series" not in snap:
                raise ValueError("not a snapshot document")
            return snap
        except (OSError, ValueError) as e:
            # never abort recovery: fall back to whatever the WAL holds
            log.warning("unreadable snapshot %s (%s); recovering from "
                        "WAL segments only", path, e)
            stats["snapshot_error"] = str(e)
            return None

    def _restore_snapshot(self, snap: dict, stats: dict):
        entries = snap["series"]
        n = len(self._shard_dbs)
        if n == 1:
            self._shard_dbs[0].restore_series(entries)
        else:
            per: dict = defaultdict(list)
            for e in entries:
                per[shard_index(e["m"], _tags_key(e["tags"]), n)].append(e)
            for i, es in per.items():
                self._shard_dbs[i].restore_series(es)
        shard_counts = snap.get("shard_counts")
        if shard_counts and len(shard_counts) == n:
            for i, c in enumerate(shard_counts):
                self._shard_dbs[i].add_count(c)
        else:
            self._shard_dbs[0].add_count(snap.get("count", 0))
        stats["snapshot_series"] = len(entries)
        stats["snapshot_points"] = sum(len(e["times"]) for e in entries)

    # -- snapshot + compaction ------------------------------------------------

    def snapshot(self) -> dict:
        """Write-barrier snapshot: rotate every shard WAL, capture the
        live column stores + rollup state, persist atomically, drop
        every covered segment."""
        with self._snap_lock:
            return self._snapshot_locked()

    def _snapshot_locked(self, seal_cutoff: Optional[int] = None) -> dict:
        sealed_points = 0
        with ExitStack() as barrier:
            # write barrier: all shard WAL locks at once — nothing can
            # append (and therefore nothing can apply) while the rotate
            # heads and the captured state are taken together
            for wal in self._wals:
                barrier.enter_context(wal.lock)
            heads = {i: wal.rotate()
                     for i, wal in enumerate(self._wals)}
            if seal_cutoff is not None and self._cold is not None:
                # seal: copy expired raw prefixes into one immutable
                # chunk (durable but not yet live), then per shard —
                # atomically under that shard's database lock — trim the
                # prefix and flip the chunk query-visible.  The barrier
                # guarantees the captured prefixes cannot drift before
                # the trim; the snapshot rename below is the crash
                # commit point (``cold_committed``).
                entries = []
                for sdb in self._shard_dbs:
                    entries.extend(sdb.capture_expired(seal_cutoff))
                seq = self._cold.append_chunk(entries) if entries else None
                for sdb in self._shard_dbs:
                    sealed_points += sdb.commit_seal(seal_cutoff, seq)
                if seq is not None:
                    with self._stats_lock:
                        self._retention["seals"] += 1
            states = [db.snapshot_state() for db in self._shard_dbs]
        doc = {
            "format": 1,
            "name": getattr(self.db, "name", ""),
            "shards": len(self._shard_dbs),
            "wal_heads": {str(i): s for i, s in heads.items()},
            "count": sum(s["count"] for s in states),
            "shard_counts": [s["count"] for s in states],
            "series": [e for s in states for e in s["series"]],
        }
        if self._cold is not None:
            # every cold-enabled snapshot records the commit horizon —
            # chunks above it at recovery are uncommitted orphans
            doc["cold_committed"] = self._cold.max_seq()
        path = os.path.join(self.directory, SNAPSHOT_FILE)
        tmp = path + ".tmp"
        data = json.dumps(doc, separators=(",", ":")).encode()
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(self.directory)          # the rename must survive too
        dropped = 0
        for i, wal in enumerate(self._wals):
            # floor BEFORE dropping: a crash between the two would leave
            # an empty dir, the next process would restart numbering at
            # seq 1 (below the snapshot head) and its records would be
            # skipped by every later recovery
            wal.ensure_seq_floor(heads[i])
            dropped += wal.drop_segments_below(heads[i])
        with self._stats_lock:
            self._snapshots += 1
        return {"series": len(doc["series"]),
                "points": sum(len(e["times"]) for e in doc["series"]),
                "count": doc["count"], "bytes": len(data),
                "segments_dropped": dropped,
                "points_sealed": sealed_points}

    # -- retention ------------------------------------------------------------

    def enforce_retention(self, max_age_ns: Optional[int] = None,
                          max_points_per_series: Optional[int] = None,
                          rollup_max_age_ns: Optional[int] = None) -> dict:
        """Retention sweep; never silent — returns (and accumulates into
        :meth:`stats`) what it dropped or sealed.

        Without a cold tier: in-memory retention, then drop whole
        expired WAL segments (compacted away through a snapshot, so the
        rollup windows their points fed keep answering after recovery).

        With a cold tier (``cold=True``): expired raw prefixes are
        *sealed* into compressed chunks via the snapshot write barrier
        (see :meth:`_snapshot_locked`) instead of age-dropped; only
        ``max_points_per_series`` caps and the independent rollup
        horizon still discard, and those discards are counted."""
        report = {"raw_points_dropped": 0, "rollup_windows_dropped": 0,
                  "points_sealed": 0}
        if self._cold is not None and max_age_ns is not None:
            cutoff = now_ns() - max_age_ns
            if any(sdb.has_expired_raw(cutoff)
                   for sdb in self._shard_dbs) or \
                    any(w.expired_segments(cutoff) for w in self._wals):
                with self._snap_lock:
                    snap = self._snapshot_locked(seal_cutoff=cutoff)
                report["points_sealed"] = snap.get("points_sealed", 0)
            report.update(self.db.enforce_retention(
                None, max_points_per_series, rollup_max_age_ns))
        else:
            report.update(self.db.enforce_retention(
                max_age_ns, max_points_per_series, rollup_max_age_ns))
            if max_age_ns is not None:
                cutoff = now_ns() - max_age_ns
                if any(w.expired_segments(cutoff) for w in self._wals):
                    self.snapshot()
        with self._stats_lock:
            self._retention["sweeps"] += 1
            for k in ("raw_points_dropped", "rollup_windows_dropped",
                      "points_sealed"):
                self._retention[k] += report[k]
        return report

    # -- introspection / lifecycle --------------------------------------------

    def stats(self) -> dict:
        with self._stats_lock:
            out = {"fsync": self.fsync,
                   "shards": len(self._wals),
                   "appended_batches": self._appended_batches,
                   "appended_points": self._appended_points,
                   "snapshots": self._snapshots}
        out["appended_records"] = sum(w.records_appended
                                      for w in self._wals)
        out["segments"] = sum(w.segment_count() for w in self._wals)
        out["wal_bytes"] = sum(w.wal_bytes() for w in self._wals)
        snap = os.path.join(self.directory, SNAPSHOT_FILE)
        out["snapshot_bytes"] = os.path.getsize(snap) \
            if os.path.exists(snap) else 0
        with self._stats_lock:
            out["retention"] = dict(self._retention)
        if self._cold is not None:
            out["cold"] = self._cold.stats()
        if self._recovered is not None:
            out["recovered"] = dict(self._recovered)
        return out

    def close(self):
        """Seal active segments and wait for the sealer to flush them."""
        _FLUSHER.unregister(self)
        for wal in self._wals:
            wal.close()
        self._sealer.drain()
        if self._lock_fd is not None:
            try:
                os.close(self._lock_fd)    # releases the flock
            except OSError:
                pass
            self._lock_fd = None

    def _disk_shard_dirs(self) -> dict:
        out = {}
        for fn in os.listdir(self.directory):
            path = os.path.join(self.directory, fn)
            if fn.startswith("shard-") and os.path.isdir(path):
                try:
                    out[int(fn[len("shard-"):])] = path
                except ValueError:
                    continue
        return out


# --------------------------------------------------------------------------
# Legacy JSONL import
# --------------------------------------------------------------------------


def import_legacy_jsonl(path: str, store: DurableStore) -> dict:
    """Import a pre-WAL ``<db>.jsonl`` append log.

    The legacy writer appended outside any lock, so the file may hold a
    torn trailing line (unclean shutdown) or interleaved partial lines
    (concurrent writers) — both are skipped with a warning instead of
    aborting the whole recovery, which is what the old ``load_persisted``
    did.  Surviving points are written *through the WAL* (durable in the
    new format) and the file is renamed ``*.jsonl.imported`` so the next
    boot does not double-import it."""
    pts = []
    skipped = 0
    with open(path, "rb") as f:
        for line in f.read().split(b"\n"):
            if not line.strip():
                continue
            try:
                d = json.loads(line)
                pts.append(Point(d["m"], d["t"], d["f"], d["ts"]))
            except (ValueError, KeyError, TypeError):
                skipped += 1
    if skipped:
        log.warning("legacy log %s: skipped %d torn/corrupt line(s)",
                    path, skipped)
    if pts:
        store.write(pts)
    os.replace(path, path + ".imported")
    # without this, a crash right here forgets the rename and the next
    # boot double-imports every legacy point
    _fsync_dir(os.path.dirname(os.path.abspath(path)))
    return {"points": len(pts), "lines_skipped": skipped}
