"""Dashboard agent (paper §III.D).

The paper's agent generates Grafana dashboards *from templates* based on the
databases and the metrics available in them: dashboard, row and panel
templates are combined into a full dashboard, settings adjusted for the
current job, and an analysis header shows badly-behaving jobs on the initial
view (Fig. 2).  The admin view lists all running jobs with thumbnails.

Air-gapped adaptation (DESIGN.md §10): we emit (a) Grafana-compatible
dashboard JSON using the same template mechanism and (b) a self-contained
static HTML rendering with inline SVG sparklines, so the dashboards are
viewable without any external service.

The agent is shard-transparent: it reads only the Database-shaped query
surface (``measurements``/``field_keys``/``select``/``rollup_*``), so
``backend.db(name)`` may hand back a plain ``Database``, a hash-
partitioned ``repro.core.shard.ShardedDatabase`` or any federated view —
per-job dashboards render identically either way (scatter-gather happens
below this layer).  Panel sparklines execute through the derived-metric
query engine (``repro.core.query``): the per-panel window query is
planned once and cached against the ingest watermark, so re-rendering an
unchanged dashboard costs O(1) per panel.

The analysis header reads the findings the continuous engine
(``repro.core.analysis.AnalysisEngine``) persisted into the ``analysis``
measurement — O(#alerts) per render.  The seed agent re-ran every rule
over the full database on *every* render (and again for every job in the
admin view); that rescan is gone.
"""

from __future__ import annotations

import html
import json
import os
import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.analysis import ANALYSIS_MEASUREMENT, load_alerts
from repro.core.jobs import JobInfo
from repro.core.marker import MARKER_MEASUREMENT, roofline_spec
from repro.core.query import QueryEngine, QuerySpec
from repro.core.tsdb import TSDBServer

# --------------------------------------------------------------------------
# Templates (Grafana-style JSON fragments with ${...} placeholders)
# --------------------------------------------------------------------------

PANEL_TEMPLATES = {
    "timeseries": {
        "type": "timeseries",
        "title": "${title}",
        "datasource": "${db}",
        "targets": [{"measurement": "${measurement}",
                     "field": "${field}",
                     "groupBy": "hostname",
                     "tags": {"jobid": "${jobid}"}}],
        "gridPos": {"h": 8, "w": 12},
    },
    "stat": {
        "type": "stat",
        "title": "${title}",
        "datasource": "${db}",
        "targets": [{"measurement": "${measurement}", "field": "${field}",
                     "agg": "last", "tags": {"jobid": "${jobid}"}}],
        "gridPos": {"h": 4, "w": 6},
    },
    "annotations": {
        "type": "annotations",
        "datasource": "${db}",
        "targets": [{"measurement": "job_event", "field": "event",
                     "tags": {"jobid": "${jobid}"}}],
    },
}

# Default row templates: which measurements/fields become panels when the
# metrics exist in the database (agent selects applicable templates).
DEFAULT_ROWS = [
    ("Analysis", [("stat", "hpm", "mfu", "MFU"),
                  ("stat", "hpm", "tokens_per_s", "tokens/s"),
                  ("stat", "hpm", "step_time_s", "step time")]),
    ("HPM", [("timeseries", "hpm", "mfu", "Model FLOPs utilization"),
             ("timeseries", "hpm", "mem_gb_per_s", "Memory bandwidth"),
             ("timeseries", "hpm", "ici_gb_per_s", "Interconnect traffic"),
             ("timeseries", "hpm", "step_time_s", "Step time")]),
    ("Application", [("timeseries", "usermetric", "value", "App metrics")]),
    ("System", [("timeseries", "system", "cpu_load_1m", "CPU load"),
                ("timeseries", "system", "rss_bytes", "Memory allocated"),
                ("timeseries", "system", "net_tx_bytes", "Network I/O"),
                ("timeseries", "system", "write_bytes", "File I/O")]),
]


def _subst(obj, mapping: dict):
    if isinstance(obj, str):
        for k, v in mapping.items():
            obj = obj.replace("${" + k + "}", str(v))
        return obj
    if isinstance(obj, dict):
        return {k: _subst(v, mapping) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_subst(v, mapping) for v in obj]
    return obj


@dataclass
class DashboardAgent:
    backend: TSDBServer
    out_dir: str = "dashboards"
    rows: list = field(default_factory=lambda: list(DEFAULT_ROWS))
    panel_templates: dict = field(
        default_factory=lambda: dict(PANEL_TEMPLATES))

    # fallback engines kept for at most this many distinct views — per-
    # render throwaway views (a fresh FederatedQuery per request) must
    # not accumulate engines + caches for the process lifetime
    MAX_FALLBACK_ENGINES = 8

    def __post_init__(self):
        os.makedirs(self.out_dir, exist_ok=True)
        # id(db) -> (weakref-to-db, engine): the weakref validates the id
        # against object reuse after GC; a WeakKeyDictionary would not
        # work here (the engine strongly references its backend — the
        # key — so entries would never be collected)
        self._engines: "OrderedDict" = OrderedDict()
        # concurrent dashboard renders (one per ThreadingHTTPServer
        # request) share this LRU; unguarded get/move_to_end/popitem
        # interleavings corrupt the OrderedDict
        self._engines_lock = threading.Lock()

    def _engine(self, db, db_name: Optional[str] = None) -> QueryEngine:
        # prefer the backend's shared per-database registry
        # (TSDBServer.query_engine) so dashboard renders and /query/v2
        # requests hit the SAME watermark-keyed cache — a private engine
        # here would recompute panels the server already cached
        registry = getattr(self.backend, "query_engine", None)
        if registry is not None and db_name is not None and \
                db is self.backend.db(db_name):
            return registry(db_name)
        key = id(db)
        with self._engines_lock:
            ent = self._engines.get(key)
            if ent is not None and ent[0]() is db:
                self._engines.move_to_end(key)
                return ent[1]
            eng = QueryEngine(db)
            self._engines[key] = (weakref.ref(db), eng)
            self._engines.move_to_end(key)
            while len(self._engines) > self.MAX_FALLBACK_ENGINES:
                self._engines.popitem(last=False)
            return eng

    # -- template assembly (the paper's core mechanism) -----------------------

    def build_dashboard(self, job: JobInfo, db_name: str = "global") -> dict:
        """Combine templates into a Grafana-style dashboard for one job."""
        db = self.backend.db(db_name)
        available = set(db.measurements())
        mapping = {"jobid": job.job_id, "db": db_name,
                   "user": job.user}
        findings = load_alerts(db, jobid=job.job_id)
        rows_out = []
        for row_title, panels in self.rows:
            panels_out = []
            for ptype, meas, fieldname, title in panels:
                if meas not in available:
                    continue        # agent selects templates by availability
                if fieldname not in db.field_keys(meas) and \
                        fieldname != "value":
                    continue
                tpl = self.panel_templates[ptype]
                panels_out.append(_subst(tpl, {**mapping, "title": title,
                                               "measurement": meas,
                                               "field": fieldname}))
            if panels_out:
                rows_out.append({"title": row_title, "panels": panels_out})
        # marker regions get a dedicated roofline row (below), not the
        # generic per-field timeseries treatment
        if MARKER_MEASUREMENT in available:
            rows_out.append({"title": "Roofline", "panels": [{
                "type": "roofline",
                "title": "Per-region roofline (marker regions)",
                "datasource": db_name,
                # a full /query/v2 QuerySpec: the panel, the low_roofline
                # rule and any CLI consumer all execute the *same* spec
                "targets": [{"query_v2":
                             roofline_spec(job.job_id).to_dict()}],
                "gridPos": {"h": 8, "w": 24},
            }]})
        # app-level metrics beyond the defaults (paper §IV: extra metrics may
        # be available with application-level monitoring); the engine's own
        # analysis measurement is rendered as the header, not as panels
        extra = sorted(available - {"hpm", "system", "job_event",
                                    MARKER_MEASUREMENT,
                                    ANALYSIS_MEASUREMENT})
        for meas in extra:
            panels_out = [
                _subst(self.panel_templates["timeseries"],
                       {**mapping, "title": f"{meas}.{fk}",
                        "measurement": meas, "field": fk})
                for fk in db.field_keys(meas)
                if fk not in ("event",)]
            if panels_out:
                rows_out.append({"title": f"app:{meas}",
                                 "panels": panels_out})
        return {
            "dashboard": {
                "title": f"Job {job.job_id} ({job.user})",
                "tags": ["lms", job.user],
                "annotations": _subst(self.panel_templates["annotations"],
                                      mapping),
                "header": {
                    "analysis": [
                        {"rule": f.rule, "severity": f.severity,
                         "host": f.host, "state": f.state,
                         "duration_s": f.duration_s,
                         "evidence": f.evidence}
                        for f in findings],
                    "status": ("unhealthy" if any(
                        f.severity == "critical" for f in findings)
                        else "ok"),
                },
                "rows": rows_out,
                "time": {"from": job.start_ns, "to": job.end_ns or "now"},
            },
        }

    def write_dashboard(self, job: JobInfo, db_name: str = "global") -> str:
        dash = self.build_dashboard(job, db_name)
        path = os.path.join(self.out_dir, f"job_{job.job_id}.json")
        with open(path, "w") as f:
            json.dump(dash, f, indent=1, default=str)
        html_path = os.path.join(self.out_dir, f"job_{job.job_id}.html")
        with open(html_path, "w") as f:
            f.write(self.render_html(job, dash, db_name))
        return path

    # -- admin view (all running jobs + thumbnails, Fig. 2) ---------------------

    def build_admin_view(self, jobs: list, db_name: str = "global") -> dict:
        db = self.backend.db(db_name)
        out = []
        for job in jobs:
            findings = load_alerts(db, jobid=job.job_id)
            thumb = self._series_for(db, "hpm", "mfu", job.job_id,
                                     db_name=db_name)
            out.append({"jobid": job.job_id, "user": job.user,
                        "hosts": len(job.hosts),
                        "running": job.running,
                        "alerts": len(findings),
                        "status": "unhealthy" if any(
                            f.severity == "critical" for f in findings)
                        else "ok",
                        "thumbnail_mfu": thumb[1][-50:]})
        return {"jobs": out}

    def write_admin_view(self, jobs: list, db_name: str = "global") -> str:
        view = self.build_admin_view(jobs, db_name)
        path = os.path.join(self.out_dir, "admin.json")
        with open(path, "w") as f:
            json.dump(view, f, indent=1, default=str)
        return path

    # -- static HTML rendering ---------------------------------------------------

    # sparklines cap out visually around this many segments; coarser rollup
    # tiers are preferred once a panel would exceed it
    MAX_PANEL_POINTS = 400

    def _series_for(self, db, meas: str, fieldname: str,
                    jobid: str, host: Optional[str] = None,
                    db_name: Optional[str] = None):
        # ``db`` is any Database-shaped view (plain, sharded, federated)
        tags = {"jobid": jobid}
        if host:
            tags["hostname"] = host
        # transparent rollup read: finest tier that fits the panel budget,
        # coarsest tier if nothing fits — O(#windows) instead of a raw
        # rescan, and still renders after raw-point retention.  The tier is
        # chosen from cheap stored-window counts; the panel query itself
        # goes through the query engine, so a repeated render of the same
        # dashboard is a cache hit until the measurement ingests again.
        cfg = getattr(db, "rollup_config", None)
        if cfg is not None:
            chosen = None
            for tier_ns in cfg.tiers_ns:
                cnt = db.rollup_window_count(meas, fieldname, tags=tags,
                                             tier_ns=tier_ns)
                if cnt == 0:        # field not rolled up -> raw path
                    chosen = None
                    break
                chosen = tier_ns
                if cnt <= self.MAX_PANEL_POINTS:
                    break
            if chosen is not None:
                res = self._engine(db, db_name).query(QuerySpec(
                    measurement=meas, metrics=(fieldname,), tags=tags,
                    window_ns=chosen))
                ts, vs = res.column(fieldname)
                if ts:
                    return ts, vs
        ts, vs = [], []
        for s in db.select(meas, [fieldname], tags):
            ts.extend(s.times)
            vs.extend(v for v in s.values.get(fieldname, []))
        pairs = sorted((t, v) for t, v in zip(ts, vs)
                       if isinstance(v, (int, float)))
        return [t for t, _ in pairs], [v for _, v in pairs]

    @staticmethod
    def _sparkline(times, values, w=600, h=80) -> str:
        if len(values) < 2:
            return "<svg/>"
        vmin, vmax = min(values), max(values)
        rng = (vmax - vmin) or 1.0
        t0, t1 = times[0], times[-1]
        trng = (t1 - t0) or 1
        pts = " ".join(
            f"{(t - t0) / trng * w:.1f},{h - (v - vmin) / rng * (h - 4) - 2:.1f}"
            for t, v in zip(times, values))
        return (f'<svg width="{w}" height="{h}">'
                f'<polyline fill="none" stroke="#2a7" stroke-width="1.5" '
                f'points="{pts}"/>'
                f'<text x="2" y="12" font-size="10">{vmax:.4g}</text>'
                f'<text x="2" y="{h-2}" font-size="10">{vmin:.4g}</text>'
                f'</svg>')

    def _roofline_html(self, db, spec_dict: dict,
                       db_name: Optional[str] = None) -> str:
        """Per-region roofline table: executes the panel's embedded
        /query/v2 spec through the shared engine (derived ROOFLINE
        metrics evaluated over the rollup tiers, cached against the
        ingest watermark) and reduces each region's windows to totals
        (time/calls; window agg is "sum") and window means (ratios)."""
        res = self._engine(db, db_name).query(
            QuerySpec.from_dict(spec_dict))

        def _col(g, metric):
            return [v for v in (g.get(metric) or {}).get("values", ())
                    if v is not None]

        def _fmt(v, spec="{:.3g}"):
            return spec.format(v) if v is not None else "&mdash;"

        rows = ["<table border='1' cellpadding='4'>"
                "<tr><th>region</th><th>calls</th><th>time (s)</th>"
                "<th>intensity (flop/B)</th><th>achieved GFLOP/s</th>"
                "<th>roofline frac</th></tr>"]
        for region in sorted(res.groups):
            g = res.groups[region]
            tot = {m: sum(_col(g, m)) for m in ("time_s", "calls")}
            mean = {}
            for m in ("intensity", "achieved_gflops", "roofline_frac"):
                vals = _col(g, m)
                mean[m] = sum(vals) / len(vals) if vals else None
            rows.append(
                f"<tr><td>{html.escape(region)}</td>"
                f"<td>{tot['calls']:.0f}</td>"
                f"<td>{tot['time_s']:.3g}</td>"
                f"<td>{_fmt(mean['intensity'])}</td>"
                f"<td>{_fmt(mean['achieved_gflops'])}</td>"
                f"<td>{_fmt(mean['roofline_frac'], '{:.1%}')}</td></tr>")
        rows.append("</table>")
        return "\n".join(rows)

    def render_html(self, job: JobInfo, dash: dict,
                    db_name: str = "global") -> str:
        db = self.backend.db(db_name)
        head = dash["dashboard"]["header"]
        parts = [f"<html><head><title>{html.escape(dash['dashboard']['title'])}"
                 "</title></head><body style='font-family:monospace'>",
                 f"<h1>{html.escape(dash['dashboard']['title'])}</h1>",
                 f"<h2>Status: {head['status']}</h2>"]
        if head["analysis"]:
            parts.append("<ul>")
            for a in head["analysis"]:
                parts.append(
                    f"<li><b>{a['severity']}</b> {a['rule']} on "
                    f"{a['host'] or 'job'} for {a['duration_s']:.0f}s — "
                    f"{html.escape(a['evidence'])}</li>")
            parts.append("</ul>")
        for row in dash["dashboard"]["rows"]:
            parts.append(f"<h3>{html.escape(row['title'])}</h3>")
            for panel in row["panels"]:
                tgt = panel["targets"][0]
                if "query_v2" in tgt:
                    parts.append(
                        f"<div><b>{html.escape(panel['title'])}</b><br>"
                        f"{self._roofline_html(db, tgt['query_v2'], db_name)}"
                        "</div>")
                    continue
                ts, vs = self._series_for(db, tgt["measurement"],
                                          tgt["field"], job.job_id,
                                          db_name=db_name)
                parts.append(f"<div><b>{html.escape(panel['title'])}</b><br>"
                             f"{self._sparkline(ts, vs)}</div>")
        parts.append("</body></html>")
        return "\n".join(parts)
