"""Host agent — per-node metric collection (paper §III.A).

Gathers (a) system-level metrics from the OS (CPU load, RSS, I/O counters —
the things Diamond/Ganglia collected in the paper's setup) and (b) the
TPU/XLA-derived HPM events described in DESIGN.md §2 (FLOPs, bytes,
collective traffic per step from the compiled artifact, plus step
wall-times).  Raw events go through the LIKWID-style performance groups to
produce derived metrics, and everything is emitted to the router with the
mandatory ``hostname`` tag.

On a real multi-host pod slice each process runs one agent (hostname =
worker name); single-process simulations can run several agents with
synthetic hostnames — that is what the straggler tests do.
"""

from __future__ import annotations

import os
import resource
import socket
import threading
import time
from typing import Optional

from repro.core.line_protocol import Point, now_ns
from repro.core.perf_groups import derive_all


def _read_proc_io() -> dict:
    try:
        out = {}
        with open("/proc/self/io") as f:
            for line in f:
                k, _, v = line.partition(":")
                out[k.strip()] = int(v)
        return {"read_bytes": out.get("read_bytes", 0),
                "write_bytes": out.get("write_bytes", 0)}
    except OSError:
        return {"read_bytes": 0, "write_bytes": 0}


def _read_net_dev(path: str = "/proc/net/dev") -> dict:
    try:
        rx = tx = 0
        with open(path) as f:
            for line in f.readlines()[2:]:
                name, _, rest = line.partition(":")
                if name.strip() == "lo":
                    continue
                # guard per line: a malformed/truncated row (seen on
                # exotic kernels and in torn sysfs reads) must not kill
                # the whole collection tick — skip it (without partial
                # sums) and keep counting the remaining interfaces
                try:
                    cols = rest.split()
                    row_rx, row_tx = int(cols[0]), int(cols[8])
                except (ValueError, IndexError):
                    continue
                rx += row_rx
                tx += row_tx
        return {"net_rx_bytes": rx, "net_tx_bytes": tx}
    except OSError:
        return {"net_rx_bytes": 0, "net_tx_bytes": 0}


class HostAgent:
    """Collects system + XLA-HPM metrics for one (possibly simulated) host."""

    def __init__(self, router, hostname: Optional[str] = None,
                 device_constants: Optional[dict] = None,
                 batch_size: int = 1,
                 max_pending_points: int = 65536):
        self.router = router
        self.hostname = hostname or socket.gethostname()
        # static per-step facts from the compiled artifact (set once after
        # compile): hlo_flops, hlo_bytes, collective_bytes, model_flops,
        # tokens_per_step, hbm_bytes_in_use
        self.step_constants = dict(device_constants or {})
        # previous cumulative-counter sample + its monotonic clock, for
        # the per-interval rate fields (see RATE_FIELDS)
        self._last_sys: Optional[dict] = None
        self._last_t = time.monotonic()
        # >1: buffer points and hand the router whole batches (paper §III.A
        # batched transmission); 1 keeps the historical emit-per-call path
        # so live analyzers see every point immediately
        self.batch_size = max(int(batch_size), 1)
        # points waiting for the next batch, plus any re-buffered after a
        # failed send (bounded: a dead router drops the oldest points
        # past max_pending_points instead of growing memory forever)
        self.max_pending_points = int(max_pending_points)
        # guards the emit buffer + failure counters: collection ticks,
        # explicit flush() callers and __exit__ may run on different
        # threads (the straggler tests drive several agents at once)
        self._lock = threading.Lock()
        self._pending: list = []
        self._failed_flushes = 0
        self._dropped_points = 0

    # -- compiled-artifact facts ------------------------------------------------

    def set_step_constants(self, **kwargs):
        self.step_constants.update(kwargs)

    # -- system metrics (Diamond/Ganglia analogue) -------------------------------

    # cumulative counter field -> the per-interval rate field derived from
    # consecutive samples; cpu seconds become fractions of the wall
    # interval (1.0 = one core fully busy)
    RATE_FIELDS = {
        "cpu_user_s": "cpu_user_frac",
        "cpu_sys_s": "cpu_sys_frac",
        "read_bytes": "read_bytes_per_s",
        "write_bytes": "write_bytes_per_s",
        "net_rx_bytes": "net_rx_bytes_per_s",
        "net_tx_bytes": "net_tx_bytes_per_s",
    }

    def _rate_fields(self, counters: dict, now_m: float) -> dict:
        """Per-interval rates from consecutive cumulative-counter samples.

        A negative delta means the counter reset underneath us (process
        restart feeding the same hostname, kernel counter wrap): that
        field's rate is skipped for this interval and the new value
        becomes the baseline — a reset must never emit a huge negative
        (or wrapped-positive) rate.
        """
        prev, dt = self._last_sys, now_m - self._last_t
        out = {}
        if prev is not None and dt > 0:
            for k, rate_name in self.RATE_FIELDS.items():
                cur, last = counters.get(k), prev.get(k)
                if cur is None or last is None:
                    continue
                delta = cur - last
                if delta < 0:           # counter reset -> skip, re-baseline
                    continue
                out[rate_name] = delta / dt
        self._last_sys = counters
        self._last_t = now_m
        return out

    def collect_system(self) -> Point:
        ru = resource.getrusage(resource.RUSAGE_SELF)
        try:
            load1, load5, load15 = os.getloadavg()
        except OSError:
            load1 = load5 = load15 = 0.0
        fields = {
            "cpu_load_1m": load1,
            "cpu_user_s": ru.ru_utime,
            "cpu_sys_s": ru.ru_stime,
            "rss_bytes": ru.ru_maxrss * 1024,
            **{k: float(v) for k, v in _read_proc_io().items()},
            **{k: float(v) for k, v in _read_net_dev().items()},
        }
        counters = {k: fields[k] for k in self.RATE_FIELDS if k in fields}
        fields.update(self._rate_fields(counters, time.monotonic()))
        return Point("system", {"hostname": self.hostname}, fields, now_ns())

    # -- per-step HPM ------------------------------------------------------------

    def collect_step(self, *, step: int, step_time_s: float,
                     extra_events: Optional[dict] = None,
                     emit: bool = True, ts: Optional[int] = None) -> dict:
        """Build raw events for one step, derive groups, emit to router.

        Returns the derived metrics dict (also used by the live analyzers).
        ``ts`` overrides the point timestamp (simulated hosts in tests).
        """
        raw = dict(self.step_constants)
        raw["step_time_s"] = max(step_time_s, 1e-9)
        raw["step"] = step
        if extra_events:
            raw.update(extra_events)
        derived = derive_all(raw)
        if emit:
            fields = {"step": step, "step_time_s": step_time_s}
            fields.update({k: float(v) for k, v in derived.items()})
            if extra_events:
                fields.update({k: float(v) for k, v in extra_events.items()
                               if k not in fields})
            self._emit(Point("hpm", {"hostname": self.hostname},
                             fields, ts if ts is not None
                             else now_ns()))
        return derived

    def emit_system(self):
        self._emit(self.collect_system())

    # -- batched emission --------------------------------------------------------

    def _emit(self, point: Point):
        with self._lock:
            self._pending.append(point)
            full = len(self._pending) >= self.batch_size
        if full:
            # implicit flush: a down router/sink must never crash the
            # collection tick — the failure is counted, the points are
            # re-buffered (bounded) and retried on the next emit
            self._flush(raise_errors=False)

    def flush(self):
        """Send any buffered points as one batch.  Explicit flushes
        re-buffer AND raise on a failing sink."""
        self._flush(raise_errors=True)

    def _flush(self, raise_errors: bool):
        with self._lock:
            if not self._pending:
                return
            pending, self._pending = self._pending, []
        try:
            # sink call outside the lock: a slow router must not stall
            # concurrent collection ticks
            self.router.write(pending)
        except Exception:
            with self._lock:
                self._failed_flushes += 1
                self._pending[:0] = pending
                excess = len(self._pending) - self.max_pending_points
                if excess > 0:
                    del self._pending[:excess]
                    self._dropped_points += excess
            if raise_errors:
                raise

    @property
    def emit_stats(self) -> dict:
        with self._lock:
            return {"pending": len(self._pending),
                    "failed_flushes": self._failed_flushes,
                    "dropped_points": self._dropped_points}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.flush()
        return False
