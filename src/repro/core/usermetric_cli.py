"""Command-line metric/event sender (paper §IV: "For use in batch scripts,
a command line application can send metrics and events from the shell").

Examples (against a running LMS HTTP endpoint)::

    python -m repro.core.usermetric_cli --url http://127.0.0.1:8086 \
        metric loss 1.234 --tag phase=warmup
    python -m repro.core.usermetric_cli --url $LMS_URL \
        event run_state "starting miniMD"
    python -m repro.core.usermetric_cli --url $LMS_URL \
        job-start --jobid 42 --user alice --hosts h1,h2

``--binary HOST:PORT`` prefers the binary ingest plane
(``repro.core.ingest``) for metric/event sends, with the HTTP line path
as automatic fallback; job signals always go over HTTP.
"""

from __future__ import annotations

import argparse
import socket
import sys

from repro.core.httpd import HttpSink
from repro.core.ingest import BinarySink
from repro.core.line_protocol import Point, now_ns


def _tags(args) -> dict:
    tags = {"hostname": args.hostname}
    for t in args.tag or []:
        k, _, v = t.partition("=")
        tags[k] = v
    return tags


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="usermetric")
    ap.add_argument("--url", required=True, help="LMS router HTTP endpoint")
    ap.add_argument("--binary", metavar="HOST:PORT",
                    help="prefer the binary ingest plane at HOST:PORT "
                         "(falls back to --url's HTTP line path)")
    ap.add_argument("--db", default="global")
    ap.add_argument("--hostname", default=socket.gethostname())
    sub = ap.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("metric", help="send one numeric metric")
    m.add_argument("name")
    m.add_argument("value", type=float)
    m.add_argument("--tag", action="append")

    e = sub.add_parser("event", help="send one string event")
    e.add_argument("name")
    e.add_argument("text")
    e.add_argument("--tag", action="append")

    js = sub.add_parser("job-start")
    js.add_argument("--jobid", required=True)
    js.add_argument("--user", required=True)
    js.add_argument("--hosts", required=True,
                    help="comma-separated hostnames")
    js.add_argument("--tag", action="append")

    je = sub.add_parser("job-end")
    je.add_argument("--jobid", required=True)

    args = ap.parse_args(argv)
    http = HttpSink(args.url, db=args.db)
    if args.binary:
        host, _, port = args.binary.rpartition(":")
        sink = BinarySink(host or "127.0.0.1", int(port), db=args.db,
                          fallback=http)
    else:
        sink = http

    if args.cmd == "metric":
        sink.write(Point(args.name, _tags(args), {"value": args.value},
                         now_ns()))
    elif args.cmd == "event":
        sink.write(Point(args.name, _tags(args), {"event": args.text},
                         now_ns()))
    elif args.cmd == "job-start":
        tags = {k: v for k, v in
                (t.partition("=")[::2] for t in (args.tag or []))}
        http.job_start(args.jobid, args.user, args.hosts.split(","), tags)
    elif args.cmd == "job-end":
        http.job_end(args.jobid)
    return 0


if __name__ == "__main__":
    sys.exit(main())
