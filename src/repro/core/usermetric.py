"""libusermetric — application-level monitoring (paper §IV).

A lightweight library that buffers and sends batched messages in the
InfluxDB line protocol.  Default tags can be specified and are added to each
message; besides metric name, value, default tags and time stamp, arbitrary
tags can be supplied (e.g. a thread identifier).

Sinks: an in-process :class:`~repro.core.router.MetricsRouter` or an HTTP
endpoint (``repro.core.httpd.HttpSink``) — the same code path either way,
mirroring how the paper's libusermetric talks to the router over HTTP.
A command-line tool for batch scripts lives in ``usermetric_cli``.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Optional, Union

from repro.core.line_protocol import Point, now_ns


class UserMetric:
    """Buffered, batched metric/event emitter with default tags."""

    def __init__(self, sink, *, default_tags: Optional[dict] = None,
                 batch_size: int = 64, flush_interval_s: float = 5.0,
                 hostname: Optional[str] = None,
                 auto_flush_thread: bool = False,
                 max_buffered_points: int = 65536):
        """sink: callable(list[Point]) or an object with .write(points).

        ``max_buffered_points`` bounds the re-buffer kept while the sink
        is failing (e.g. the router endpoint is down): a dead sink drops
        the *oldest* points past the bound instead of growing memory
        forever.
        """
        self._sink = sink.write if hasattr(sink, "write") else sink
        self.default_tags = dict(default_tags or {})
        self.default_tags.setdefault(
            "hostname", hostname or socket.gethostname())
        self.batch_size = batch_size
        self.flush_interval_s = flush_interval_s
        self.max_buffered_points = int(max_buffered_points)
        self._buf: list = []
        self._lock = threading.Lock()
        self._last_flush = time.monotonic()
        self._sent_points = 0
        self._sent_batches = 0
        self._dropped_points = 0
        self._failed_flushes = 0
        self._join_timeouts = 0
        self._stop = threading.Event()
        self._thread = None
        self._markers = None            # lazy MarkerSession (see .markers)
        if auto_flush_thread:
            self._thread = threading.Thread(target=self._flush_loop,
                                            daemon=True)
            self._thread.start()

    # -- emit -----------------------------------------------------------------

    def metric(self, name: str, value: Union[float, int, dict],
               tags: Optional[dict] = None, ts: Optional[int] = None):
        """Numeric metric; ``value`` may be a dict of field -> value."""
        fields = value if isinstance(value, dict) else {"value": value}
        fields = {k: (float(v) if not isinstance(v, (bool, int, str))
                      else v) for k, v in fields.items()}
        self._push(Point(name, self._tags(tags), fields,
                         ts if ts is not None else now_ns()))

    def event(self, name: str, text: str, tags: Optional[dict] = None,
              ts: Optional[int] = None):
        """String-valued event (paper Fig. 3 start/end markers)."""
        self._push(Point(name, self._tags(tags), {"event": text},
                         ts if ts is not None else now_ns()))

    @property
    def markers(self):
        """Lazy per-emitter marker session (``repro.core.marker``): exact
        nested/concurrent region accounting emitted through this
        UserMetric as the ``marker`` measurement."""
        with self._lock:
            mk = self._markers
        if mk is None:
            from repro.core.marker import MarkerSession
            mk = MarkerSession(self)
            with self._lock:
                if self._markers is None:
                    self._markers = mk
                mk = self._markers
        return mk

    def region(self, name: str, tags: Optional[dict] = None):
        """Context manager timing a code region.

        Routed through the marker subsystem (exact call counts and
        inclusive/exclusive time under nesting and reentrancy — the old
        inline implementation allocated a throwaway class per call and
        only emitted a duration); the legacy per-call ``<name>_time_s``
        point is still emitted for backward compatibility.
        """
        um = self
        inner = self.markers.region(name)

        class _Region:
            def __enter__(self):
                inner.__enter__()
                return self

            def __exit__(self, *exc):
                inner.__exit__(*exc)
                self.seconds = inner.seconds
                um.metric(f"{name}_time_s", inner.seconds, tags)
                return False
        return _Region()

    # -- buffering --------------------------------------------------------------

    def _tags(self, tags):
        out = dict(self.default_tags)
        if tags:
            out.update(tags)
        return out

    def _push(self, p: Point):
        flush_now = False
        with self._lock:
            self._buf.append(p)
            if len(self._buf) >= self.batch_size or \
                    time.monotonic() - self._last_flush \
                    >= self.flush_interval_s:
                flush_now = True
        if flush_now:
            # implicit flush: a failing sink must never crash the
            # monitored application's metric()/event() call — failures
            # are counted and the points re-buffered (bounded) instead
            self._flush(raise_errors=False)

    def flush(self):
        """Explicit flush: sink failures re-buffer AND raise, so batch
        scripts that call ``flush()``/``close()`` see the error.  Pending
        marker-region deltas are drained into the buffer first."""
        with self._lock:
            mk = self._markers
        if mk is not None:
            mk.flush()
        self._flush(raise_errors=True)

    def _flush(self, raise_errors: bool):
        with self._lock:
            buf, self._buf = self._buf, []
            self._last_flush = time.monotonic()
        if not buf:
            return
        try:
            self._sink(buf)
        except Exception:
            # re-buffer at the front (bounded) so a transient sink
            # failure loses nothing and a dead sink can't grow memory
            # forever
            with self._lock:
                self._failed_flushes += 1
                self._buf[:0] = buf
                excess = len(self._buf) - self.max_buffered_points
                if excess > 0:
                    del self._buf[:excess]
                    self._dropped_points += excess
            if raise_errors:
                raise
            return
        with self._lock:
            self._sent_points += len(buf)
            self._sent_batches += 1

    def _flush_loop(self):
        while not self._stop.wait(self.flush_interval_s):
            self._flush(raise_errors=False)     # retry next interval

    def close(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.flush_interval_s)
            if self._thread.is_alive():
                # a flusher stuck in a hung sink outlives us; count it
                # so callers reading .stats can tell
                with self._lock:
                    self._join_timeouts += 1
        self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    @property
    def stats(self) -> dict:
        with self._lock:
            return {"sent_points": self._sent_points,
                    "sent_batches": self._sent_batches,
                    "dropped_points": self._dropped_points,
                    "failed_flushes": self._failed_flushes,
                    "join_timeouts": self._join_timeouts,
                    "buffered": len(self._buf)}
