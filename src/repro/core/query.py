"""Derived-metric query engine — planned, cached, pushdown-federated
performance-group queries (paper §V, grown query-side).

The paper's core abstraction is the LIKWID *performance group*: raw HPM
events plus formulas for derived metrics.  The seed stack derived metrics
exactly once, at collection time (``HostAgent.collect_step``), so nothing
could be derived retroactively, across measurements, or over rollup
tiers.  This module moves derivation to *query time* — the capability
MPCDF's job-specific monitoring and PerSyst both put at the center of
their analysis stacks:

* a declarative :class:`QuerySpec` (measurement, tag filters, time range,
  window, group-by tag, derived-metric expressions, top-k/order-by) that
  serializes to JSON — the same spec runs locally, against a sharded
  database, or pushed down to remote LMS instances;
* a planner (:func:`make_plan`) that compiles every formula once
  (``perf_groups.compile_formula`` — module-level parse cache) and picks
  the cheapest data tier: rollup windows when the query window nests into
  a tier (``RollupConfig.tier_for``), raw columns otherwise.  Rollup
  plans keep answering after raw-point retention;
* vectorized evaluation: per input field the engine gathers *mergeable*
  ``WindowAgg`` partials, aligns them into window columns per group, and
  applies each compiled expression across all windows in one pass
  (``CompiledFormula.eval_columns``) — including cross-measurement joins
  written as ``measurement.field`` (e.g. a roofline fraction mixing
  ``hpm`` and ``system`` inputs);
* an LRU result cache keyed by ``(plan fingerprint, per-measurement
  ingest watermark)`` (:meth:`Database.data_version`): repeated dashboard
  renders are O(1) dict hits until new points actually arrive;
* shard/federation transparency: collection happens through the partials
  protocol from PR 2, so a ``ShardedDatabase`` executes the sub-plan per
  shard and merges ``WindowAgg`` state, and backends exposing
  ``query_partials`` (``HttpQueryClient`` via ``POST /query/v2``,
  ``FederatedQuery`` fanning out) receive the *whole spec* in one round
  trip and plan against their own tier/retention state — the pushdown
  path that replaces pulling raw series over the wire.

Range semantics (windowed specs): ``t_min``/``t_max`` bound the result at
*window* granularity — a window is included iff its epoch-aligned start
lies in ``[t_min - t_min % w, t_max - t_max % w]``.  The raw fallback
expands its point-level scan to the same whole windows, so the rollup and
raw tiers answer identically whenever both hold the data (the planner
property tests pin this).
"""

from __future__ import annotations

import hashlib
import json
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Optional

from repro.core.perf_groups import (HW_CONSTANTS, CompiledFormula,
                                    compile_formula, formula_for)
from repro.core.rollup import ROLLUP_AGGS, known_agg, quantile_of
from repro.core.shard import (decode_partials, encode_partials,
                              merge_scalar_partials, merge_windowed_partials)
from repro.core.tsdb import Series, _agg

__all__ = [
    "QueryEngine", "QueryPlan", "QueryResult", "QuerySpec",
    "collect_backend_partials", "decode_plan_partials",
    "derived_rollup_series", "derived_select_series",
    "encode_plan_partials", "evaluate_plan", "make_plan",
]


# --------------------------------------------------------------------------
# The declarative spec
# --------------------------------------------------------------------------


def _normalize_metrics(metrics) -> tuple:
    """Canonical ``((name, expr_or_None), ...)``.

    Accepted entries:

    * ``"field"`` — passthrough of a stored field;
    * ``"name=expr"`` — derived metric with an explicit formula;
    * ``"@metric"`` / ``"@GROUP.metric"`` — derived metric resolved from
      the registered performance groups (``perf_groups.formula_for``), so
      a spec can name ``@hbm_bw_util`` and have the MEM group's formula
      applied at query time over stored raw events;
    * ``(name, expr)`` / ``(name, None)`` pairs (the canonical form).
    """
    if isinstance(metrics, str):
        metrics = (metrics,)
    out = []
    for m in metrics:
        if isinstance(m, str):
            if m.startswith("@"):
                ref = m[1:]
                expr = formula_for(ref)
                if expr is None:
                    raise ValueError(f"no performance group defines "
                                     f"metric {ref!r}")
                name = ref.rpartition(".")[2]
                out.append((name, expr))
            elif "=" in m:
                name, _, expr = m.partition("=")
                out.append((name.strip(), expr.strip()))
            else:
                out.append((m, None))
        else:
            name, expr = m
            out.append((str(name), None if expr is None else str(expr)))
    if not out:
        raise ValueError("QuerySpec needs at least one metric")
    seen = set()
    for name, _ in out:
        if name in seen:
            raise ValueError(f"duplicate metric name {name!r}")
        seen.add(name)
    return tuple(out)


@dataclass(frozen=True)
class QuerySpec:
    """One declarative query, compiled once into a :class:`QueryPlan`.

    ``agg`` reduces each input field's windows to a value before formulas
    apply (per-window means by default — the same inputs the offline
    perf-group derivation saw per step).  ``order_by``/``order_agg``/
    ``limit`` rank groups by a result metric reduced over its windows and
    keep the top-k (server-side: applied after the federated merge).
    """

    measurement: str
    metrics: tuple
    tags: tuple = ()
    t_min: Optional[int] = None
    t_max: Optional[int] = None
    window_ns: Optional[int] = None
    group_by: Optional[str] = None
    agg: str = "mean"
    order_by: Optional[str] = None
    order_agg: str = "mean"
    limit: Optional[int] = None
    descending: bool = True

    def __post_init__(self):
        if not self.measurement:
            raise ValueError("QuerySpec needs a measurement")
        object.__setattr__(self, "metrics", _normalize_metrics(self.metrics))
        tags = self.tags
        if isinstance(tags, dict):
            tags = tags.items()
        object.__setattr__(self, "tags", tuple(
            sorted((str(k), str(v)) for k, v in tags)))
        for agg in (self.agg, self.order_agg):
            if not known_agg(agg):
                raise ValueError(f"unknown agg {agg!r} "
                                 f"(expected one of {ROLLUP_AGGS} "
                                 f"or a pNN quantile)")
        if self.window_ns is not None:
            object.__setattr__(self, "window_ns", int(self.window_ns))
            if self.window_ns <= 0:
                raise ValueError("window_ns must be positive")
        if self.limit is not None:
            object.__setattr__(self, "limit", int(self.limit))
            if self.limit <= 0:
                raise ValueError("limit must be positive")
        names = {name for name, _ in self.metrics}
        if self.order_by is not None and self.order_by not in names:
            raise ValueError(f"order_by {self.order_by!r} is not one of "
                             f"the spec's metrics {sorted(names)}")

    # -- wire form -----------------------------------------------------------

    def to_dict(self) -> dict:
        return {"measurement": self.measurement,
                "metrics": [list(m) for m in self.metrics],
                "tags": dict(self.tags),
                "t_min": self.t_min, "t_max": self.t_max,
                "window_ns": self.window_ns, "group_by": self.group_by,
                "agg": self.agg, "order_by": self.order_by,
                "order_agg": self.order_agg, "limit": self.limit,
                "descending": self.descending}

    @classmethod
    def from_dict(cls, d: dict) -> "QuerySpec":
        return cls(measurement=d["measurement"], metrics=d["metrics"],
                   tags=d.get("tags") or (), t_min=d.get("t_min"),
                   t_max=d.get("t_max"), window_ns=d.get("window_ns"),
                   group_by=d.get("group_by"), agg=d.get("agg", "mean"),
                   order_by=d.get("order_by"),
                   order_agg=d.get("order_agg", "mean"),
                   limit=d.get("limit"),
                   descending=d.get("descending", True))

    def fingerprint(self) -> str:
        """Stable content hash — the plan/result cache key half that
        identifies *what* is asked (the ingest watermark is the other
        half, identifying *over which data*)."""
        fp = getattr(self, "_fp", None)
        if fp is None:
            blob = json.dumps(self.to_dict(), sort_keys=True,
                              separators=(",", ":"))
            fp = hashlib.sha1(blob.encode()).hexdigest()
            object.__setattr__(self, "_fp", fp)
        return fp


# --------------------------------------------------------------------------
# Planning: compile formulas, resolve inputs, pick the data tier
# --------------------------------------------------------------------------


class QueryPlan:
    """A compiled spec: outputs (compiled formulas / passthroughs), the
    unique ``(measurement, field)`` inputs they need, and the tier
    decision.  Built once per (spec fingerprint, backend tier config)."""

    __slots__ = ("spec", "outputs", "inputs", "use_rollups", "tier_ns",
                 "measurements", "fingerprint")

    def __init__(self, spec: QuerySpec,
                 outputs: tuple, inputs: tuple,
                 use_rollups: bool, tier_ns: Optional[int]):
        self.spec = spec
        self.outputs = outputs      # ((name, CompiledFormula|None, refs),)
        self.inputs = inputs        # ((measurement, field), ...)
        self.use_rollups = use_rollups
        self.tier_ns = tier_ns
        self.measurements = tuple(sorted({m for m, _ in inputs}
                                         or {spec.measurement}))
        self.fingerprint = spec.fingerprint()


def _resolve_ident(ident: str, default_measurement: str):
    """Formula identifier -> input key.  ``m.f`` joins another
    measurement; bare names read the spec's measurement; hardware
    constants are compile-time constants, not inputs."""
    if "." in ident:
        m, _, f = ident.partition(".")
        return (m, f)
    if ident in HW_CONSTANTS:
        return None
    return (default_measurement, ident)


def _split_quantile_ident(ident: str):
    """``"p95(hpm.flops)"`` -> ``("hpm.flops", "p95")`` — the synthetic
    identifiers ``perf_groups`` emits for quantile calls; None for plain
    identifiers."""
    if not ident.endswith(")"):
        return None
    fn, _, rest = ident.partition("(")
    if quantile_of(fn) is None:
        return None
    return rest[:-1], fn


def make_plan(spec: QuerySpec, rollup_config=None) -> QueryPlan:
    """Compile a spec against a backend's tier layout.

    Tier selection: a windowed query is served from the rollup tiers iff
    the window nests into some tier (coarsest such tier; exact by the
    rollup design notes) — that plan survives raw retention.  A window
    that aligns with no tier falls back to a raw rescan.  Scalar specs
    (``window_ns=None``) always scan raw, like ``Database.aggregate``.

    Raw plans span the hot columns *and* the compressed cold tier
    (``repro.core.coldstore``) when one is attached: sealed fragments
    are merged under the hot columns inside ``Database.select``, so the
    collection path below is tier-transparent by construction and a raw
    plan answers byte-identically whether its range is resident, sealed,
    or straddles the seal point.  :func:`plan_tiers` reports which tiers
    a plan's range actually touches (``QueryResult.meta["tiers"]``).
    """
    outputs = []
    inputs: list = []

    def add_input(key):
        if key not in inputs:
            inputs.append(key)

    for name, expr in spec.metrics:
        if expr is None:
            key = (spec.measurement, name)
            add_input(key)
            outputs.append((name, None, ((name, key, None),)))
            continue
        cf = compile_formula(expr)
        refs = []
        for ident in cf.names:
            qs = _split_quantile_ident(ident)
            if qs is None:
                key = _resolve_ident(ident, spec.measurement)
                agg_override = None
            else:
                inner, agg_override = qs
                key = _resolve_ident(inner, spec.measurement)
                if key is None:
                    raise ValueError(
                        f"cannot take {agg_override} of constant {inner!r}")
            if key is None:
                continue
            add_input(key)
            # 3-tuple refs: a per-ref agg override (quantile calls like
            # p95(flops)) reduces the same merged partials with its own
            # agg — the partials wire form stays agg-agnostic
            refs.append((ident, key, agg_override))
        outputs.append((name, cf, tuple(refs)))
    use_rollups = False
    tier_ns = None
    if spec.window_ns is not None and rollup_config is not None:
        tier_ns = rollup_config.tier_for(spec.window_ns)
        use_rollups = tier_ns is not None
    return QueryPlan(spec, tuple(outputs), tuple(inputs), use_rollups,
                     tier_ns)


# --------------------------------------------------------------------------
# Collection: mergeable per-input partials from any backend
# --------------------------------------------------------------------------


def _raw_bounds(spec: QuerySpec):
    """Expand point-level bounds to whole windows so the raw fallback
    covers exactly the windows the rollup path would (see module notes);
    scalar specs keep point-granularity bounds."""
    w = spec.window_ns
    if w is None:
        return spec.t_min, spec.t_max
    t_min = spec.t_min - spec.t_min % w if spec.t_min is not None else None
    t_max = (spec.t_max - spec.t_max % w) + w - 1 \
        if spec.t_max is not None else None
    return t_min, t_max


def plan_tiers(plan: QueryPlan, backend) -> list:
    """Which storage tiers this plan's collection reads — planner
    metadata only (the read path itself is tier-transparent).  A
    rollup-served plan reads the rollup tier alone; a raw plan reads the
    hot columns plus, when the backend has sealed chunks overlapping the
    plan's whole-window raw bounds, the cold tier."""
    if plan.use_rollups:
        return ["rollup"]
    tiers = ["hot"]
    fn = getattr(backend, "cold_time_range", None)
    if fn is None:
        return tiers
    t_min, t_max = _raw_bounds(plan.spec)
    for m in plan.measurements:
        try:
            rng = fn(m)
        except (TypeError, ValueError):
            rng = None
        if rng is not None and \
                (t_min is None or rng[1] >= t_min) and \
                (t_max is None or rng[0] <= t_max):
            tiers.append("cold")
            break
    return tiers


def collect_backend_partials(backend, spec: QuerySpec) -> dict:
    """Execute the spec's *collection* half against one Database-shaped
    backend: ``{(measurement, field): partials}`` where partials are the
    mergeable ``aggregate_partials`` maps (``{group: {w0: WindowAgg}}``
    windowed, ``{group: WindowAgg}`` scalar).

    Plans against the backend's own ``rollup_config``: a backend whose
    raw points are gone answers from its surviving rollup tiers, a
    rollup-disabled backend from raw — per-backend tier choice is exactly
    why federation pushes the *spec* down, not a finished plan.
    """
    plan = make_plan(spec, getattr(backend, "rollup_config", None))
    tags = dict(spec.tags) or None
    out = {}
    if plan.use_rollups:
        t_min, t_max, use = spec.t_min, spec.t_max, True
    else:
        (t_min, t_max), use = _raw_bounds(spec), False
    for meas, fieldname in plan.inputs:
        out[(meas, fieldname)] = backend.aggregate_partials(
            meas, fieldname, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=spec.group_by, window_ns=spec.window_ns,
            use_rollups=use if spec.window_ns is not None else "auto")
    return out


def merge_plan_partials(parts: Iterable[dict], windowed: bool) -> dict:
    """Merge per-backend ``{input: partials}`` maps input-by-input with
    the PR 2 ``WindowAgg`` merge semantics — the gather half of the
    federated/sharded execution."""
    parts = [p for p in parts if p]
    keys: list = []
    for p in parts:
        for k in p:
            if k not in keys:
                keys.append(k)
    merge = merge_windowed_partials if windowed else merge_scalar_partials
    return {k: merge([p[k] for p in parts if k in p]) for k in keys}


# -- wire form (httpd POST /query/v2, mode=partials) -------------------------


def encode_plan_partials(collected: dict, windowed: bool) -> list:
    """JSON-safe, deterministically ordered per-input partials."""
    return [{"m": m, "field": f,
             "partials": encode_partials(collected[(m, f)], windowed)}
            for m, f in sorted(collected)]


def decode_plan_partials(items: list, windowed: bool) -> dict:
    return {(d["m"], d["field"]): decode_partials(d["partials"], windowed)
            for d in items}


# --------------------------------------------------------------------------
# Evaluation: aligned window columns -> derived metric columns
# --------------------------------------------------------------------------


@dataclass
class QueryResult:
    """Finalized result.  ``groups`` is ordered (ranked when the spec
    orders, else by group key), windowed entries are
    ``{metric: {"times": [...], "values": [...]}}``, scalar entries
    ``{metric: value}``.  ``to_json`` is canonical — equal results are
    byte-identical across local, sharded and HTTP-federated execution.
    ``meta`` (tier choice, cache hit) is diagnostics, not payload."""

    fingerprint: str
    window_ns: Optional[int]
    groups: dict
    meta: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"fingerprint": self.fingerprint,
                "window_ns": self.window_ns, "groups": self.groups}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), separators=(",", ":"))

    @classmethod
    def from_dict(cls, d: dict, meta: Optional[dict] = None) -> "QueryResult":
        return cls(d["fingerprint"], d.get("window_ns"), d["groups"],
                   meta or {})

    def column(self, metric: str, group: str = ""):
        """``(times, values)`` of one metric in one group (the dashboard
        sparkline shape); empty lists when absent."""
        g = self.groups.get(group)
        if not g or metric not in g:
            return [], []
        if self.window_ns is None:
            return [], [g[metric]]
        m = g[metric]
        return m["times"], m["values"]


def evaluate_plan(plan: QueryPlan, collected: dict) -> QueryResult:
    """Merged per-input partials -> finalized result: reduce each window
    with the spec's input agg, align columns, run every compiled formula
    across all windows, then rank/limit groups."""
    spec = plan.spec
    windowed = spec.window_ns is not None
    group_names: list = []
    for key in plan.inputs:
        for g in collected.get(key, ()):
            if g not in group_names:
                group_names.append(g)
    group_names.sort()
    groups: dict = {}
    for g in group_names:
        if windowed:
            entry = _evaluate_windowed_group(plan, collected, g)
        else:
            entry = _evaluate_scalar_group(plan, collected, g)
        if entry:
            groups[g] = entry
    groups = _rank_groups(spec, groups, windowed)
    return QueryResult(plan.fingerprint, spec.window_ns, groups,
                       meta={"tier_ns": plan.tier_ns,
                             "use_rollups": plan.use_rollups,
                             "inputs": [list(k) for k in plan.inputs]})


def _evaluate_windowed_group(plan: QueryPlan, collected: dict,
                             g: str) -> dict:
    spec = plan.spec
    # reduce each (input, agg) pair's WindowAggs once per group; shared
    # across outputs.  Windows whose aggregate cannot answer (None: empty
    # merge, quantile without a sketch / tainted) are skipped like gaps.
    vals_by_input: dict = {}

    def reduced(key, agg):
        ck = (key, agg)
        if ck not in vals_by_input:
            wins = collected.get(key, {}).get(g)
            m = None
            if wins:
                m = {}
                for w0, wa in wins.items():
                    v = wa.value(agg)
                    if v is not None:
                        m[w0] = v
                m = m or None
            vals_by_input[ck] = m
        return vals_by_input[ck]

    entry = {}
    for name, cf, refs in plan.outputs:
        if cf is None:
            vals = reduced(refs[0][1], spec.agg)
            if not vals:
                continue
            starts = sorted(vals)
            entry[name] = {"times": starts,
                           "values": [vals[w] for w in starts]}
            continue
        starts: list = []
        seen = set()
        for _, key, agg_override in refs:
            for w0 in reduced(key, agg_override or spec.agg) or ():
                if w0 not in seen:
                    seen.add(w0)
                    starts.append(w0)
        if not starts:
            continue
        starts.sort()
        cols = {}
        for ident, key, agg_override in refs:
            vals = reduced(key, agg_override or spec.agg)
            if vals is not None:
                cols[ident] = [vals.get(w0) for w0 in starts]
        derived = cf.eval_columns(cols, len(starts))
        times = [w0 for w0, v in zip(starts, derived) if v is not None]
        if times:
            entry[name] = {"times": times,
                           "values": [v for v in derived if v is not None]}
    return entry


def _evaluate_scalar_group(plan: QueryPlan, collected: dict, g: str) -> dict:
    spec = plan.spec
    vals_by_input: dict = {}

    def reduced(key, agg):
        ck = (key, agg)
        if ck not in vals_by_input:
            wa = collected.get(key, {}).get(g)
            v = None
            if wa is not None and wa.count:
                v = wa.value(agg)
            vals_by_input[ck] = v
        return vals_by_input[ck]

    entry = {}
    for name, cf, refs in plan.outputs:
        if cf is None:
            v = reduced(refs[0][1], spec.agg)
            if v is not None:
                entry[name] = v
            continue
        env = {}
        for ident, key, agg_override in refs:
            v = reduced(key, agg_override or spec.agg)
            if v is not None:
                env[ident] = v
        try:
            v = cf.eval(env)
        except (KeyError, ZeroDivisionError, OverflowError):
            continue
        if not isinstance(v, complex):      # same skip rule as eval_columns
            entry[name] = v
    return entry


def _rank_groups(spec: QuerySpec, groups: dict, windowed: bool) -> dict:
    if spec.order_by is None:
        ordered = sorted(groups)
        if spec.limit is not None:
            ordered = ordered[:spec.limit]
        return {g: groups[g] for g in ordered}
    ranked = []
    for g, entry in groups.items():
        m = entry.get(spec.order_by)
        if m is None:
            continue                    # unrankable groups drop out
        # _agg: the one aggregate dispatcher (shared with Database)
        rank = _agg(m["values"], spec.order_agg) if windowed else m
        ranked.append((rank, g))
    ranked.sort(key=lambda rg: ((-rg[0] if spec.descending else rg[0]),
                                rg[1]))
    if spec.limit is not None:
        ranked = ranked[:spec.limit]
    return {g: groups[g] for _, g in ranked}


# --------------------------------------------------------------------------
# The engine: plan cache + watermark-keyed LRU result cache
# --------------------------------------------------------------------------


class _LRUCache:
    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key):
        with self._lock:
            v = self._d.get(key)
            if v is not None:
                self._d.move_to_end(key)
            return v

    def put(self, key, value):
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def __len__(self):
        with self._lock:
            return len(self._d)


class QueryEngine:
    """Plan, execute and cache :class:`QuerySpec` queries over one
    Database-shaped backend (plain/sharded database, ``FederatedQuery``
    view or ``HttpQueryClient`` remote).

    Execution prefers a backend-side ``query_partials(spec)`` (whole-spec
    pushdown: a sharded database fans the sub-plan per shard, a remote
    client ships one ``POST /query/v2``); otherwise it collects per-input
    partials locally.  Results are cached in an LRU keyed by
    ``(plan fingerprint, per-measurement ingest watermark)`` — a repeat
    query is a dict hit until one of the touched measurements actually
    ingested (or retired) data.  Backends without ``data_version`` are
    simply never cached.
    """

    def __init__(self, backend, *, cache_size: int = 128):
        self.backend = backend
        # plans are keyed by the full spec fingerprint, which includes
        # t_min/t_max — a dashboard issuing t_max=now per render mints a
        # new fingerprint every time, so this must be bounded like the
        # result cache or a long-lived server engine leaks plans
        self._plans = _LRUCache(max(2 * cache_size, 256))
        self._cache = _LRUCache(cache_size)
        self.stats = {"queries": 0, "cache_hits": 0, "cache_misses": 0,
                      "plans_compiled": 0}

    def plan(self, spec: QuerySpec) -> QueryPlan:
        fp = spec.fingerprint()
        plan = self._plans.get(fp)
        if plan is None:
            plan = make_plan(
                spec, getattr(self.backend, "rollup_config", None))
            self._plans.put(fp, plan)
            self.stats["plans_compiled"] += 1
        return plan

    def _watermark(self, plan: QueryPlan):
        ver = getattr(self.backend, "data_version", None)
        if ver is None:
            return None
        try:
            return tuple(ver(m) for m in plan.measurements)
        except (AttributeError, ValueError):
            # a backend that cannot report a watermark — a local view
            # lacking data_version (AttributeError) or a remote whose
            # /meta doesn't serve one (ValueError): never cache, always
            # recompute; the query itself must still run
            return None

    def query(self, spec: QuerySpec) -> QueryResult:
        plan = self.plan(spec)
        self.stats["queries"] += 1
        wm = self._watermark(plan)
        if wm is not None:
            hit = self._cache.get((plan.fingerprint, wm))
            if hit is not None:
                self.stats["cache_hits"] += 1
                return hit
        self.stats["cache_misses"] += 1
        collected = self.collect(spec)
        res = evaluate_plan(plan, collected)
        # advisory: which storage tiers the collection actually spanned
        # (never part of to_json(), so parity comparisons are unaffected)
        res.meta["tiers"] = plan_tiers(plan, self.backend)
        if wm is not None:
            res.meta["watermark"] = list(wm)
            self._cache.put((plan.fingerprint, wm), res)
        return res

    def collect(self, spec: QuerySpec) -> dict:
        """Merged per-input partials for a spec (the mergeable half —
        what ``/query/v2`` mode=partials serves)."""
        qp = getattr(self.backend, "query_partials", None)
        if qp is not None:
            return qp(spec)
        return collect_backend_partials(self.backend, spec)

    def cache_info(self) -> dict:
        return {**self.stats, "cached_results": len(self._cache),
                "cached_plans": len(self._plans)}


# --------------------------------------------------------------------------
# Per-series query-time derivation (the analysis engine's rule input)
# --------------------------------------------------------------------------


def _expr_inputs(expr: str) -> list:
    """``[(ident, field, agg_override)]`` for every data input of a
    per-series rule expression — ``agg_override`` is the quantile name
    for ``pNN(field)`` calls, else None (use the caller's agg)."""
    cf = compile_formula(expr)
    inputs = []
    for ident in cf.names:
        qs = _split_quantile_ident(ident)
        fieldname, agg_override = (ident, None) if qs is None else qs
        if "." in fieldname:
            raise ValueError(
                f"per-series derivation cannot join measurements "
                f"({ident!r}); use a QuerySpec with group-by instead")
        if qs is not None or fieldname not in HW_CONSTANTS:
            inputs.append((ident, fieldname, agg_override))
    return inputs


def derived_rollup_series(db, measurement: str, name: str, expr: str, *,
                          tags: Optional[dict] = None,
                          t_min: Optional[int] = None,
                          t_max: Optional[int] = None,
                          window_ns: Optional[int] = None,
                          agg: str = "mean") -> list:
    """Evaluate ``expr`` per raw series over its rollup windows: one
    :class:`Series` per stored series with the *derived* metric as its
    single field — the shape ``AnalysisEngine`` consumes, so threshold
    rules may reference metrics that were never emitted at collection
    time (``ThresholdRule.expr``).  Windows missing an input (or hitting
    a domain error) are skipped, like any gap.  Quantile calls
    (``p95(field)``) reduce that field's rollup windows with their own
    agg — served from the window sketches when the field is opted into
    ``RollupConfig(sketch_fields=...)``, absent otherwise."""
    cf = compile_formula(expr)
    inputs = _expr_inputs(expr)
    per_series: dict = {}       # tags_key -> (tags, {ident: {w0: val}})
    for ident, fieldname, agg_override in inputs:
        for s in db.rollup_series(measurement, fieldname,
                                  agg=agg_override or agg,
                                  tags=tags, window_ns=window_ns,
                                  t_min=t_min, t_max=t_max):
            key = tuple(sorted(s.tags.items()))
            entry = per_series.get(key)
            if entry is None:
                entry = per_series[key] = (s.tags, {})
            entry[1][ident] = dict(zip(s.times,
                                       s.values.get(fieldname, ())))
    out = []
    for key in sorted(per_series):
        stags, by_ident = per_series[key]
        starts = sorted({w0 for vals in by_ident.values() for w0 in vals})
        if not starts:
            continue
        cols = {i: [vals.get(w0) for w0 in starts]
                for i, vals in by_ident.items()}
        derived = cf.eval_columns(cols, len(starts))
        times = [w0 for w0, v in zip(starts, derived) if v is not None]
        if times:
            out.append(Series(measurement, dict(stags), times,
                              {name: [v for v in derived
                                      if v is not None]}))
    return out


def _numeric_col(col: list) -> list:
    return [v if isinstance(v, (int, float)) and not isinstance(v, bool)
            else None for v in col]


def derived_select_series(db, measurement: str, name: str, expr: str, *,
                          tags: Optional[dict] = None,
                          t_min: Optional[int] = None,
                          t_max: Optional[int] = None) -> list:
    """Raw-point twin of :func:`derived_rollup_series` (rollup-disabled
    databases): evaluates the compiled expression per point over each
    series' aligned columns.

    Inputs are fetched one field per ``select`` — the remote client's
    wire form (``HttpQueryClient.select``) is single-field, and this
    function must stay federation-transparent like every other rule
    input path.  Columns of one series normally share one timestamp
    list (one store) and align by index; if they ever differ (ingest
    raced between per-field fetches on a remote), alignment falls back
    to the timestamp union.

    A quantile call (``p95(field)``) degenerates to per-point identity
    here: the quantile of a single raw point is that point.  Rules that
    need real windowed quantiles belong on the rollup path
    (:func:`derived_rollup_series`)."""
    cf = compile_formula(expr)
    inputs = _expr_inputs(expr)
    fields = sorted({f for _, f, _ in inputs})
    if not fields:          # constants-only formula: any series' clock
        return [Series(measurement, dict(s.tags), list(s.times),
                       {name: cf.eval_columns({}, len(s.times))})
                for s in db.select(measurement, None, tags, t_min, t_max)
                if s.times]
    per_series: dict = {}   # tags_key -> (tags, {field: (times, col)})
    for f in fields:
        for s in db.select(measurement, [f], tags, t_min, t_max):
            key = tuple(sorted(s.tags.items()))
            entry = per_series.get(key)
            if entry is None:
                entry = per_series[key] = (s.tags, {})
            entry[1][f] = (s.times, _numeric_col(s.values.get(f, [])))
    out = []
    for key in sorted(per_series):
        stags, by_field = per_series[key]
        time_lists = [t for t, _ in by_field.values()]
        if all(t == time_lists[0] for t in time_lists[1:]):
            times0 = time_lists[0]
            by_f = {f: col for f, (_, col) in by_field.items()}
        else:               # rare cross-fetch skew: align on the union
            times0 = sorted({t for ts, _ in by_field.values() for t in ts})
            by_f = {f: [m.get(t) for t in times0]
                    for f, (ts, col) in by_field.items()
                    for m in (dict(zip(ts, col)),)}
        cols = {ident: by_f[f] for ident, f, _ in inputs if f in by_f}
        derived = cf.eval_columns(cols, len(times0))
        times = [t for t, v in zip(times0, derived) if v is not None]
        if times:
            out.append(Series(measurement, dict(stags), times,
                              {name: [v for v in derived
                                      if v is not None]}))
    return out
