"""Per-job performance fingerprints — windowed metric quantiles per job.

PerSyst (PAPERS.md, arxiv 2009.06061) aggregates site-wide performance
properties via *quantiles* precisely because means hide pathological
tails; the MPCDF job-monitoring system builds its per-job analysis on the
same insight.  This module derives that statistical foundation for LMS: a
job's *fingerprint* is a vector of per-metric quantiles (p50/p95/p99 by
default) computed over the job's windowed rollup data, persisted as an
``analysis``-measurement point so a fleet of past runs is queryable like
any other series.

How quantiles are obtained, in preference order:

* **Sketch-exact** — fields opted into ``RollupConfig(sketch_fields=...)``
  carry a mergeable :class:`repro.core.rollup.QuantileSketch` per rollup
  window; merging every window of the job yields quantiles over *all raw
  points* of the job (within the sketch's relative-accuracy bound), even
  after retention dropped the raw points, and identically across shards
  and HTTP federation (sketch merge is exact).
* **Window-mean fallback** — unsketched fields fall back to the exact
  nearest-rank quantile over the job's per-window means: deterministic
  and retention-proof, but a distribution of window means rather than of
  raw points (documented coarsening, not an error).
* **Raw fallback** — rollup-disabled databases compute exact quantiles
  from a raw scan.

The fleet rule (``AnalysisEngine``): a finished job whose ``p95``
fingerprint sits more than ``sigma`` (default 3) standard deviations from
the distribution of its *own past runs* (same family: jobname tag, else
user) is flagged through the normal alert surface (``/alerts``), see
:func:`fingerprint_outliers`.

Everything here is pure functions over the Database query surface — no
locks, no threads; the caller (``AnalysisEngine``) provides exclusion.
"""

from __future__ import annotations

import json
import math
from typing import Iterable, Optional

from repro.core.line_protocol import Point
from repro.core.rollup import QUANTILE_AGGS, quantile_of

# tag value marking fingerprint points within the analysis measurement
FINGERPRINT_KIND = "job_fingerprint"

# default analysis-series measurement name (analysis.ANALYSIS_MEASUREMENT;
# duplicated literal — analysis.py imports this module, not vice versa)
_ANALYSIS_MEASUREMENT = "analysis"


def _exact_quantile(vals: list, q: float) -> Optional[float]:
    """Exact nearest-rank percentile (rank ``ceil(q*n) - 1``, 0-based) —
    the same convention ``QuantileSketch.quantile`` approximates."""
    if not vals:
        return None
    s = sorted(vals)
    return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]


def _numeric(vals: Iterable) -> list:
    return [v for v in vals
            if isinstance(v, (int, float)) and not isinstance(v, bool)
            and v == v]


def job_fingerprint(db, jobid: str,
                    measurements: tuple = ("hpm", "system"),
                    quantiles: tuple = QUANTILE_AGGS) -> dict:
    """``{metric: {"p50": v, "p95": v, "p99": v}}`` for one job.

    Works against any Database-shaped backend (local, sharded,
    ``FederatedQuery`` — the partials it reads already federate).  The
    first measurement claims a duplicated field name, like the engine's
    job reports.  Metrics with no numeric data are omitted; an empty dict
    means "no fingerprintable data".
    """
    tags = {"jobid": jobid}
    rollups = getattr(db, "rollup_config", None) is not None
    fp: dict = {}
    for meas in measurements:
        for fieldname in db.field_keys(meas):
            if fieldname in fp:
                continue
            if rollups:
                parts = db.rollup_window_partials(meas, fieldname,
                                                  tags=tags)
                total = None        # whole-job merged aggregate
                means: list = []    # per-window means (fallback basis)
                for wins in parts.values():
                    for wa in wins.values():
                        if not wa.count:
                            continue
                        if total is None:
                            total = wa.fresh()
                        total.merge(wa)
                        mv = wa.value("mean")
                        if mv is not None:
                            means.append(mv)
                if total is None:
                    continue
                qs = {}
                for qname in quantiles:
                    v = total.value(qname)      # sketch answer, or None
                    if v is None:
                        v = _exact_quantile(means, quantile_of(qname))
                    if v is not None:
                        qs[qname] = v
                if qs:
                    fp[fieldname] = qs
            else:
                vals: list = []
                for s in db.select(meas, [fieldname], tags):
                    vals.extend(_numeric(s.values.get(fieldname) or ()))
                if vals:
                    fp[fieldname] = {
                        qn: _exact_quantile(vals, quantile_of(qn))
                        for qn in quantiles}
    return fp


def fingerprint_point(jobid: str, family: str, fp: dict, ts: int,
                      measurement: str = _ANALYSIS_MEASUREMENT) -> Point:
    """The persisted form: one analysis-measurement point per finished
    job, tagged for fleet queries (kind/jobid/family), carrying the whole
    vector as a JSON blob plus one flattened numeric field per
    (metric, quantile) — ``"<metric>.<quantile>"`` (dots, not colons:
    line-protocol field names must stay separator-clean)."""
    tags = {"kind": FINGERPRINT_KIND, "jobid": jobid}
    if family:
        tags["family"] = family
    fields: dict = {"fingerprint": json.dumps(fp, sort_keys=True)}
    for metric, qs in sorted(fp.items()):
        for qname, v in sorted(qs.items()):
            fields[f"{metric}.{qname}"] = float(v)
    return Point(measurement, tags, fields, ts)


def load_fingerprints(db, *, family: Optional[str] = None,
                      jobid: Optional[str] = None,
                      measurement: str = _ANALYSIS_MEASUREMENT) -> list:
    """Past-run fingerprints, oldest first:
    ``[{"jobid", "family", "ts", "fingerprint"}]``."""
    tags = {"kind": FINGERPRINT_KIND}
    if family:
        tags["family"] = family
    if jobid:
        tags["jobid"] = jobid
    out = []
    for s in db.select(measurement, ["fingerprint"], tags):
        col = s.values.get("fingerprint") or ()
        for t, v in zip(s.times, col):
            if not isinstance(v, str):
                continue
            try:
                fp = json.loads(v)
            except ValueError:
                continue
            out.append({"jobid": s.tags.get("jobid", ""),
                        "family": s.tags.get("family", ""),
                        "ts": t, "fingerprint": fp})
    out.sort(key=lambda e: (e["ts"], e["jobid"]))
    return out


def fingerprint_outliers(fp: dict, history: list, *, sigma: float = 3.0,
                         min_runs: int = 3, quantile: str = "p95") -> list:
    """The fleet rule: metrics whose ``quantile`` value sits more than
    ``sigma`` standard deviations from the job's own past runs.

    ``history`` is a list of past fingerprint dicts (same family, this
    job excluded).  A metric participates only with ``min_runs`` past
    observations — a first or second run has no distribution to deviate
    from.  The deviation scale is floored (relative 1e-9 of the mean) so
    float jitter between byte-similar runs can never fire the rule on a
    zero-variance history."""
    out = []
    for metric, qs in sorted(fp.items()):
        v = qs.get(quantile)
        if not isinstance(v, (int, float)):
            continue
        past = []
        for h in history:
            hv = h.get(metric)
            hv = hv.get(quantile) if isinstance(hv, dict) else None
            if isinstance(hv, (int, float)) and not isinstance(hv, bool):
                past.append(hv)
        if len(past) < min_runs:
            continue
        mu = sum(past) / len(past)
        sd = math.sqrt(sum((p - mu) ** 2 for p in past) / len(past))
        floor = max(sd, abs(mu) * 1e-9, 1e-12)
        z = abs(v - mu) / floor
        if z > sigma:
            out.append({"metric": metric, "quantile": quantile,
                        "value": v, "mean": mu, "sd": sd,
                        "z": z, "runs": len(past)})
    return out
