"""Sharded TSDB + federated scatter-gather queries — the multi-node LMS.

The paper (§III.C) runs one router and one InfluxDB, sized for "small to
medium sized commodity clusters"; job-specific monitoring at larger scale
(MPCDF's system, PerSyst) partitions collection and layers aggregation on
top.  This module is that layer for the embedded TSDB:

* :class:`ShardedDatabase` — hash-partitions series keys across N
  independent :class:`repro.core.tsdb.Database` shards.  Each shard has
  its own lock, rollup tiers and retention, so concurrent batched writes
  from different hosts land on different shards and no longer contend on
  a single ``RLock``.  The full ``Database`` query surface is preserved,
  so the HTTP endpoint, the dashboard agent and the analysis rules are
  shard-transparent.

* :class:`FederatedQuery` — scatter-gather over any mix of *backends*
  (local ``Database``/``ShardedDatabase`` objects or
  ``repro.core.httpd.HttpQueryClient`` remotes, i.e. other LMS router
  instances).  Queries fan out, partial results come back as mergeable
  :class:`repro.core.rollup.WindowAgg` state, and the gather side merges
  them with the existing rollup merge semantics (sums add, mins min,
  ``last`` = lexicographic ``(t, v)`` max, ``mean`` = merged sum/count) —
  so federated answers are **exactly** what a single database fed the
  union of the points would return, for every agg in ``ROLLUP_AGGS``.

Sharding invariants
-------------------

* A series key is ``(measurement, sorted(tags.items()))``; the shard
  index is ``crc32(key) % N`` (:func:`shard_index`) — stable across
  processes and Python hash randomization, so a persisted/replayed stream
  lands on the same shards.
* Every series lives on exactly one shard: ``select`` and
  ``rollup_series`` federate by *concatenation*, no merging needed.
* Windowed state is epoch-aligned (``t - t % window_ns``) on every shard,
  so per-window partials from different shards line up key-for-key and
  merge losslessly (see ``rollup.py`` design notes).
"""

from __future__ import annotations

import zlib
from typing import Iterable, List, Optional

from repro.core.line_protocol import Point
from repro.core.rollup import (QuantileSketch, RollupConfig, SketchAgg,
                               WindowAgg, finalize_scalar, finalize_windowed,
                               merge_window_maps)
from repro.core.tsdb import Database, _tags_key

__all__ = [
    "FederatedQuery", "ShardedDatabase", "shard_index",
    "merge_scalar_partials", "merge_windowed_partials",
    "finalize_scalar", "finalize_windowed",
    "windowagg_to_dict", "windowagg_from_dict",
    "encode_partials", "decode_partials",
]


def shard_index(measurement: str, tags_key: tuple, n_shards: int) -> int:
    """Stable shard index for one series key (crc32, not ``hash()`` —
    Python string hashing is randomized per process)."""
    h = zlib.crc32(repr((measurement, tags_key)).encode())
    return h % n_shards


# --------------------------------------------------------------------------
# Partial-aggregate merge/finalize helpers (the gather half)
# --------------------------------------------------------------------------


def merge_scalar_partials(parts: Iterable[dict]) -> dict:
    """Merge ``{group: WindowAgg}`` maps from disjoint series sets.

    Groups contributed by exactly one backend (the common case when
    grouping by a shard-local tag like ``hostname`` — a series lives on
    exactly one shard) are adopted as-is: partials are fresh per-call
    merge products, so reuse is safe and the gather side pays only for
    groups that truly span backends."""
    grouped: dict = {}
    for p in parts:
        for g, agg in p.items():
            grouped.setdefault(g, []).append(agg)
    out: dict = {}
    for g, aggs in grouped.items():
        if len(aggs) == 1:
            out[g] = aggs[0]
            continue
        # fresh() of the first partial: the merge product keeps the
        # aggregate-family kind (a sketch-carrying partial merges into a
        # sketch-carrying result; mixed kinds degrade via tainting)
        cur = out[g] = aggs[0].fresh()
        for agg in aggs:
            cur.merge(agg)
    return out


def merge_windowed_partials(parts: Iterable[dict]) -> dict:
    """Merge ``{group: {window_start: WindowAgg}}`` maps (same
    singleton-group adoption as :func:`merge_scalar_partials`)."""
    grouped: dict = {}
    for p in parts:
        for g, wins in p.items():
            grouped.setdefault(g, []).append(wins)
    return {g: maps[0] if len(maps) == 1 else merge_window_maps(maps)
            for g, maps in grouped.items()}


# finalize_scalar / finalize_windowed — the finalize half of the gather —
# are canonical in repro.core.rollup (every query layer shares the same
# None-skipping semantics) and re-exported here for the gather-side API.


# -- wire form (httpd /query?partials=1) ------------------------------------


def windowagg_to_dict(wa: WindowAgg) -> dict:
    """Versioned wire form: the six scalar keys are the v1 form every
    peer understands; sketch-carrying aggregates add a ``"sketch"`` key
    that old peers simply ignore (their merge of the scalar keys stays
    exact, quantiles degrade to None via tainting on the asking side)."""
    d = {"count": wa.count, "sum": wa.sum, "min": wa.min, "max": wa.max,
         "last_t": wa.last_t, "last_v": wa.last_v}
    sk = getattr(wa, "sketch", None)
    if sk is not None:
        d["sketch"] = sk.to_state()
    return d


def windowagg_from_dict(d: dict) -> WindowAgg:
    """Inverse of :func:`windowagg_to_dict`; plain 6-key dicts from
    older-version peers decode as scalar aggregates."""
    sk = d.get("sketch")
    if sk is not None:
        sketch = QuantileSketch.from_state(sk)
        wa = SketchAgg(sketch.rel_acc, sketch.max_bins)
        wa.sketch = sketch
    else:
        wa = WindowAgg()
    wa.count = d["count"]
    wa.sum = d["sum"]
    wa.min = d["min"]
    wa.max = d["max"]
    wa.last_t = d["last_t"]
    wa.last_v = d["last_v"]
    return wa


def encode_partials(parts: dict, windowed: bool) -> dict:
    """JSON-safe form (window starts stringified — JSON keys)."""
    if windowed:
        return {g: {str(w0): windowagg_to_dict(wa) for w0, wa in wins.items()}
                for g, wins in parts.items()}
    return {g: windowagg_to_dict(wa) for g, wa in parts.items()}


def decode_partials(payload: dict, windowed: bool) -> dict:
    if windowed:
        return {g: {int(w0): windowagg_from_dict(d) for w0, d in wins.items()}
                for g, wins in payload.items()}
    return {g: windowagg_from_dict(d) for g, d in payload.items()}


# --------------------------------------------------------------------------
# Federated scatter-gather query layer
# --------------------------------------------------------------------------


class FederatedQuery:
    """Scatter-gather queries over Database-shaped backends.

    Backends must expose the partials surface
    (``aggregate_partials`` / ``rollup_window_partials``) plus the
    read-only ``Database`` methods they federate.  Local shards, whole
    ``ShardedDatabase`` objects and ``HttpQueryClient`` remotes all
    qualify, and the merged output of :meth:`aggregate_partials` is itself
    mergeable — federations nest (shards inside an instance, instances
    inside a deployment).

    Exactness requires backends to hold *disjoint* series sets (true for
    shards by construction; for multi-instance deployments route each
    host's metrics to one instance).
    """

    def __init__(self, backends: Iterable):
        self.backends = list(backends)
        if not self.backends:
            raise ValueError("FederatedQuery needs at least one backend")
        self._remote = [i for i, b in enumerate(self.backends)
                        if getattr(b, "is_remote", False)]
        self._executor = None       # lazily created, reused across queries

    @property
    def rollup_config(self):
        """The backends' rollup layout — what rollup-aware readers
        (dashboards, rule evaluation) introspect to stay on the
        rollup-served path through a federated view.  Answers with the
        first backend's non-None config (local attribute or a remote's
        fetched-and-cached one); None only if no backend has rollups.
        Assumes a uniform deployment, like the merge rules do."""
        for b in self.backends:
            cfg = getattr(b, "rollup_config", None)
            if cfg is not None:
                return cfg
        return None

    # -- scatter -------------------------------------------------------------

    def _fanout(self, call) -> list:
        """``[call(b) for b in backends]`` — but remote backends (HTTP
        round-trips) run concurrently, so a federated query costs ~the
        slowest instance, not the sum, and local shards stay inline (no
        thread overhead on the common path).  The worker pool is created
        once and reused — its lifetime matches the backends'."""
        if len(self._remote) < 2:
            return [call(b) for b in self.backends]
        if self._executor is None:
            from concurrent.futures import ThreadPoolExecutor
            self._executor = ThreadPoolExecutor(
                max_workers=len(self._remote),
                thread_name_prefix="lms-federate")
        results = [None] * len(self.backends)
        futs = {i: self._executor.submit(call, self.backends[i])
                for i in self._remote}
        for i, b in enumerate(self.backends):
            if i not in futs:
                results[i] = call(b)
        for i, f in futs.items():
            results[i] = f.result()
        return results

    def aggregate_partials(self, measurement: str, field: str, **kw) -> dict:
        parts = self._fanout(
            lambda b: b.aggregate_partials(measurement, field, **kw))
        if kw.get("window_ns") is None:
            return merge_scalar_partials(parts)
        return merge_windowed_partials(parts)

    def query_partials(self, spec) -> dict:
        """Whole-spec pushdown of a ``repro.core.query.QuerySpec``: each
        backend executes the full sub-plan (against its *own* tier and
        retention state — backends exposing ``query_partials``, i.e.
        remote instances and nested federations, receive the spec in one
        round trip) and the per-input ``WindowAgg`` partials merge with
        the standard rules.  Replaces pulling raw series off remotes."""
        from repro.core.query import (collect_backend_partials,
                                      merge_plan_partials)

        def collect(b):
            qp = getattr(b, "query_partials", None)
            return qp(spec) if qp is not None \
                else collect_backend_partials(b, spec)

        return merge_plan_partials(self._fanout(collect),
                                   spec.window_ns is not None)

    def data_version(self, measurement=None) -> int:
        """Summed backend watermarks — moves iff some backend's data for
        the measurement moved, which is all the query cache needs.
        Raises AttributeError if any backend cannot report one (the
        engine then simply never caches over this view)."""
        return sum(b.data_version(measurement) for b in self.backends)

    def rollup_window_partials(self, measurement: str, field: str,
                               **kw) -> dict:
        return merge_windowed_partials(self._fanout(
            lambda b: b.rollup_window_partials(measurement, field, **kw)))

    # -- gather + finalize (Database-shaped results) -------------------------

    def aggregate(self, measurement: str, field: str, *, agg: str = "mean",
                  tags: Optional[dict] = None, t_min: Optional[int] = None,
                  t_max: Optional[int] = None,
                  group_by_tag: Optional[str] = None,
                  window_ns: Optional[int] = None,
                  use_rollups: object = "auto"):
        merged = self.aggregate_partials(
            measurement, field, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=group_by_tag, window_ns=window_ns,
            use_rollups=use_rollups)
        if window_ns is None:
            return finalize_scalar(merged, agg)
        return finalize_windowed(merged, agg)

    def rollup_aggregate(self, measurement: str, field: str, *,
                         agg: str = "mean", tags: Optional[dict] = None,
                         t_min: Optional[int] = None,
                         t_max: Optional[int] = None,
                         group_by_tag: Optional[str] = None,
                         window_ns: Optional[int] = None):
        return finalize_windowed(self.rollup_window_partials(
            measurement, field, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=group_by_tag, window_ns=window_ns), agg)

    # -- concatenating / union / summing fan-outs ----------------------------

    def select(self, measurement: str, fields: Optional[list] = None,
               tags: Optional[dict] = None, t_min: Optional[int] = None,
               t_max: Optional[int] = None) -> list:
        out: list = []
        for b in self.backends:
            out.extend(b.select(measurement, fields, tags, t_min, t_max))
        return out

    def rollup_series(self, measurement: str, field: str, *,
                      agg: str = "mean", tags: Optional[dict] = None,
                      window_ns: Optional[int] = None,
                      t_min: Optional[int] = None,
                      t_max: Optional[int] = None) -> list:
        out: list = []
        for b in self.backends:
            out.extend(b.rollup_series(measurement, field, agg=agg,
                                       tags=tags, window_ns=window_ns,
                                       t_min=t_min, t_max=t_max))
        return out

    def rollup_window_count(self, measurement: str, field: str, *,
                            tags: Optional[dict] = None,
                            tier_ns: Optional[int] = None) -> int:
        return sum(b.rollup_window_count(measurement, field, tags=tags,
                                         tier_ns=tier_ns)
                   for b in self.backends)

    def measurements(self) -> list:
        out: set = set()
        for b in self.backends:
            out.update(b.measurements())
        return sorted(out)

    def field_keys(self, measurement: str) -> list:
        out: set = set()
        for b in self.backends:
            out.update(b.field_keys(measurement))
        return sorted(out)

    def tag_values(self, measurement: str, tag: str) -> list:
        out: set = set()
        for b in self.backends:
            out.update(b.tag_values(measurement, tag))
        return sorted(out)

    def point_count(self) -> int:
        return sum(b.point_count() for b in self.backends)

    def stored_points(self) -> int:
        return sum(b.stored_points() for b in self.backends)

    def cold_time_range(self, measurement=None):
        """Combined sealed-chunk time span over backends that have a
        cold tier (``None`` when none do) — planner metadata only, so
        remotes without the surface are simply skipped."""
        lo = hi = None
        for b in self.backends:
            fn = getattr(b, "cold_time_range", None)
            rng = fn(measurement) if fn is not None else None
            if rng is None:
                continue
            if lo is None or rng[0] < lo:
                lo = rng[0]
            if hi is None or rng[1] > hi:
                hi = rng[1]
        return None if lo is None else (lo, hi)


# --------------------------------------------------------------------------
# Sharded database
# --------------------------------------------------------------------------


class ShardedDatabase:
    """Hash-partitioned drop-in for :class:`Database`.

    Writes group a batch per shard first (one crc32 per point), then hand
    each shard its sub-batch: the shard's own batched column-extend path
    runs under *that shard's* lock only, so writers touching different
    hosts proceed in parallel with each other and with readers of other
    shards.  All queries go through an internal :class:`FederatedQuery`
    over the shards.
    """

    def __init__(self, name: str, shards: int = 4,
                 rollup_config: Optional[RollupConfig] = RollupConfig()):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.name = name
        self.rollup_config = rollup_config
        self.shards: List[Database] = [
            Database(f"{name}#{i}", rollup_config) for i in range(shards)]
        self._fed = FederatedQuery(self.shards)

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def shard_for(self, measurement: str, tags: dict) -> Database:
        return self.shards[shard_index(measurement, _tags_key(tags),
                                       len(self.shards))]

    # -- write ---------------------------------------------------------------

    def write(self, points: Iterable[Point]):
        n = len(self.shards)
        if n == 1:
            self.shards[0].write(points)
            return
        # one grouping pass for the whole batch: series keys are computed
        # once per point (shared with Database.write) and the crc32 route
        # once per *series*, then each shard applies its pre-grouped
        # slice under its own lock
        by_series, tags_of = Database.group_points(points)
        if not by_series:
            return
        shard_series: dict = {}
        shard_tags: dict = {}
        for key, items in by_series.items():
            i = shard_index(key[0], key[1], n)
            if i not in shard_series:
                shard_series[i] = {}
                shard_tags[i] = {}
            shard_series[i][key] = items
            shard_tags[i][key] = tags_of[key]
        for i, groups in shard_series.items():
            self.shards[i].write_grouped(groups, shard_tags[i])

    def write_columns(self, by_cols: dict, tags_of: dict):
        """Columnar twin of :meth:`write` (same shapes as
        ``Database.write_columns``): route each series' columns to its
        shard, then apply per shard under that shard's lock — the binary
        ingest plane's path onto a sharded backend."""
        n = len(self.shards)
        if n == 1:
            self.shards[0].write_columns(by_cols, tags_of)
            return
        shard_cols: dict = {}
        shard_tags: dict = {}
        for key, tc in by_cols.items():
            i = shard_index(key[0], key[1], n)
            if i not in shard_cols:
                shard_cols[i] = {}
                shard_tags[i] = {}
            shard_cols[i][key] = tc
            shard_tags[i][key] = tags_of[key]
        for i, cols_map in shard_cols.items():
            self.shards[i].write_columns(cols_map, shard_tags[i])

    # -- retention (per shard, each under its own lock) ----------------------

    def enforce_retention(self, max_age_ns: Optional[int] = None,
                          max_points_per_series: Optional[int] = None,
                          rollup_max_age_ns: Optional[int] = None) -> dict:
        out = {"raw_points_dropped": 0, "rollup_windows_dropped": 0}
        for shard in self.shards:
            r = shard.enforce_retention(max_age_ns, max_points_per_series,
                                        rollup_max_age_ns)
            for k in out:
                out[k] += r.get(k, 0)
        return out

    # -- queries: scatter-gather over the shards -----------------------------

    def select(self, measurement: str, fields: Optional[list] = None,
               tags: Optional[dict] = None, t_min: Optional[int] = None,
               t_max: Optional[int] = None) -> list:
        return self._fed.select(measurement, fields, tags, t_min, t_max)

    def aggregate(self, measurement: str, field: str, **kw):
        return self._fed.aggregate(measurement, field, **kw)

    def aggregate_partials(self, measurement: str, field: str, **kw) -> dict:
        return self._fed.aggregate_partials(measurement, field, **kw)

    def rollup_aggregate(self, measurement: str, field: str, **kw):
        return self._fed.rollup_aggregate(measurement, field, **kw)

    def rollup_window_partials(self, measurement: str, field: str,
                               **kw) -> dict:
        return self._fed.rollup_window_partials(measurement, field, **kw)

    def query_partials(self, spec) -> dict:
        """Sub-plan per shard, partials merged (repro.core.query)."""
        return self._fed.query_partials(spec)

    def data_version(self, measurement=None) -> int:
        return self._fed.data_version(measurement)

    def rollup_series(self, measurement: str, field: str, **kw) -> list:
        return self._fed.rollup_series(measurement, field, **kw)

    def rollup_window_count(self, measurement: str, field: str,
                            **kw) -> int:
        return self._fed.rollup_window_count(measurement, field, **kw)

    def measurements(self) -> list:
        return self._fed.measurements()

    def field_keys(self, measurement: str) -> list:
        return self._fed.field_keys(measurement)

    def tag_values(self, measurement: str, tag: str) -> list:
        return self._fed.tag_values(measurement, tag)

    def point_count(self) -> int:
        return self._fed.point_count()

    def stored_points(self) -> int:
        return self._fed.stored_points()

    def cold_time_range(self, measurement=None):
        return self._fed.cold_time_range(measurement)
