"""Streaming rollup tiers — incremental downsampling for the LMS hot path.

The paper (§II) leans on InfluxDB's retention policies to "keep the
generated data volume under control"; related job-monitoring systems
(MPCDF's job-specific monitoring, PerSyst) go one step further and
aggregate on the fly so cluster-wide monitoring stays cheap.  This module
is that step for the embedded TSDB: every write also updates a small set
of *tiered* windowed aggregates, so

* dashboards and analysis rules read O(#windows) summaries instead of
  rescanning every raw point, and
* retention can drop raw points while the rollups keep answering windowed
  queries over the whole job lifetime.

Design notes
------------

* **Tiers.**  A :class:`RollupConfig` lists window sizes in ns (default
  1 s / 10 s / 60 s).  Each (series, field) pair keeps, per tier, a dict
  ``window_start_ns -> WindowAgg``.  Window starts are *epoch-aligned*
  (``ts - ts % tier_ns``) — the same alignment the raw windowed-aggregate
  path uses for non-negative timestamps — so a query window that is a
  multiple of a tier is covered by whole tier windows and merged results
  are **exactly** equal to a naive recompute from raw points.

* **Incrementality.**  A :class:`WindowAgg` stores ``(count, sum, min,
  max, last_t, last_v)``.  All of these are order-independent (``last``
  keeps the lexicographically largest ``(t, v)`` pair, matching the raw
  path's sort-then-take-last), so out-of-order ingest needs no special
  casing: the point lands in whichever window its timestamp belongs to.

* **Mergeability.**  Two ``WindowAgg``s combine losslessly (sums add,
  mins min, ...), which is what lets a 60 s query window be served from
  either the 60 s tier directly or from 60 merged 1 s windows, and what
  lets per-series windows merge across a ``group_by_tag`` group.
  ``mean`` is derived as ``sum / count`` at query time and is therefore
  exact after any merge.

* **Retention.**  Rollups live beside the raw columns and are *not*
  touched by raw-point trims; :meth:`SeriesRollups.trim` applies an
  independent (much longer) retention to the windows themselves.

* **Types.**  Only real numbers are rolled up (bools and strings are
  excluded, matching ``Database.aggregate``'s numeric filter); event
  series simply have no rollup state.

Thread-safety is inherited from the owning ``Database``: all mutation and
query entry points are called under the database lock.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Tuple

# 1 s / 10 s / 60 s — finest tier first; coarser tiers must be integer
# multiples of finer ones for the query planner's nesting logic to hold.
DEFAULT_TIERS_NS: Tuple[int, ...] = (
    1_000_000_000, 10_000_000_000, 60_000_000_000)

ROLLUP_AGGS = ("mean", "min", "max", "sum", "count", "last")


@dataclass(frozen=True)
class RollupConfig:
    """Tier layout + rollup-side retention."""

    tiers_ns: Tuple[int, ...] = DEFAULT_TIERS_NS
    # drop rollup windows older than this (None = keep forever)
    max_age_ns: Optional[int] = None

    def __post_init__(self):
        tiers = tuple(sorted(int(t) for t in self.tiers_ns))
        if any(t <= 0 for t in tiers):
            raise ValueError("tier sizes must be positive")
        object.__setattr__(self, "tiers_ns", tiers)

    def tier_for(self, window_ns: int) -> Optional[int]:
        """Coarsest tier that nests exactly into ``window_ns`` windows."""
        best = None
        for t in self.tiers_ns:
            if t <= window_ns and window_ns % t == 0:
                best = t
        return best


class WindowAgg:
    """Incremental aggregate state for one (tier, window, field)."""

    __slots__ = ("count", "sum", "min", "max", "last_t", "last_v")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.last_t = None
        self.last_v = None

    def update(self, t: int, v: float):
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        if self.last_t is None or (t, v) >= (self.last_t, self.last_v):
            self.last_t, self.last_v = t, v

    def merge(self, other: "WindowAgg"):
        self.count += other.count
        self.sum += other.sum
        if other.min is not None and (self.min is None or
                                      other.min < self.min):
            self.min = other.min
        if other.max is not None and (self.max is None or
                                      other.max > self.max):
            self.max = other.max
        if other.last_t is not None and (
                self.last_t is None or
                (other.last_t, other.last_v) >= (self.last_t, self.last_v)):
            self.last_t, self.last_v = other.last_t, other.last_v

    def value(self, agg: str):
        if agg == "mean":
            return self.sum / self.count
        if agg == "min":
            return self.min
        if agg == "max":
            return self.max
        if agg == "sum":
            return self.sum
        if agg == "count":
            return float(self.count)
        if agg == "last":
            return self.last_v
        raise ValueError(f"agg {agg!r} not served by rollups")

    # -- snapshot state (repro.core.wal) -------------------------------------

    def state(self) -> list:
        """JSON-safe state list — the snapshot form (``repro.core.wal``)."""
        return [self.count, self.sum, self.min, self.max,
                self.last_t, self.last_v]

    @classmethod
    def from_state(cls, s: list) -> "WindowAgg":
        wa = cls()
        wa.count, wa.sum, wa.min, wa.max, wa.last_t, wa.last_v = s
        return wa


def _is_numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class SeriesRollups:
    """All rollup state for one series: field -> tier -> windows."""

    __slots__ = ("config", "_fields")

    def __init__(self, config: RollupConfig):
        self.config = config
        # field -> {tier_ns -> {window_start -> WindowAgg}}
        self._fields: dict = {}

    # -- write ---------------------------------------------------------------

    def observe(self, ts: int, fields: dict):
        for k, v in fields.items():
            if not _is_numeric(v):
                continue
            tiers = self._fields.get(k)
            if tiers is None:
                tiers = {t: {} for t in self.config.tiers_ns}
                self._fields[k] = tiers
            for tier_ns, wins in tiers.items():
                w0 = ts - ts % tier_ns
                agg = wins.get(w0)
                if agg is None:
                    agg = wins[w0] = WindowAgg()
                agg.update(ts, v)

    def observe_columns(self, times: list, cols: dict):
        """Column-oriented batched observe — the batched-ingest fast path.

        ``times`` is ascending; ``cols`` maps field -> value list aligned
        with ``times`` (``None`` holes for points missing the field) —
        exactly the column segments the series store just appended, so
        ingest pays no per-point restructuring.  Points of one window are
        contiguous in a sorted batch, so each window's run is aggregated
        in local variables and merged into its ``WindowAgg`` once —
        per-window instead of per-point method-call cost.
        """
        for k, col in cols.items():
            # numeric filter once per column; tier passes then run over
            # clean parallel lists with no per-point type checks
            tl: list = []
            vl: list = []
            ta, va = tl.append, vl.append
            for t, v in zip(times, col):
                tv = type(v)
                if tv is float or tv is int or (
                        v is not None and isinstance(v, (int, float))
                        and tv is not bool):
                    ta(t)
                    va(v)
            n = len(tl)
            if not n:
                continue
            tiers = self._fields.get(k)
            if tiers is None:
                tiers = {t: {} for t in self.config.tiers_ns}
                self._fields[k] = tiers
            for tier_ns, wins in tiers.items():
                i = 0
                while i < n:
                    w0 = tl[i] - tl[i] % tier_ns
                    end = w0 + tier_ns
                    # seed min/max from the first value, not +/-inf: NaN
                    # compares false everywhere, and an inf seed would leak
                    # as a fabricated min/max for all-NaN runs (the scalar
                    # WindowAgg.update path keeps the first value too)
                    v0 = vl[i]
                    s = 0.0
                    mn = v0
                    mx = v0
                    j = i
                    while j < n and tl[j] < end:
                        v = vl[j]
                        s += v
                        if v < mn:
                            mn = v
                        if v > mx:
                            mx = v
                        j += 1
                    # "last" = lexicographic (t, v) max: times ascend, so
                    # take max v among the run's final-timestamp ties
                    lt, lv = tl[j - 1], vl[j - 1]
                    p = j - 2
                    while p >= i and tl[p] == lt:
                        if vl[p] > lv:
                            lv = vl[p]
                        p -= 1
                    agg = wins.get(w0)
                    if agg is None:
                        agg = wins[w0] = WindowAgg()
                    agg.count += j - i
                    agg.sum += s
                    if agg.min is None or mn < agg.min:
                        agg.min = mn
                    if agg.max is None or mx > agg.max:
                        agg.max = mx
                    if agg.last_t is None or \
                            (lt, lv) >= (agg.last_t, agg.last_v):
                        agg.last_t, agg.last_v = lt, lv
                    i = j

    # -- query ---------------------------------------------------------------

    def fields(self) -> list:
        return list(self._fields)

    def windows(self, field: str, window_ns: int,
                t_min: Optional[int] = None,
                t_max: Optional[int] = None) -> dict:
        """``window_start -> WindowAgg`` for the requested window size.

        ``window_ns`` must be a multiple of some tier (see
        :meth:`RollupConfig.tier_for`); tier windows are re-bucketed into
        the coarser requested windows by merging.  ``t_min``/``t_max``
        filter at *window* granularity: a window is included iff it lies
        inside the epoch-aligned [t_min, t_max] window range.
        """
        tiers = self._fields.get(field)
        if tiers is None:
            return {}
        tier_ns = self.config.tier_for(window_ns)
        if tier_ns is None:
            raise ValueError(f"window {window_ns} not served by tiers "
                             f"{self.config.tiers_ns}")
        lo = None if t_min is None else t_min - t_min % window_ns
        hi = None if t_max is None else t_max - t_max % window_ns
        out: dict = {}
        for w0, agg in tiers[tier_ns].items():
            q0 = w0 - w0 % window_ns
            if (lo is not None and q0 < lo) or (hi is not None and q0 > hi):
                continue
            cur = out.get(q0)
            if cur is None:
                cur = out[q0] = WindowAgg()
            cur.merge(agg)
        return out

    # -- snapshot state (repro.core.wal) -------------------------------------

    def dump_state(self) -> dict:
        """JSON-safe dump of all window state: ``{field: {tier_ns(str):
        {window_start(str): WindowAgg.state()}}}`` (string keys — JSON
        objects).  Restoring with :meth:`restore_state` reproduces every
        rollup answer exactly, without re-observing any raw point — what
        makes crash recovery O(live data) (``repro.core.wal``)."""
        return {field: {str(tier_ns): {str(w0): agg.state()
                                       for w0, agg in wins.items()}
                        for tier_ns, wins in tiers.items()}
                for field, tiers in self._fields.items()}

    def restore_state(self, state: dict):
        """Inverse of :meth:`dump_state`.  Tiers are reconciled against the
        *current* config: dumped tiers no longer configured are dropped,
        newly configured tiers start empty (they fill from new writes)."""
        for field, tiers in state.items():
            restored = {t: {} for t in self.config.tiers_ns}
            for tier_ns, wins in tiers.items():
                tier_ns = int(tier_ns)
                if tier_ns in restored:
                    restored[tier_ns] = {int(w0): WindowAgg.from_state(s)
                                         for w0, s in wins.items()}
            self._fields[field] = restored

    # -- retention -----------------------------------------------------------

    def trim(self, now_ts: int, max_age_ns: Optional[int] = None) -> int:
        """Drop windows whose *end* is older than ``max_age_ns``;
        returns the number of windows dropped (0 = nothing changed, so
        retention need not invalidate query caches)."""
        age = max_age_ns if max_age_ns is not None else self.config.max_age_ns
        if age is None:
            return 0
        dropped = 0
        for tiers in self._fields.values():
            for tier_ns, wins in tiers.items():
                cutoff = now_ts - age
                stale = [w0 for w0 in wins if w0 + tier_ns <= cutoff]
                for w0 in stale:
                    del wins[w0]
                dropped += len(stale)
        return dropped

    def window_count(self) -> int:
        return sum(len(w) for tiers in self._fields.values()
                   for w in tiers.values())

    def tier_window_count(self, field: str, tier_ns: int) -> int:
        """Stored window count for one (field, tier) — O(1), no merge."""
        tiers = self._fields.get(field)
        if tiers is None or tier_ns not in tiers:
            return 0
        return len(tiers[tier_ns])


def merge_window_maps(maps: Iterable[dict]) -> dict:
    """Merge per-series ``window_start -> WindowAgg`` maps (group_by)."""
    out: dict = {}
    for m in maps:
        for w0, agg in m.items():
            cur = out.get(w0)
            if cur is None:
                cur = out[w0] = WindowAgg()
            cur.merge(agg)
    return out
