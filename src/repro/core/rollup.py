"""Streaming rollup tiers — incremental downsampling for the LMS hot path.

The paper (§II) leans on InfluxDB's retention policies to "keep the
generated data volume under control"; related job-monitoring systems
(MPCDF's job-specific monitoring, PerSyst) go one step further and
aggregate on the fly so cluster-wide monitoring stays cheap.  This module
is that step for the embedded TSDB: every write also updates a small set
of *tiered* windowed aggregates, so

* dashboards and analysis rules read O(#windows) summaries instead of
  rescanning every raw point, and
* retention can drop raw points while the rollups keep answering windowed
  queries over the whole job lifetime.

Design notes
------------

* **Tiers.**  A :class:`RollupConfig` lists window sizes in ns (default
  1 s / 10 s / 60 s).  Each (series, field) pair keeps, per tier, a dict
  ``window_start_ns -> WindowAgg``.  Window starts are *epoch-aligned*
  (``ts - ts % tier_ns``) — the same alignment the raw windowed-aggregate
  path uses for non-negative timestamps — so a query window that is a
  multiple of a tier is covered by whole tier windows and merged results
  are **exactly** equal to a naive recompute from raw points.

* **Aggregate family.**  Window state is a *family* of mergeable
  aggregates behind one interface — ``update(t, v)`` / ``merge(other)``
  / ``value(agg)`` / ``state()`` / ``fresh()`` — with module-level
  ``agg_from_state`` dispatching snapshot state back to the right member:

  - :class:`WindowAgg` — the scalar base: ``(count, sum, min, max,
    last_t, last_v)``.  All components are order-independent (``last``
    keeps the lexicographically largest ``(t, v)`` pair, matching the raw
    path's sort-then-take-last), so out-of-order ingest needs no special
    casing.
  - :class:`SketchAgg` — the scalar base plus a :class:`QuantileSketch`
    (DDSketch-style fixed-gamma log-binned histogram), serving
    ``p50``/``p95``/``p99`` (any ``pNN``) with relative error
    ``<= sketch_rel_acc`` against the exact nearest-rank percentile.
    Opt-in per (measurement, field) via ``RollupConfig(sketch_fields=...)``
    so the default path pays no extra memory.

* **Mergeability.**  Two aggregates combine losslessly (sums add, mins
  min, sketch bins add bin-wise), which is what lets a 60 s query window
  be served from either the 60 s tier directly or from 60 merged 1 s
  windows, what lets per-series windows merge across a ``group_by_tag``
  group, and what makes scatter-gather federation exact.  ``mean`` is
  derived as ``sum / count`` at query time and is therefore exact after
  any merge; an empty (or merged-empty) window yields ``None`` like
  ``min``/``max`` instead of dividing by zero.

* **Graceful degradation.**  A quantile asked of a plain scalar
  :class:`WindowAgg` (field not sketched, or a partial from an
  older-version peer) answers ``None`` rather than raising, and merging
  sketch-less state into a :class:`SketchAgg` *taints* the sketch (its
  quantiles turn ``None`` while the scalar components stay exact).
  Mixed-version federation therefore degrades to "no quantile for that
  window" instead of corrupting.

* **Retention.**  Rollups live beside the raw columns and are *not*
  touched by raw-point trims; :meth:`SeriesRollups.trim` applies an
  independent (much longer) retention to the windows themselves.

* **Types.**  Only real numbers are rolled up (bools and strings are
  excluded, matching ``Database.aggregate``'s numeric filter); event
  series simply have no rollup state.  Sketches additionally skip
  non-finite values (NaN/inf carry no rank information).

Thread-safety is inherited from the owning ``Database``: all mutation and
query entry points are called under the database lock.
"""

from __future__ import annotations

import math
import re

from collections import Counter
from dataclasses import dataclass
from typing import Iterable, Optional, Tuple

# 1 s / 10 s / 60 s — finest tier first; coarser tiers must be integer
# multiples of finer ones for the query planner's nesting logic to hold.
DEFAULT_TIERS_NS: Tuple[int, ...] = (
    1_000_000_000, 10_000_000_000, 60_000_000_000)

# Aggregates derivable from the scalar WindowAgg components alone.
SCALAR_AGGS = ("mean", "min", "max", "sum", "count", "last")

# Quantiles served from rollup tiers when the field carries a sketch
# (RollupConfig.sketch_fields).  Any ``pNN``/``pNN.N`` spelling is
# accepted by the query layers; these are the conventional members.
QUANTILE_AGGS = ("p50", "p95", "p99")

ROLLUP_AGGS = SCALAR_AGGS + QUANTILE_AGGS

# per-rel_acc (gamma, log gamma) constants shared by all sketches
_GAMMA_CACHE: dict = {}

# per-rel_acc bounded value -> encoded-bin-key memo for the batched ingest
# path: monitoring values are heavily quantized (utilizations, clocks,
# temperatures repeat), so most points resolve their DDSketch bin with one
# dict probe instead of a log/ceil chain
_KEY_CACHE: dict = {}
_KEY_CACHE_MAX = 32768

# encoded-key sentinel for non-finite values (real encoded keys are
# bounded by ~2*log(DBL_MAX)/log(gamma), far below this)
_SKIP_KEY = 1 << 60


def _encode_value(v: float, inv: float, kc: dict) -> int:
    """Slow path of the fused ingest loop: first sighting of a value.
    Returns ``bin_key << 1 | sign_bit`` (or ``_SKIP_KEY`` for non-finite
    values) and memoises it — except for NaN, which can never be looked
    up again (``NaN != NaN``) and would only pollute the cache."""
    if 0.0 < v < math.inf:
        c = math.ceil(math.log(v) * inv) << 1
    elif -math.inf < v < 0.0:
        c = (math.ceil(math.log(-v) * inv) << 1) | 1
    else:
        c = _SKIP_KEY
    if v == v and len(kc) < _KEY_CACHE_MAX:
        kc[v] = c
    return c

_QUANTILE_RE = re.compile(r"p(\d{1,2}(?:\.\d+)?)\Z")


def quantile_of(agg: str) -> Optional[float]:
    """``"p95"`` -> ``0.95`` (``"p99.9"`` -> ``0.999``); None if ``agg``
    is not a quantile spelling.  Only ``0 < q < 1`` spellings parse —
    ``p0``/``p100`` are min/max and have exact scalar aggregates."""
    if not isinstance(agg, str):
        return None
    m = _QUANTILE_RE.match(agg)
    if m is None:
        return None
    q = float(m.group(1)) / 100.0
    return q if 0.0 < q < 1.0 else None


def known_agg(agg: str) -> bool:
    """True iff some member of the aggregate family can serve ``agg``."""
    return agg in SCALAR_AGGS or quantile_of(agg) is not None


@dataclass(frozen=True)
class RollupConfig:
    """Tier layout, rollup-side retention, and per-field sketch opt-in."""

    tiers_ns: Tuple[int, ...] = DEFAULT_TIERS_NS
    # drop rollup windows older than this (None = keep forever)
    max_age_ns: Optional[int] = None
    # quantile-sketch opt-in: {measurement: ("field", ...)} or
    # {measurement: "*"} (all numeric fields).  Normalised to a sorted
    # tuple-of-tuples so the config stays frozen/hashable.
    sketch_fields: tuple = ()
    # DDSketch relative accuracy alpha: answered quantiles are within
    # alpha (relative) of the exact nearest-rank percentile.
    sketch_rel_acc: float = 0.01
    # bin-count cap per sketch; lowest-magnitude bins collapse beyond it
    sketch_max_bins: int = 2048

    def __post_init__(self):
        tiers = tuple(sorted(int(t) for t in self.tiers_ns))
        if any(t <= 0 for t in tiers):
            raise ValueError("tier sizes must be positive")
        object.__setattr__(self, "tiers_ns", tiers)
        if not 0.0 < self.sketch_rel_acc < 1.0:
            raise ValueError("sketch_rel_acc must be in (0, 1)")
        if self.sketch_max_bins < 8:
            raise ValueError("sketch_max_bins must be >= 8")
        sf = self.sketch_fields
        items = sf.items() if isinstance(sf, dict) else tuple(sf or ())
        norm = []
        for meas, fields in items:
            if fields == "*":
                norm.append((str(meas), "*"))
            else:
                norm.append((str(meas),
                             tuple(sorted(str(f) for f in fields))))
        object.__setattr__(self, "sketch_fields", tuple(sorted(norm)))
        object.__setattr__(self, "_sketch_map", dict(self.sketch_fields))

    def tier_for(self, window_ns: int) -> Optional[int]:
        """Coarsest tier that nests exactly into ``window_ns`` windows."""
        best = None
        for t in self.tiers_ns:
            if t <= window_ns and window_ns % t == 0:
                best = t
        return best

    # -- sketch opt-in --------------------------------------------------------

    @property
    def sketch_gamma(self) -> float:
        """Log-bin base: ``(1 + alpha) / (1 - alpha)``."""
        a = self.sketch_rel_acc
        return (1.0 + a) / (1.0 - a)

    def sketched(self, measurement: Optional[str], field: str) -> bool:
        if measurement is None:
            return False
        fields = self._sketch_map.get(measurement)
        if fields is None:
            return False
        return fields == "*" or field in fields

    def sketch_field_map(self) -> dict:
        """``{measurement: "*" | [field, ...]}`` — the ``/meta`` form."""
        return {m: ("*" if fs == "*" else list(fs))
                for m, fs in self.sketch_fields}

    def new_agg(self, measurement: Optional[str], field: str,
                tier_ns: Optional[int] = None) -> "WindowAgg":
        """Factory: the family member configured for this field.

        ``tier_ns`` is the rollup tier the window belongs to, when it
        belongs to one.  Sketch bins are maintained only on the finest
        tier — coarser tiers answer quantiles by merging finest windows
        at read time (:meth:`SeriesRollups.windows`) — so a coarser
        ``tier_ns`` yields the scalar member even for sketched fields.
        Callers outside the tier structure (cold-scan rebuilds, query-
        side merge targets) omit it and get the full member."""
        if tier_ns is not None and tier_ns != self.tiers_ns[0]:
            return WindowAgg()
        if self.sketched(measurement, field):
            return SketchAgg(self.sketch_rel_acc, self.sketch_max_bins)
        return WindowAgg()


class QuantileSketch:
    """DDSketch-style mergeable quantile sketch (fixed gamma).

    Finite values land in log-spaced bins ``key = ceil(log_gamma |v|)``
    (separate positive/negative bin maps plus an exact zero counter); a
    bin's representative ``2 * gamma^key / (gamma + 1)`` is within
    ``rel_acc`` (relative) of every value in the bin.  Bins are integer
    counters, so merging is exact bin-wise addition — commutative and
    associative — and identical point multisets yield identical bins no
    matter how ingest was batched, sharded, or federated.  Beyond
    ``max_bins`` the lowest-magnitude bins collapse upward (tail quantiles
    keep their guarantee; extreme-low quantiles coarsen).  Non-finite
    values are skipped.  ``tainted`` marks a sketch merged with sketch-less
    (or differently-parameterised) state: its quantiles answer ``None``
    while the surrounding scalar aggregate stays exact.
    """

    __slots__ = ("rel_acc", "max_bins", "gamma", "_lg", "zero",
                 "pos", "neg", "tainted", "_pending")

    def __init__(self, rel_acc: float = 0.01, max_bins: int = 2048):
        self.rel_acc = rel_acc
        self.max_bins = max_bins
        # rollups create one sketch per (window, field) — thousands per
        # series — so the per-rel_acc constants are cached module-wide
        # rather than recomputed (math.log) on every window open
        cached = _GAMMA_CACHE.get(rel_acc)
        if cached is None:
            g = (1.0 + rel_acc) / (1.0 - rel_acc)
            cached = _GAMMA_CACHE[rel_acc] = (g, math.log(g))
        self.gamma, self._lg = cached
        self.zero = 0
        self.pos: dict = {}
        self.neg: dict = {}
        self.tainted = False
        # run-level (encoded-key list, zeros) deltas from the batched
        # ingest path, counted and folded into pos/neg lazily on first
        # read (defer/_flush): ingest pays one list append per run, and
        # the flush counts keys with collections.Counter — a C loop —
        # before touching the Python-level bin dicts once per *distinct*
        # bin.  Every read entry point flushes first, so external
        # semantics are unchanged.
        self._pending: list = []

    # -- write ---------------------------------------------------------------

    def defer(self, keys: list, zeros: int):
        """Queue a run-level delta: ``keys`` is a list of encoded bin
        keys (``bin_key << 1 | sign_bit``), one per inserted value.  The
        caller must not mutate the list afterwards."""
        self._pending.append((keys, zeros))
        if len(self._pending) > 64:
            self._flush()

    def _flush(self):
        if not self._pending:
            return
        ctr: Counter = Counter()
        up = ctr.update
        for keys, zeros in self._pending:
            self.zero += zeros
            if keys:
                up(keys)
        self._pending.clear()
        if ctr:
            pos = self.pos
            neg = self.neg
            for c, cnt in ctr.items():
                if c & 1:
                    key = c >> 1
                    neg[key] = neg.get(key, 0) + cnt
                else:
                    key = c >> 1
                    pos[key] = pos.get(key, 0) + cnt
            if len(pos) + len(neg) > self.max_bins:
                self._collapse()

    def insert(self, v: float, n: int = 1):
        if not math.isfinite(v):
            return
        if v == 0:
            self.zero += n
            return
        a = v if v > 0 else -v
        key = math.ceil(math.log(a) / self._lg)
        d = self.pos if v > 0 else self.neg
        d[key] = d.get(key, 0) + n
        if len(self.pos) + len(self.neg) > self.max_bins:
            self._collapse()

    def merge(self, other: "QuantileSketch"):
        self._flush()
        other._flush()
        if other.tainted or other.rel_acc != self.rel_acc:
            self.tainted = True
        self.zero += other.zero
        pos = self.pos
        for k, c in other.pos.items():
            pos[k] = pos.get(k, 0) + c
        neg = self.neg
        for k, c in other.neg.items():
            neg[k] = neg.get(k, 0) + c
        if len(pos) + len(neg) > self.max_bins:
            self._collapse()

    def _collapse(self):
        while len(self.pos) + len(self.neg) > self.max_bins:
            d = self.pos if len(self.pos) >= len(self.neg) else self.neg
            if len(d) < 2:
                d = self.neg if d is self.pos else self.pos
            ks = sorted(d)
            k0, k1 = ks[0], ks[1]
            d[k1] = d.get(k1, 0) + d.pop(k0)

    # -- query ---------------------------------------------------------------

    def count(self) -> int:
        self._flush()
        return self.zero + sum(self.pos.values()) + sum(self.neg.values())

    def _rep(self, key: int) -> float:
        try:
            return 2.0 * self.gamma ** key / (self.gamma + 1.0)
        except OverflowError:
            return math.inf

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` using the exact nearest-rank convention
        (rank ``ceil(q*n) - 1``, 0-based) — the same convention the raw
        rescan path uses, so sketch answers are directly comparable."""
        if self.tainted:
            return None
        n = self.count()          # flushes pending run deltas
        if n == 0:
            return None
        rank = min(n - 1, max(0, math.ceil(q * n) - 1))
        acc = 0
        # ascending value order: most-negative first (largest |v| bin),
        # then zero, then positives by ascending bin
        for k in sorted(self.neg, reverse=True):
            acc += self.neg[k]
            if acc > rank:
                return -self._rep(k)
        acc += self.zero
        if acc > rank:
            return 0.0
        for k in sorted(self.pos):
            acc += self.pos[k]
            if acc > rank:
                return self._rep(k)
        return self._rep(max(self.pos)) if self.pos else 0.0

    # -- snapshot / wire state ------------------------------------------------

    def to_state(self) -> dict:
        """JSON-safe dict — rides both WAL snapshots and the federation
        wire form (string bin keys: JSON objects)."""
        self._flush()
        return {"a": self.rel_acc, "b": self.max_bins, "z": self.zero,
                "t": 1 if self.tainted else 0,
                "p": {str(k): c for k, c in self.pos.items()},
                "n": {str(k): c for k, c in self.neg.items()}}

    @classmethod
    def from_state(cls, d: dict) -> "QuantileSketch":
        sk = cls(float(d["a"]), int(d["b"]))
        sk.zero = int(d["z"])
        sk.tainted = bool(d.get("t"))
        sk.pos = {int(k): int(c) for k, c in d["p"].items()}
        sk.neg = {int(k): int(c) for k, c in d["n"].items()}
        return sk


class WindowAgg:
    """Scalar member of the aggregate family — one (tier, window, field).

    The family interface is ``update(t, v)`` / ``merge(other)`` /
    ``value(agg)`` / ``state()`` / ``fresh()`` (an empty aggregate of the
    same kind and parameters, used by every merge site so re-bucketing
    and scatter-gather preserve the member kind); ``agg_from_state``
    is the module-level inverse of ``state()``.
    """

    __slots__ = ("count", "sum", "min", "max", "last_t", "last_v")

    kind = "scalar"

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.last_t = None
        self.last_v = None

    def fresh(self) -> "WindowAgg":
        """Empty aggregate of the same kind/parameters (merge identity)."""
        return WindowAgg()

    def update(self, t: int, v: float):
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        if self.last_t is None or (t, v) >= (self.last_t, self.last_v):
            self.last_t, self.last_v = t, v

    def merge(self, other: "WindowAgg"):
        self.count += other.count
        self.sum += other.sum
        if other.min is not None and (self.min is None or
                                      other.min < self.min):
            self.min = other.min
        if other.max is not None and (self.max is None or
                                      other.max > self.max):
            self.max = other.max
        if other.last_t is not None and (
                self.last_t is None or
                (other.last_t, other.last_v) >= (self.last_t, self.last_v)):
            self.last_t, self.last_v = other.last_t, other.last_v

    def value(self, agg: str):
        """Finalise ``agg``; ``None`` = "this aggregate cannot answer"
        (empty window for ``mean``/``min``/``max``/``last``, any quantile
        for a sketch-less or tainted aggregate) — query layers skip
        ``None`` windows rather than fabricating values."""
        if agg == "mean":
            return self.sum / self.count if self.count else None
        if agg == "min":
            return self.min
        if agg == "max":
            return self.max
        if agg == "sum":
            return self.sum
        if agg == "count":
            return float(self.count)
        if agg == "last":
            return self.last_v
        if quantile_of(agg) is not None:
            return None
        raise ValueError(f"agg {agg!r} not served by rollups")

    # -- snapshot state (repro.core.wal) -------------------------------------

    def state(self) -> list:
        """JSON-safe state list — the snapshot form (``repro.core.wal``)."""
        return [self.count, self.sum, self.min, self.max,
                self.last_t, self.last_v]

    @classmethod
    def from_state(cls, s: list) -> "WindowAgg":
        """Back-compat alias for 6-element scalar state; prefer the
        family-dispatching :func:`agg_from_state`."""
        return agg_from_state(s)


class SketchAgg(WindowAgg):
    """Scalar aggregate + quantile sketch: serves ``pNN`` from rollups."""

    __slots__ = ("sketch",)

    kind = "sketch"

    def __init__(self, rel_acc: float = 0.01, max_bins: int = 2048):
        super().__init__()
        self.sketch = QuantileSketch(rel_acc, max_bins)

    def fresh(self) -> "SketchAgg":
        return SketchAgg(self.sketch.rel_acc, self.sketch.max_bins)

    def update(self, t: int, v: float):
        super().update(t, v)
        self.sketch.insert(v)

    def merge(self, other: "WindowAgg"):
        super().merge(other)
        osk = getattr(other, "sketch", None)
        if osk is not None:
            self.sketch.merge(osk)
        elif other.count:
            # sketch-less state merged in (older peer / unsketched
            # field): quantiles are no longer exact -> taint
            self.sketch.tainted = True

    def value(self, agg: str):
        q = quantile_of(agg)
        if q is not None:
            return self.sketch.quantile(q)
        return super().value(agg)

    def state(self) -> list:
        return [self.count, self.sum, self.min, self.max,
                self.last_t, self.last_v, self.sketch.to_state()]


def agg_from_state(s: list) -> WindowAgg:
    """Snapshot-state dispatch: 6-element lists are the (pre-family)
    scalar form, a 7th element is the sketch state — old snapshots
    restore as plain scalars and keep answering exactly."""
    if len(s) > 6:
        sk = QuantileSketch.from_state(s[6])
        wa = SketchAgg(sk.rel_acc, sk.max_bins)
        wa.sketch = sk
    else:
        wa = WindowAgg()
    wa.count, wa.sum, wa.min, wa.max, wa.last_t, wa.last_v = s[:6]
    return wa


def finalize_scalar(merged: dict, agg: str) -> dict:
    """``group -> aggregate`` to ``group -> value``, skipping groups whose
    aggregate cannot answer (empty, or quantile without a sketch)."""
    out = {}
    for g, wa in merged.items():
        if not wa.count:
            continue
        v = wa.value(agg)
        if v is not None:
            out[g] = v
    return out


def finalize_windowed(merged: dict, agg: str) -> dict:
    """``group -> {w0 -> aggregate}`` to ``group -> (times, values)``,
    skipping windows whose aggregate cannot answer."""
    out = {}
    for g, wins in merged.items():
        times = []
        values = []
        for w0 in sorted(wins):
            wa = wins[w0]
            if not wa.count:
                continue
            v = wa.value(agg)
            if v is None:
                continue
            times.append(w0)
            values.append(v)
        if times:
            out[g] = (times, values)
    return out


def _is_numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class SeriesRollups:
    """All rollup state for one series: field -> tier -> windows."""

    __slots__ = ("config", "measurement", "_fields")

    def __init__(self, config: RollupConfig,
                 measurement: Optional[str] = None):
        self.config = config
        # which family member each field gets (RollupConfig.new_agg)
        self.measurement = measurement
        # field -> {tier_ns -> {window_start -> WindowAgg}}
        self._fields: dict = {}

    # -- write ---------------------------------------------------------------

    def observe(self, ts: int, fields: dict):
        for k, v in fields.items():
            if not _is_numeric(v):
                continue
            tiers = self._fields.get(k)
            if tiers is None:
                tiers = {t: {} for t in self.config.tiers_ns}
                self._fields[k] = tiers
            for tier_ns, wins in tiers.items():
                w0 = ts - ts % tier_ns
                agg = wins.get(w0)
                if agg is None:
                    agg = wins[w0] = self.config.new_agg(
                        self.measurement, k, tier_ns)
                agg.update(ts, v)

    def observe_columns(self, times: list, cols: dict):
        """Column-oriented batched observe — the batched-ingest fast path.

        ``times`` is ascending; ``cols`` maps field -> value list aligned
        with ``times`` (``None`` holes for points missing the field) —
        exactly the column segments the series store just appended, so
        ingest pays no per-point restructuring.  Points of one window are
        contiguous in a sorted batch, so each window's run is aggregated
        in local variables and merged into its ``WindowAgg`` once —
        per-window instead of per-point method-call cost.  Sketched
        fields resolve each value's DDSketch bin key inline (one bounded
        value->key memo probe for the common repeated-value case) and
        hand the run's key list to the finest-tier sketch for lazy
        Counter-based folding; coarser tiers carry no sketch at all —
        quantile reads merge finest windows instead (:meth:`windows`).
        Unsketched fields pay nothing new.
        """
        for k, col in cols.items():
            # numeric filter once per column; tier passes then run over
            # clean parallel lists with no per-point type checks
            tl: list = []
            vl: list = []
            ta, va = tl.append, vl.append
            for t, v in zip(times, col):
                tv = type(v)
                if tv is float or tv is int or (
                        v is not None and isinstance(v, (int, float))
                        and tv is not bool):
                    ta(t)
                    va(v)
            n = len(tl)
            if not n:
                continue
            tiers = self._fields.get(k)
            if tiers is None:
                tiers = {t: {} for t in self.config.tiers_ns}
                self._fields[k] = tiers
            sketched = self.config.sketched(self.measurement, k)
            if sketched:
                acc = self.config.sketch_rel_acc
                cached = _GAMMA_CACHE.get(acc)
                if cached is None:
                    g = (1.0 + acc) / (1.0 - acc)
                    cached = _GAMMA_CACHE[acc] = (g, math.log(g))
                inv = 1.0 / cached[1]
                kc = _KEY_CACHE.get(acc)
                if kc is None:
                    kc = _KEY_CACHE[acc] = {}
                kc_get = kc.get
                fin_tier = self.config.tiers_ns[0]
            for tier_ns, wins in tiers.items():
                fin_sketch = sketched and tier_ns == fin_tier
                i = 0
                while i < n:
                    w0 = tl[i] - tl[i] % tier_ns
                    end = w0 + tier_ns
                    # seed min/max from the first value, not +/-inf: NaN
                    # compares false everywhere, and an inf seed would leak
                    # as a fabricated min/max for all-NaN runs (the scalar
                    # WindowAgg.update path keeps the first value too)
                    v0 = vl[i]
                    s = 0.0
                    mn = v0
                    mx = v0
                    j = i
                    if fin_sketch:
                        # fused pass: the finest tier resolves each
                        # value's DDSketch bin key alongside the scalar
                        # stats — usually one memo probe; _encode_value
                        # handles first sightings and non-finite values
                        run_keys: list = []
                        ra = run_keys.append
                        zeros = 0
                        while j < n and tl[j] < end:
                            v = vl[j]
                            s += v
                            if v < mn:
                                mn = v
                            if v > mx:
                                mx = v
                            if v == 0.0:
                                zeros += 1
                            else:
                                c = kc_get(v)
                                if c is None:
                                    c = _encode_value(v, inv, kc)
                                if c != _SKIP_KEY:
                                    ra(c)
                            j += 1
                    else:
                        while j < n and tl[j] < end:
                            v = vl[j]
                            s += v
                            if v < mn:
                                mn = v
                            if v > mx:
                                mx = v
                            j += 1
                    # "last" = lexicographic (t, v) max: times ascend, so
                    # take max v among the run's final-timestamp ties
                    lt, lv = tl[j - 1], vl[j - 1]
                    p = j - 2
                    while p >= i and tl[p] == lt:
                        if vl[p] > lv:
                            lv = vl[p]
                        p -= 1
                    agg = wins.get(w0)
                    if agg is None:
                        agg = wins[w0] = self.config.new_agg(
                            self.measurement, k, tier_ns)
                    agg.count += j - i
                    agg.sum += s
                    if agg.min is None or mn < agg.min:
                        agg.min = mn
                    if agg.max is None or mx > agg.max:
                        agg.max = mx
                    if agg.last_t is None or \
                            (lt, lv) >= (agg.last_t, agg.last_v):
                        agg.last_t, agg.last_v = lt, lv
                    if fin_sketch:
                        agg.sketch.defer(run_keys, zeros)
                    i = j

    # -- query ---------------------------------------------------------------

    def fields(self) -> list:
        return list(self._fields)

    def windows(self, field: str, window_ns: int,
                t_min: Optional[int] = None,
                t_max: Optional[int] = None, *,
                quantile: bool = False) -> dict:
        """``window_start -> WindowAgg`` for the requested window size.

        ``window_ns`` must be a multiple of some tier (see
        :meth:`RollupConfig.tier_for`); tier windows are re-bucketed into
        the coarser requested windows by merging.  ``t_min``/``t_max``
        filter at *window* granularity: a window is included iff it lies
        inside the epoch-aligned [t_min, t_max] window range.

        ``quantile=True`` asks for windows whose aggregates carry sketch
        bins.  Sketch bins are maintained only on the *finest* tier (a
        write-path economy — the ingest hot loop touches one sketch per
        value, not one per tier), so sketched fields are then decomposed
        to the finest tier: tiers nest, so merging finest windows
        reproduces every coarser tier's scalars while carrying the
        quantile bins along.  All tiers share one retention
        (``RollupConfig.max_age_ns``), so the finest tier lives exactly
        as long as the coarser ones.  Scalar reads (the default) stay on
        the coarsest serving tier — fewer windows merged, and the scalar
        accumulation order is *identical* to a sketch-free config, so
        enabling sketches never perturbs a scalar answer, not even in
        the last ulp.
        """
        tiers = self._fields.get(field)
        if tiers is None:
            return {}
        tier_ns = self.config.tier_for(window_ns)
        if tier_ns is None:
            raise ValueError(f"window {window_ns} not served by tiers "
                             f"{self.config.tiers_ns}")
        fin = self.config.tiers_ns[0]
        if quantile and tier_ns != fin and window_ns % fin == 0 \
                and self.config.sketched(self.measurement, field):
            tier_ns = fin
        lo = None if t_min is None else t_min - t_min % window_ns
        hi = None if t_max is None else t_max - t_max % window_ns
        out: dict = {}
        for w0, agg in tiers[tier_ns].items():
            q0 = w0 - w0 % window_ns
            if (lo is not None and q0 < lo) or (hi is not None and q0 > hi):
                continue
            cur = out.get(q0)
            if cur is None:
                cur = out[q0] = agg.fresh()
            cur.merge(agg)
        return out

    # -- snapshot state (repro.core.wal) -------------------------------------

    def dump_state(self) -> dict:
        """JSON-safe dump of all window state: ``{field: {tier_ns(str):
        {window_start(str): WindowAgg.state()}}}`` (string keys — JSON
        objects).  Restoring with :meth:`restore_state` reproduces every
        rollup answer exactly, without re-observing any raw point — what
        makes crash recovery O(live data) (``repro.core.wal``)."""
        return {field: {str(tier_ns): {str(w0): agg.state()
                                       for w0, agg in wins.items()}
                        for tier_ns, wins in tiers.items()}
                for field, tiers in self._fields.items()}

    def restore_state(self, state: dict):
        """Inverse of :meth:`dump_state`.  Tiers are reconciled against the
        *current* config: dumped tiers no longer configured are dropped,
        newly configured tiers start empty (they fill from new writes).
        State kind wins over config: a pre-family 6-element scalar state
        restores as a scalar even for a now-sketched field (its quantiles
        answer ``None``; new windows pick up sketches)."""
        for field, tiers in state.items():
            restored = {t: {} for t in self.config.tiers_ns}
            for tier_ns, wins in tiers.items():
                tier_ns = int(tier_ns)
                if tier_ns in restored:
                    restored[tier_ns] = {int(w0): agg_from_state(s)
                                         for w0, s in wins.items()}
            self._fields[field] = restored

    # -- retention -----------------------------------------------------------

    def trim(self, now_ts: int, max_age_ns: Optional[int] = None) -> int:
        """Drop windows whose *end* is older than ``max_age_ns``;
        returns the number of windows dropped (0 = nothing changed, so
        retention need not invalidate query caches)."""
        age = max_age_ns if max_age_ns is not None else self.config.max_age_ns
        if age is None:
            return 0
        dropped = 0
        for tiers in self._fields.values():
            for tier_ns, wins in tiers.items():
                cutoff = now_ts - age
                stale = [w0 for w0 in wins if w0 + tier_ns <= cutoff]
                for w0 in stale:
                    del wins[w0]
                dropped += len(stale)
        return dropped

    def window_count(self) -> int:
        return sum(len(w) for tiers in self._fields.values()
                   for w in tiers.values())

    def tier_window_count(self, field: str, tier_ns: int) -> int:
        """Stored window count for one (field, tier) — O(1), no merge."""
        tiers = self._fields.get(field)
        if tiers is None or tier_ns not in tiers:
            return 0
        return len(tiers[tier_ns])


def merge_window_maps(maps: Iterable[dict]) -> dict:
    """Merge per-series ``window_start -> WindowAgg`` maps (group_by).
    The first aggregate seen for a window decides the member kind (its
    ``fresh()``), so sketch-carrying maps merge into sketch-carrying
    results and mixed maps degrade via tainting."""
    out: dict = {}
    for m in maps:
        for w0, agg in m.items():
            cur = out.get(w0)
            if cur is None:
                cur = out[w0] = agg.fresh()
            cur.merge(agg)
    return out
