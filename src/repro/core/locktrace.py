"""Opt-in dynamic lock-order tracer — the runtime half of the
``repro.analyzer`` lock-order pass.

:func:`install` monkeypatches the ``threading.Lock`` / ``threading.RLock``
factories so that locks *created from allowed source files* (by default
``src/repro/core``) come back wrapped in :class:`TracingLock`.  Each
wrapper remembers its **creation site** ``(realpath, lineno)`` — the same
key the static analyzer emits in ``Report.lock_sites`` — and, per
thread, the stack of traced locks currently held.  Every first-level
acquire while another traced lock is held records a directed edge
``(held site) -> (acquired site)``.

The ``-m race`` pytest tier exercises the real stack under the tracer,
maps both endpoints of every recorded edge to ``Class.attr`` lock nodes
via the analyzer's site map, and asserts the dynamic graph is a subgraph
of the static one (so the static acyclicity proof covers every order the
tests actually executed).

Scope and honesty:

* only locks created *after* :func:`install` are traced — module-level
  singletons (``wal._SEALER`` / ``wal._FLUSHER``) predate it and stay
  untraced;
* ``threading.Condition(threading.Lock())`` is traced through its inner
  lock (the factory call evaluates in the caller's frame);
* re-acquires of an RLock already held by the thread record no edge;
* overhead is one frame inspection per lock *creation* and a dict
  update per contested acquire — never install this outside tests.
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Iterable, Optional

__all__ = ["TracingLock", "install", "uninstall", "installed", "reset",
           "edges", "sites", "find_cycle"]

_ORIG_LOCK = threading.Lock
_ORIG_RLOCK = threading.RLock

# registry state guarded by a REAL lock (created at import, pre-patch)
_REG_LOCK = threading.Lock()
_EDGES: dict = {}          # (src_site, dst_site) -> count
_SITES: set = set()        # every traced creation site
_TLS = threading.local()   # .stack = [TracingLock, ...] held, in order

_installed = False
_allowed_prefixes: tuple = ()

_CORE_PREFIX = os.path.realpath(os.path.dirname(__file__))


def _held_stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


class TracingLock:
    """Lock/RLock wrapper recording held-site -> acquired-site edges."""

    __slots__ = ("_inner", "site", "kind")

    def __init__(self, inner, site: tuple, kind: str):
        self._inner = inner
        self.site = site
        self.kind = kind

    # -- acquisition bookkeeping ------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._note_acquired()
        return got

    def _note_acquired(self):
        stack = _held_stack()
        first_level = all(lk is not self for lk in stack)
        if first_level and stack:
            edge = (stack[-1].site, self.site)
            with _REG_LOCK:
                _EDGES[edge] = _EDGES.get(edge, 0) + 1
        stack.append(self)

    def release(self):
        self._inner.release()
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                break

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    # -- threading.Condition protocol -------------------------------------
    # Condition.wait() releases through these; routing them through our
    # acquire/release keeps the held stack honest across waits.

    def _release_save(self):
        inner = self._inner
        if hasattr(inner, "_release_save"):
            state = inner._release_save()
            stack = _held_stack()
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] is self:
                    del stack[i]
            return state
        self.release()
        return None

    def _acquire_restore(self, state):
        inner = self._inner
        if hasattr(inner, "_acquire_restore"):
            inner._acquire_restore(state)
            _held_stack().append(self)
            return
        self.acquire()

    def _is_owned(self):
        inner = self._inner
        if hasattr(inner, "_is_owned"):
            return inner._is_owned()
        if inner.acquire(False):
            inner.release()
            return False
        return True

    def __repr__(self):
        return (f"<TracingLock {self.kind} "
                f"{os.path.basename(self.site[0])}:{self.site[1]} "
                f"wrapping {self._inner!r}>")


def _make_factory(orig, kind: str):
    def factory():
        frame = sys._getframe(1)
        path = os.path.realpath(frame.f_code.co_filename)
        if not path.startswith(_allowed_prefixes):
            return orig()
        site = (path, frame.f_lineno)
        with _REG_LOCK:
            _SITES.add(site)
        return TracingLock(orig(), site, kind)
    return factory


def install(extra_paths: Iterable[str] = ()) -> None:
    """Patch the lock factories.  ``extra_paths``: additional directory
    prefixes (e.g. a test file's directory) whose lock creations are
    traced on top of ``repro/core``."""
    global _installed, _allowed_prefixes
    if _installed:
        raise RuntimeError("locktrace already installed")
    _allowed_prefixes = tuple(
        [_CORE_PREFIX] + [os.path.realpath(p) for p in extra_paths])
    threading.Lock = _make_factory(_ORIG_LOCK, "lock")
    threading.RLock = _make_factory(_ORIG_RLOCK, "rlock")
    _installed = True


def uninstall() -> None:
    """Restore the real factories.  Already-created TracingLocks keep
    working (they wrap real locks) but record no further edges once the
    caller also :func:`reset`\\ s."""
    global _installed
    threading.Lock = _ORIG_LOCK
    threading.RLock = _ORIG_RLOCK
    _installed = False


def installed() -> bool:
    return _installed


def reset() -> None:
    with _REG_LOCK:
        _EDGES.clear()
        _SITES.clear()


def edges() -> dict:
    """``{(src_site, dst_site): count}`` observed so far."""
    with _REG_LOCK:
        return dict(_EDGES)


def sites() -> set:
    with _REG_LOCK:
        return set(_SITES)


def find_cycle(edge_iter) -> Optional[list]:
    """A cycle ``[n0, n1, ..., n0]`` in the given edge set, or None.

    Works on any hashable node type — raw sites or mapped
    ``Class.attr`` names."""
    graph: dict = {}
    for src, dst in edge_iter:
        graph.setdefault(src, set()).add(dst)
        graph.setdefault(dst, set())
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    parent: dict = {}
    for root in sorted(graph, key=repr):
        if color[root] != WHITE:
            continue
        stack = [(root, iter(sorted(graph[root], key=repr)))]
        color[root] = GRAY
        while stack:
            node, it = stack[-1]
            for succ in it:
                if color[succ] == WHITE:
                    color[succ] = GRAY
                    parent[succ] = node
                    stack.append((succ,
                                  iter(sorted(graph[succ], key=repr))))
                    break
                if color[succ] == GRAY:
                    cycle = [succ]
                    cur = node
                    while cur != succ:
                        cycle.append(cur)
                        cur = parent[cur]
                    cycle.append(succ)
                    cycle.reverse()
                    return cycle
            else:
                color[node] = BLACK
                stack.pop()
        continue
    return None
