"""Data-analysis methodology (paper §V) — the continuous analysis engine.

Three analysis layers, exactly as the paper structures them:

1. **Pathological-job detection** — simple rules over resource-utilization
   metrics using *thresholds and timeouts* (paper Fig. 4: FP rate and memory
   bandwidth below thresholds for more than 10 minutes => "break in
   computation").  Implemented as :class:`ThresholdRule` with a full alert
   *lifecycle*: a violation stretch opens at its first violating sample,
   extends while the condition holds, fires once it outlasts the rule's
   timeout, and **resolves** at its last violating sample once the metric
   has stayed clear for the rule's hysteresis window
   (``clear_duration_s`` — a flapping metric does not re-fire every
   window).  Three evaluators share one state machine (:class:`_Stretch`),
   so they agree exactly on the same data:

   * :func:`evaluate_rule` — offline, over one (time, value) series;
   * :class:`StreamAnalyzer` — point-driven, fed raw points (router
     subscriber or direct calls), thread-safe, out-of-order-guarded;
   * :class:`AnalysisEngine` — the *continuous* subsystem: it evaluates
     the streaming **rollup windows** the TSDB already maintains
     (O(#windows) per tick on a background thread — zero work on the
     ingest hot path) and writes alert transitions and per-job reports
     back into the TSDB as the ``analysis`` measurement, so sharding,
     federation and WAL durability apply transparently and alert state
     survives a restart (:meth:`AnalysisEngine.recover`).

2. **Performance-pattern decision tree** — marking applications with
   significant optimization potential (Treibig/Hager performance patterns,
   refined into a decision tree in the FEPA project).  Implemented as a
   data-driven tree over derived metrics; on the TPU the discriminating
   metrics are the three roofline terms, so the tree classifies jobs as
   compute-, memory- or collective-bound (+ load imbalance / ingest-stall
   branches) and attaches a remedy.  Missing inputs are never silently
   defaulted: pathology tests (``>`` nodes) treat a missing signal as "no
   evidence" and record it in the decision path; goodness tests (``<``
   nodes) cannot certify either branch without data and classify as
   ``insufficient-data``.

3. **RooflineAnalyzer** — the assignment's three-term roofline, computed per
   (arch x shape x mesh) cell from the dry-run's compiled artifact.  It both
   fills EXPERIMENTS.md §Roofline and feeds layer 2.

The ``analysis`` measurement schema (what :func:`load_alerts` /
:func:`load_job_report` read back, also over HTTP or federated views):

* alerts — tags ``{kind: "alert", rule, hostname, severity[, jobid]}``;
  one point per lifecycle event, fields ``state`` ("firing"/"resolved"),
  ``start_ns``, ``last_ns`` (last violating sample/window), ``evidence``,
  and on resolution ``end_ns`` + ``duration_s``.  Episodes of the same
  series are keyed by their ``start_ns``.
* job reports — tags ``{kind: "job_report", jobid}``; fields ``report``
  (the full JSON document), ``pattern``, ``status``, ``alerts_total``.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from repro.core.fingerprint import (fingerprint_outliers, fingerprint_point,
                                    job_fingerprint, load_fingerprints)
from repro.core.line_protocol import Point, now_ns
from repro.core.perf_groups import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.core.tsdb import _tags_key

ANALYSIS_MEASUREMENT = "analysis"
INSUFFICIENT_DATA = "insufficient-data"

# ==========================================================================
# 1. Threshold + timeout rules, alert lifecycle
# ==========================================================================

_OPS = {
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
}


@dataclass(frozen=True)
class ThresholdRule:
    """``metric op threshold`` sustained for ``min_duration_s`` => finding.

    ``clear_duration_s`` is the resolution hysteresis: a firing alert
    resolves only after the metric has stayed non-violating for this long
    past the last violation (0 = resolve at the first clear sample, the
    exact offline-scan semantics).

    ``expr`` makes ``metric`` a *query-time derived* metric: a
    performance-group formula (``repro.core.perf_groups``) over the
    measurement's stored fields, evaluated per rollup window (or per raw
    point on rollup-disabled databases) by ``repro.core.query`` — so a
    rule can threshold a metric that was never emitted at collection
    time (e.g. ``hbm_bw_util`` over stored raw byte counters).
    """

    name: str
    measurement: str
    metric: str
    op: str
    threshold: float
    min_duration_s: float
    severity: str = "warning"          # warning | critical
    description: str = ""
    clear_duration_s: float = 0.0
    expr: Optional[str] = None

    def check(self, value: float) -> bool:
        if value is None or (isinstance(value, float) and math.isnan(value)):
            return self.op in ("<", "<=")   # NaN counts as "below threshold"
        return _OPS[self.op](value, self.threshold)


@dataclass
class Finding:
    rule: str
    severity: str
    host: str
    start_ns: int
    end_ns: int
    evidence: str

    @property
    def duration_s(self) -> float:
        return (self.end_ns - self.start_ns) / 1e9


@dataclass
class Alert:
    """One alert episode with its lifecycle state.

    ``last_ns`` tracks the most recent violating sample (window); while
    firing it keeps extending, and on resolution ``end_ns`` freezes at the
    *last violating* sample — the recovery sample is never counted into
    the violation's duration.
    """

    rule: str
    severity: str
    host: str
    jobid: str
    start_ns: int
    last_ns: int
    end_ns: Optional[int] = None
    state: str = "firing"              # firing | resolved
    evidence: str = ""

    @property
    def active(self) -> bool:
        return self.state == "firing"

    @property
    def duration_s(self) -> float:
        end = self.end_ns if self.end_ns is not None else self.last_ns
        return (end - self.start_ns) / 1e9

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "host": self.host, "jobid": self.jobid, "state": self.state,
                "start_ns": self.start_ns, "last_ns": self.last_ns,
                "end_ns": self.end_ns, "duration_s": self.duration_s,
                "evidence": self.evidence}

    @classmethod
    def from_dict(cls, d: dict) -> "Alert":
        return cls(d["rule"], d["severity"], d["host"], d.get("jobid", ""),
                   d["start_ns"], d["last_ns"], d.get("end_ns"),
                   d.get("state", "firing"), d.get("evidence", ""))


class _Stretch:
    """Violation-stretch state machine shared by every evaluator.

    Semantics (identical offline, point-streamed and window-streamed):
    a stretch opens at the first violating sample, ``last_violation_ns``
    tracks the latest violation, and a non-violating sample closes the
    stretch at ``last_violation_ns`` once it is ``clear_duration_s`` past
    it.  A closed stretch *qualifies* (is a finding / fired alert) iff the
    violations alone span ``min_duration_s`` — so a data gap before the
    recovery sample can never inflate the reported duration past
    ``min_duration_s`` (the seed evaluator closed at the recovery sample's
    timestamp and did exactly that).
    """

    __slots__ = ("start_ns", "last_violation_ns")

    def __init__(self):
        self.start_ns = None
        self.last_violation_ns = None

    def qualified(self, rule: ThresholdRule) -> bool:
        return self.start_ns is not None and \
            (self.last_violation_ns - self.start_ns) / 1e9 >= \
            rule.min_duration_s

    def advance(self, rule: ThresholdRule, ts: int, value):
        """Feed one sample; returns ``(qualified, closed)`` where
        ``qualified`` says the (still open) stretch now outlasts the rule
        timeout and ``closed`` is ``(start, end, qualified)`` when this
        sample closed a stretch."""
        closed = None
        if rule.check(value):
            if self.start_ns is None:
                self.start_ns = ts
            self.last_violation_ns = ts
        elif self.start_ns is not None and \
                (ts - self.last_violation_ns) / 1e9 >= rule.clear_duration_s:
            closed = (self.start_ns, self.last_violation_ns,
                      self.qualified(rule))
            self.start_ns = self.last_violation_ns = None
        return self.qualified(rule), closed

    def close(self, rule: ThresholdRule):
        """Forced close (end of series / job end): ``(start, end,
        qualified)`` or None when no stretch is open."""
        if self.start_ns is None:
            return None
        span = (self.start_ns, self.last_violation_ns, self.qualified(rule))
        self.start_ns = self.last_violation_ns = None
        return span


# Default rule set: the paper's elementary resource-utilization checks,
# translated to TPU-job metrics (DESIGN.md §2).  Thresholds are config knobs.
def default_rules(*, mfu_floor: float = 0.02, mem_floor_gbs: float = 1.0,
                  idle_timeout_s: float = 60.0,
                  straggler_skew: float = 0.15,
                  roofline_floor: float = 0.05) -> list:
    # query-time derived rule over the marker measurement: regions without
    # flops/bytes counters produce no derived windows at all (the query
    # layer skips them), so the rule can only fire on instrumented regions
    from repro.core.marker import low_roofline_rule
    clear = idle_timeout_s / 4          # hysteresis: see ThresholdRule
    return [
        low_roofline_rule(roofline_floor, min_duration_s=idle_timeout_s,
                          clear_duration_s=clear),
        ThresholdRule("compute_break", "hpm", "mfu", "<", mfu_floor,
                      idle_timeout_s, "critical",
                      "FP rate below threshold for too long -> break in "
                      "computation (paper Fig. 4)", clear),
        ThresholdRule("membw_break", "hpm", "mem_gb_per_s", "<",
                      mem_floor_gbs, idle_timeout_s, "warning",
                      "memory bandwidth below threshold -> idle/stalled",
                      clear),
        ThresholdRule("data_stall", "hpm", "data_stall_frac", ">", 0.3,
                      idle_timeout_s, "warning",
                      "input pipeline starves the accelerator", clear),
        ThresholdRule("step_time_straggler", "hpm", "straggler_skew", ">",
                      straggler_skew, idle_timeout_s / 2, "warning",
                      "per-host step time skew -> straggler", clear),
    ]


def evaluate_rule(rule: ThresholdRule, times: list, values: list,
                  host: str = "") -> list:
    """Offline evaluation over one series -> list of Finding.

    A finding opens when the condition first holds and closes at the
    *last violating* sample; only stretches whose violations span the
    rule's timeout are reported (Fig. 4).  Out-of-order samples (possible
    in hand-built series; DB series are sorted) are dropped, matching the
    streaming evaluators' monotonic guard.
    """
    findings = []
    stretch = _Stretch()
    last_t = None
    for t, v in zip(times, values):
        if last_t is not None and t < last_t:
            continue
        last_t = t
        _, closed = stretch.advance(rule, t, v)
        if closed is not None and closed[2]:
            findings.append(Finding(rule.name, rule.severity, host,
                                    closed[0], closed[1], rule.description))
    tail = stretch.close(rule)
    if tail is not None and tail[2]:
        findings.append(Finding(rule.name, rule.severity, host, tail[0],
                                tail[1], rule.description))
    return findings


def evaluate_rules_on_db(db: "Database", rules: list, *, jobid: Optional[str] = None,
                         group_by_tag: str = "hostname",
                         use_rollups: object = "auto") -> list:
    """Run every rule over every matching host series in a Database.

    ``db`` is duck-typed: a plain ``Database``, a sharded one
    (``repro.core.shard.ShardedDatabase``) or a ``FederatedQuery`` view
    all work — ``rollup_series``/``select`` federate by concatenation
    (each host series lives on exactly one shard), so pathological-job
    findings are shard-transparent.

    With ``use_rollups`` (the default), rule evaluation reads the finest
    rollup tier — per-window means with window starts as timestamps —
    instead of rescanning raw points, so the cost is O(#windows) and the
    rules keep working after retention dropped the raw data.  Threshold +
    timeout semantics are preserved: a sustained excursion spans the same
    windows it spans points (tier windows are far shorter than any rule
    timeout).  ``use_rollups=False`` forces the raw scan; ``True`` forces
    the rollup path and raises on a rollup-disabled database rather than
    silently evaluating nothing.

    This is the *batch* evaluator; the continuous subsystem
    (:class:`AnalysisEngine`) produces byte-identical findings
    incrementally and persists them — readers should prefer
    :func:`load_alerts` over re-running this scan.
    """
    rollups_available = getattr(db, "rollup_config", None) is not None
    if use_rollups is True and not rollups_available:
        raise ValueError(f"database {getattr(db, 'name', '?')!r} has "
                         "rollups disabled; cannot force use_rollups=True")
    findings = []
    for rule in rules:
        tags = {"jobid": jobid} if jobid else None
        series_list = None
        if rule.expr:
            # query-time derived metric (repro.core.query): per-series
            # windows (or raw points) of a formula over stored fields
            from repro.core.query import (derived_rollup_series,
                                          derived_select_series)
            if use_rollups is not False and rollups_available:
                series_list = derived_rollup_series(
                    db, rule.measurement, rule.metric, rule.expr,
                    tags=tags)
            if not series_list and use_rollups is not True:
                series_list = derived_select_series(
                    db, rule.measurement, rule.metric, rule.expr,
                    tags=tags)
        else:
            if use_rollups is not False and rollups_available:
                series_list = db.rollup_series(rule.measurement,
                                               rule.metric,
                                               agg="mean", tags=tags)
            if not series_list and use_rollups is not True:
                series_list = db.select(rule.measurement, [rule.metric],
                                        tags)
        for series in series_list or []:
            vals = series.values.get(rule.metric)
            if not vals:
                continue
            host = series.tags.get(group_by_tag, "")
            findings.extend(evaluate_rule(rule, series.times, vals, host))
    return findings


class _KeyState:
    """Per-(rule, series) streaming state: monotonic clock + stretch +
    the currently firing alert (None between episodes)."""

    __slots__ = ("last_ns", "stretch", "alert", "last_persist_ns", "cursor")

    def __init__(self):
        self.last_ns = None
        self.stretch = _Stretch()
        self.alert: Optional[Alert] = None
        self.last_persist_ns = 0
        self.cursor = 0                 # AnalysisEngine: next window to eat


def _lifecycle_close(st: _KeyState, rule: ThresholdRule, host: str,
                     jobid: str, span: tuple, findings: list,
                     fired: list) -> Optional[Alert]:
    """Close a stretch (clear-sample past hysteresis, or forced at job /
    stream end): resolve the firing alert at the stretch's last violation;
    a qualified stretch that never fired live (e.g. forced close right as
    it crossed the timeout) fires and resolves in one go.  Returns the
    resolved alert, if any.  Shared by StreamAnalyzer and AnalysisEngine
    so the lifecycle cannot drift between them."""
    start, end, qualified = span
    a = st.alert
    if a is None and qualified:
        a = Alert(rule.name, rule.severity, host, jobid, start, end,
                  evidence=rule.description)
        findings.append(a)
        fired.append(a)
    st.alert = None
    if a is None:
        return None
    a.last_ns = end
    a.end_ns = end
    a.state = "resolved"
    return a


def _lifecycle_advance(st: _KeyState, rule: ThresholdRule, host: str,
                       jobid: str, ts: int, value, findings: list,
                       fired: list):
    """One sample through the shared alert lifecycle.  Returns
    ``(event, alert)`` with event in {None, "fired", "extended",
    "resolved"} — what persistence layers key their write-back on."""
    if isinstance(value, str):
        return None, None               # events are not gauges
    qualified, closed = st.stretch.advance(rule, ts, value)
    resolved = None
    if closed is not None:
        resolved = _lifecycle_close(st, rule, host, jobid, closed,
                                    findings, fired)
    if qualified:
        if st.alert is None:
            st.alert = Alert(rule.name, rule.severity, host, jobid,
                             st.stretch.start_ns,
                             st.stretch.last_violation_ns,
                             evidence=rule.description)
            findings.append(st.alert)
            fired.append(st.alert)
            return "fired", st.alert
        st.alert.last_ns = st.stretch.last_violation_ns
        return "extended", st.alert
    if resolved is not None:
        return "resolved", resolved
    return None, None


class StreamAnalyzer:
    """Online point-driven rule evaluation (router subscriber, the paper's
    ZeroMQ analogue): keeps per-(rule, host) stretch state and fires
    ``on_finding`` the moment a threshold+timeout trips — the paper's
    "detect badly behaving jobs directly for instant user feedback".

    Thread-safe (router subscribers run on concurrent ingest threads);
    per-key out-of-order samples are dropped by a monotonic guard instead
    of silently resetting or rewinding rule state.  ``findings`` holds
    every fired :class:`Alert` (active and resolved).  Wire
    :meth:`on_job_end` to a ``JobRegistry`` end hook so per-host state is
    pruned (and tail stretches closed) when a job's hosts stop reporting.
    """

    def __init__(self, rules: Optional[list] = None,
                 on_finding: Optional[Callable] = None):
        self.rules = rules if rules is not None else default_rules()
        self.on_finding = on_finding
        self.findings: list = []
        self._rules_by_meas: dict = {}
        for r in self.rules:
            self._rules_by_meas.setdefault(r.measurement, []).append(r)
        self._keys: dict = {}            # (rule_name, host) -> _KeyState
        self._lock = threading.RLock()

    def __call__(self, kind: str, payload):
        if kind == "points":
            self.observe_batch(payload)
        elif kind == "job_end":
            self.on_job_end(payload)

    def observe(self, p: Point):
        self.observe_batch((p,))

    def observe_batch(self, points: Iterable[Point]):
        if isinstance(points, Point):
            points = (points,)
        fired: list = []
        with self._lock:
            for p in points:
                rules = self._rules_by_meas.get(p.measurement)
                if not rules:
                    continue
                ts = p.timestamp if p.timestamp is not None else now_ns()
                host = p.tags.get("hostname", "")
                jobid = p.tags.get("jobid", "")
                for rule in rules:
                    if rule.metric in p.fields:
                        self._observe_one(rule, host, jobid, ts,
                                          p.fields[rule.metric], fired)
        self._notify(fired)

    def _observe_one(self, rule: ThresholdRule, host: str, jobid: str,
                     ts: int, value, fired: list):
        key = (rule.name, host)
        st = self._keys.get(key)
        if st is None:
            st = self._keys[key] = _KeyState()
        elif st.last_ns is not None and ts < st.last_ns:
            return          # stale out-of-order sample: state must hold
        st.last_ns = ts
        _lifecycle_advance(st, rule, host, jobid, ts, value,
                           self.findings, fired)

    def on_job_end(self, job):
        """JobRegistry end hook: close tail stretches for the job's hosts
        and prune their per-(rule, host) state (no unbounded growth when
        hosts stop reporting)."""
        hosts = set(getattr(job, "hosts", ()) or ())
        jobid = getattr(job, "job_id", "") or ""
        fired: list = []
        with self._lock:
            for key in [k for k in self._keys if k[1] in hosts]:
                st = self._keys.pop(key)
                rule = self._rule(key[0])
                if rule is None:
                    continue
                span = st.stretch.close(rule)
                if span is not None:
                    _lifecycle_close(st, rule, key[1], jobid, span,
                                     self.findings, fired)
        self._notify(fired)

    def _rule(self, name: str) -> Optional[ThresholdRule]:
        for r in self.rules:
            if r.name == name:
                return r
        return None

    def _notify(self, fired: list):
        if self.on_finding:
            for a in fired:
                try:
                    self.on_finding(a)
                except Exception:    # a broken callback must not stall us
                    pass


# --------------------------------------------------------------------------
# Persisted alert / report read-back (shared by engine, httpd, dashboards)
# --------------------------------------------------------------------------


def load_alerts(db: "Database", *, jobid: Optional[str] = None,
                host: Optional[str] = None, rule: Optional[str] = None,
                state: str = "all") -> list:
    """Reconstruct :class:`Alert` episodes from the persisted ``analysis``
    measurement.

    ``db`` is any Database-shaped view (plain, sharded,
    ``FederatedQuery``, ``HttpQueryClient``) — only ``select`` is used, so
    alerts federate by concatenation exactly like any other series.
    ``state`` filters to ``active`` / ``resolved`` / ``all``.
    """
    tags = {"kind": "alert"}
    if jobid:
        tags["jobid"] = jobid
    if host:
        tags["hostname"] = host
    if rule:
        tags["rule"] = rule
    alerts: list = []
    for s in db.select(ANALYSIS_MEASUREMENT, None, tags):
        n = len(s.times)
        col = {f: s.values.get(f) or [None] * n
               for f in ("state", "start_ns", "last_ns", "end_ns",
                         "evidence")}
        episodes: dict = {}
        for i in range(n):              # points are time-sorted per series
            start = col["start_ns"][i]
            if start is None:
                continue
            a = episodes.get(start)
            if a is None:
                a = episodes[start] = Alert(
                    s.tags.get("rule", ""),
                    s.tags.get("severity", "warning"),
                    s.tags.get("hostname", ""), s.tags.get("jobid", ""),
                    int(start), int(start))
            last = col["last_ns"][i]
            if last is not None and int(last) >= a.last_ns:
                a.last_ns = int(last)
            if col["evidence"][i]:
                a.evidence = col["evidence"][i]
            if col["state"][i] == "resolved":
                end = col["end_ns"][i]
                a.end_ns = int(end) if end is not None else a.last_ns
                a.state = "resolved"
        alerts.extend(episodes.values())
    if state == "active":
        alerts = [a for a in alerts if a.active]
    elif state == "resolved":
        alerts = [a for a in alerts if not a.active]
    elif state != "all":
        raise ValueError(f"unknown alert state filter {state!r} "
                         "(expected active|resolved|all)")
    alerts.sort(key=lambda a: (a.start_ns, a.rule, a.host))
    return alerts


def load_job_report(db: "Database", jobid: str) -> Optional[dict]:
    """Latest persisted footprint report for one job (see
    :meth:`AnalysisEngine.job_report`), or None."""
    best, best_t = None, None
    for s in db.select(ANALYSIS_MEASUREMENT, ["report"],
                       {"kind": "job_report", "jobid": jobid}):
        for t, r in zip(s.times, s.values.get("report", ())):
            if r is not None and (best_t is None or t >= best_t):
                best, best_t = r, t
    return json.loads(best) if best else None


def _job_ended(db: "Database", jobid: str) -> bool:
    for s in db.select("job_event", ["event"], {"jobid": jobid}):
        if "end" in (s.values.get("event") or ()):
            return True
    return False


class AnalysisEngine:
    """The continuous analysis subsystem (MPCDF / PerSyst shape): rule
    evaluation runs against the TSDB's streaming **rollup windows**, and
    every result is written back into the TSDB.

    Why windows, not raw points: per-point evaluation on the ingest path
    costs more than ingest itself (it would halve throughput), while the
    rollup tiers already hold exactly the per-window means the offline
    rollup path (:func:`evaluate_rules_on_db`) evaluates — so a
    cursor-driven sweep over *new* windows is O(#windows), runs on a
    background thread, and produces byte-identical findings to the offline
    scan.  The newest window of each series is held back until a newer one
    exists (or a ``final`` tick): its mean may still change.  Late data
    behind a consumed cursor is absorbed by the rollups but not
    re-evaluated (standard watermark semantics).

    Wiring (``MonitoringStack`` does all of this):

    * subscribe to the router — a batch publish just marks the engine
      dirty (O(1)); a rate-limited worker thread ticks;
    * ``JobRegistry.on_end`` -> :meth:`on_job_end`: final-ticks the job's
      series, resolves its open alerts, writes its footprint report and
      prunes all per-series state;
    * :meth:`recover` on restart: reinstates persisted firing alerts
      (same episode continues — no duplicate re-fire) and resolves alerts
      whose job ended while the engine was down.

    Databases without rollups are still handled: the tick falls back to a
    cursor-bounded raw ``select`` (point-granularity semantics).
    """

    def __init__(self, rules: Optional[list] = None,
                 on_finding: Optional[Callable] = None,
                 backend=None, db_name: str = "global", *,
                 report_measurements: tuple = ("hpm", "system"),
                 extend_persist_interval_s: float = 60.0,
                 tick_interval_s: float = 0.25,
                 auto_tick: bool = True,
                 max_resolved_alerts: int = 10_000,
                 fingerprints: bool = True,
                 fingerprint_sigma: float = 3.0,
                 fingerprint_min_runs: int = 3):
        self.rules = rules if rules is not None else default_rules()
        self.on_finding = on_finding
        self.backend = backend
        self.db_name = db_name
        self.report_measurements = tuple(report_measurements)
        self.alerts: list = []           # fired alerts, active + resolved
        self.findings = self.alerts      # StreamAnalyzer-compatible alias
        self._rule_by_name = {r.name: r for r in self.rules}
        self._series: dict = {}          # (rule, series_key) -> _KeyState
        self._lowwater: dict = {}        # rule -> min cursor (tick t_min)
        self._tick_count = 0
        self._running: set = set()       # jobids with a live allocation
        self._ended: set = set()         # jobids whose analysis is closed
        self._recovered: dict = {}       # (rule, host, jobid) -> Alert
        self._extend_ns = int(extend_persist_interval_s * 1e9)
        self._lock = threading.RLock()
        self.stats = {"ticks": 0, "windows_evaluated": 0,
                      "alerts_fired": 0, "alerts_resolved": 0,
                      "reports_written": 0, "alerts_recovered": 0,
                      "fingerprints_written": 0, "fingerprint_outliers": 0}
        self.fingerprints = bool(fingerprints)
        self.fingerprint_sigma = float(fingerprint_sigma)
        self.fingerprint_min_runs = int(fingerprint_min_runs)
        self._max_resolved = int(max_resolved_alerts)
        # background ticker: publishes mark dirty, the worker coalesces
        self._auto_tick = bool(auto_tick)
        self._tick_interval_s = float(tick_interval_s)
        self._cv = threading.Condition(threading.Lock())
        self._dirty = False
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- router subscription (O(1) on the ingest path) -----------------------

    def __call__(self, kind: str, payload):
        if kind == "points":
            if self._auto_tick:
                self._signal()
        elif kind == "job_start":
            jid = getattr(payload, "job_id", "") or ""
            if jid:
                with self._lock:
                    self._running.add(jid)
                    self._ended.discard(jid)   # requeued/restarted job id
        elif kind == "job_end":
            self.on_job_end(payload)

    def _signal(self):
        with self._cv:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._worker, daemon=True, name="lms-analysis")
                self._thread.start()
            self._dirty = True
            self._cv.notify()

    def _worker(self):
        while True:
            with self._cv:
                while not self._dirty and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                self._dirty = False
            try:
                self.tick()
            except Exception as e:          # noqa: BLE001
                warnings.warn(f"analysis tick failed: {e!r}")
            # rate limit: coalesce bursts of publishes into one tick
            time.sleep(self._tick_interval_s)

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            thread = self._thread
        # bounded join outside the condition (the worker needs _cv to
        # observe _stop); the sleep-based rate limiter caps the wait
        if thread is not None and thread is not threading.current_thread():
            thread.join(timeout=2.0 + self._tick_interval_s)
            if thread.is_alive():
                with self._lock:
                    self.stats["tick_join_timeouts"] = \
                        self.stats.get("tick_join_timeouts", 0) + 1

    # -- the continuous evaluation sweep -------------------------------------

    def _db(self) -> "Optional[Database]":
        # Database-shaped: plain or sharded depending on the backend.
        # The annotation is load-bearing for repro.analyzer lock-order
        # resolution — ticks call into the database under self._lock.
        if self.backend is None:
            return None
        return self.backend.db(self.db_name)

    def flush(self, final: bool = False) -> "AnalysisEngine":
        """Synchronous tick — call before reading live state in tests or
        request handlers (``final`` also consumes held-back newest
        windows).  Always a full sweep: the read-your-writes promise must
        not depend on where the background ticker's counter happens to
        sit (a series backfilled entirely below the cursor low-water —
        e.g. a new job at older timestamps than a finished one — would
        otherwise stay invisible for up to FULL_SWEEP_EVERY ticks)."""
        self.tick(final=final, full=True)
        return self

    # incremental ticks bound their readout by the per-rule cursor
    # low-water; every FULL_SWEEP_EVERY-th tick (and every final or
    # explicitly full tick) is an unbounded full sweep, which is what
    # discovers a series backfilled entirely below the low-water —
    # worst-case staleness for such a series is FULL_SWEEP_EVERY
    # *background* ticks, and flush()/job-end evaluation is always exact.
    # (A stalled series pins the low-water, degrading incremental ticks
    # toward full-sweep cost until its job ends — the underlying
    # per-series window scan is O(stored windows) either way; the
    # low-water only trims result materialization.)
    FULL_SWEEP_EVERY = 8

    def tick(self, final: bool = False, full: Optional[bool] = None) -> int:
        """Advance every rule over the windows (or raw points) that became
        visible since the last tick; returns samples evaluated."""
        db = self._db()
        if db is None:
            return 0
        out: list = []
        fired: list = []
        with self._lock:
            if full is None:
                full = self._tick_count % self.FULL_SWEEP_EVERY == 0
            full = full or final
            self._tick_count += 1
            n = self._tick_locked(db, None, final, fired, out, full=full)
            self.stats["ticks"] += 1
            self.stats["windows_evaluated"] += n
        self._emit(out, fired)
        return n

    def _tick_locked(self, db: "Database", only_tags: Optional[dict],
                     final: bool,
                     fired: list, out: list, full: bool = True) -> int:
        rollups = getattr(db, "rollup_config", None) is not None
        evaluated = 0
        global_sweep = only_tags is None
        for rule in self.rules:
            t_min = None if (full or not global_sweep) \
                else self._lowwater.get(rule.name)
            series_list = self._rule_series(db, rule, only_tags, t_min,
                                            rollups)
            for s in series_list:
                vals = s.values.get(rule.metric)
                if not vals:
                    continue
                jobid = s.tags.get("jobid", "")
                if jobid and not self._job_live(db, jobid):
                    continue             # job over: its report is final
                skey = (rule.name, _tags_key(s.tags))
                st = self._series.get(skey)
                if st is None:
                    st = self._series[skey] = _KeyState()
                    self._adopt_recovered(rule, s.tags, st)
                    if t_min is not None and st.cursor < t_min:
                        full = self._rule_series(db, rule, s.tags, None,
                                                 rollups)
                        s = next((f for f in full
                                  if _tags_key(f.tags) == skey[1]), s)
                        vals = s.values.get(rule.metric) or vals
                host = s.tags.get("hostname", "")
                # hold the newest window back unless final: its aggregate
                # may still change (raw points are immutable -> no holdback)
                limit = len(s.times) if (final or not rollups) \
                    else len(s.times) - 1
                i = bisect.bisect_left(s.times, st.cursor)
                while i < limit:
                    ts = s.times[i]
                    self._advance(rule, st, host, jobid, ts, vals[i],
                                  fired, out)
                    st.cursor = ts + 1
                    evaluated += 1
                    i += 1
            if global_sweep:
                cursors = [st.cursor for (rn, _), st in self._series.items()
                           if rn == rule.name]
                if cursors:
                    self._lowwater[rule.name] = min(cursors)
        return evaluated

    @staticmethod
    def _rule_series(db: "Database", rule: ThresholdRule,
                     tags: Optional[dict],
                     t_min: Optional[int], rollups: bool) -> list:
        if rule.expr:
            # derived rule input (repro.core.query): the metric is a
            # formula over the measurement's stored fields, evaluated per
            # rollup window — it need never have been emitted
            from repro.core.query import (derived_rollup_series,
                                          derived_select_series)
            if rollups:
                return derived_rollup_series(db, rule.measurement,
                                             rule.metric, rule.expr,
                                             tags=tags, t_min=t_min)
            return derived_select_series(db, rule.measurement, rule.metric,
                                         rule.expr, tags=tags, t_min=t_min)
        if rollups:
            return db.rollup_series(rule.measurement, rule.metric,
                                    agg="mean", tags=tags, t_min=t_min)
        return db.select(rule.measurement, [rule.metric], tags, t_min)

    def _job_live(self, db: "Database", jobid: str) -> bool:
        """False once a job's analysis is closed (its end hook ran, or it
        was found ended in the DB — e.g. before a restart)."""
        if jobid in self._ended:
            return False
        if jobid in self._running:
            return True
        if _job_ended(db, jobid):
            self._ended.add(jobid)
            return False
        self._running.add(jobid)
        return True

    def _adopt_recovered(self, rule: ThresholdRule, tags: dict,
                         st: _KeyState):
        """First sighting of a series after :meth:`recover`: continue the
        persisted episode instead of re-firing a duplicate."""
        rec = self._recovered.pop(
            (rule.name, tags.get("hostname", ""), tags.get("jobid", "")),
            None)
        if rec is None:
            return
        st.cursor = rec.last_ns + 1
        if rec.active:
            st.alert = rec
            st.stretch.start_ns = rec.start_ns
            st.stretch.last_violation_ns = rec.last_ns
            st.last_persist_ns = rec.last_ns

    def _advance(self, rule: ThresholdRule, st: _KeyState, host: str,
                 jobid: str, ts: int, value, fired: list, out: list):
        n_fired = len(fired)
        event, a = _lifecycle_advance(st, rule, host, jobid, ts, value,
                                      self.alerts, fired)
        self.stats["alerts_fired"] += len(fired) - n_fired
        if event == "fired":
            st.last_persist_ns = ts
            out.append(self._alert_point(a, "firing", ts))
        elif event == "extended":
            if ts - st.last_persist_ns >= self._extend_ns:
                st.last_persist_ns = ts
                out.append(self._alert_point(a, "firing", ts))
        elif event == "resolved":
            self.stats["alerts_resolved"] += 1
            out.append(self._alert_point(a, "resolved", ts))
            self._trim_alerts()

    def _resolve(self, rule: ThresholdRule, st: _KeyState, host: str,
                 jobid: str, span: tuple, ts: int, fired: list, out: list):
        """Forced close (job end / recovery of a dead job)."""
        n_fired = len(fired)
        a = _lifecycle_close(st, rule, host, jobid, span, self.alerts,
                             fired)
        self.stats["alerts_fired"] += len(fired) - n_fired
        if a is not None:
            self.stats["alerts_resolved"] += 1
            out.append(self._alert_point(a, "resolved", ts))
            self._trim_alerts()

    def _trim_alerts(self):
        if len(self.alerts) <= self._max_resolved:
            return
        keep = [a for a in self.alerts if a.active]
        resolved = [a for a in self.alerts if not a.active]
        drop = len(self.alerts) - self._max_resolved
        self.alerts[:] = resolved[drop:] + keep

    def _alert_point(self, a: Alert, state: str, ts: int) -> Point:
        tags = {"kind": "alert", "rule": a.rule, "hostname": a.host,
                "severity": a.severity}
        if a.jobid:
            tags["jobid"] = a.jobid
        fields = {"state": state, "start_ns": a.start_ns,
                  "last_ns": a.last_ns, "evidence": a.evidence}
        if state == "resolved":
            fields["end_ns"] = a.end_ns
            fields["duration_s"] = a.duration_s
        return Point(ANALYSIS_MEASUREMENT, tags, fields, ts)

    def _emit(self, out: list, fired: list):
        if out and self.backend is not None:
            self.backend.write(out, self.db_name)
        if fired and self.on_finding:
            for a in fired:
                try:
                    self.on_finding(a)
                except Exception:   # a broken callback must not stall us
                    pass

    # -- job lifecycle --------------------------------------------------------

    def on_job_end(self, job):
        """JobRegistry end hook: final-tick the job's series, resolve its
        open alerts (end = last violating window), write its footprint
        report, and prune every per-series state it owned.  Idempotent —
        the router also republishes job_end to subscribers."""
        jobid = getattr(job, "job_id", job) or ""
        with self._lock:
            if not jobid or jobid in self._ended:
                return
            end_ns = getattr(job, "end_ns", None) or now_ns()
            hosts = set(getattr(job, "hosts", ()) or ())
            db = self._db()
            out: list = []
            fired: list = []
            if db is not None:
                # force-live for the final sweep (the end event may already
                # be in the DB when this arrives via the router's publish)
                self._running.add(jobid)
                self._tick_locked(db, {"jobid": jobid}, True, fired, out)
            self._ended.add(jobid)
            self._running.discard(jobid)
            for skey in list(self._series):
                rule_name, tags_key = skey
                tags = dict(tags_key)
                owned = tags.get("jobid") == jobid or (
                    not tags.get("jobid") and tags.get("hostname") in hosts)
                if not owned:
                    continue
                st = self._series.pop(skey)
                rule = self._rule_by_name.get(rule_name)
                if rule is None:
                    continue
                span = st.stretch.close(rule)
                if span is not None:
                    self._resolve(rule, st, tags.get("hostname", ""),
                                  tags.get("jobid", "") or jobid, span,
                                  end_ns, fired, out)
            if db is not None:
                report = self._build_report(db, jobid, running=False)
                if report is not None:
                    out.append(Point(
                        ANALYSIS_MEASUREMENT,
                        {"kind": "job_report", "jobid": jobid},
                        {"report": json.dumps(report),
                         "pattern": report["pattern"],
                         "status": report["status"],
                         "alerts_total": float(len(report["alerts"]))},
                        end_ns))
                    self.stats["reports_written"] += 1
                self._fingerprint_job(db, job, jobid, end_ns, out, fired)
        self._emit(out, fired)

    def _fingerprint_job(self, db, job, jobid: str, end_ns: int,
                         out: list, fired: list):
        """Fingerprint the finished job and apply the fleet rule: compare
        its p95 quantile vector against its own past runs (same family —
        jobname tag, else user) and flag >sigma deviations through the
        normal alert surface.  History is read before this job's point is
        emitted, so the new run never pollutes its own baseline.  Called
        under self._lock; failures are counted, never allowed to block job
        teardown."""
        if not self.fingerprints:
            return
        try:
            fp = job_fingerprint(db, jobid, self.report_measurements)
            if not fp:
                return
            tags = getattr(job, "tags", None) or {}
            family = tags.get("jobname") or getattr(job, "user", "") or ""
            history = [e["fingerprint"] for e in load_fingerprints(db)
                       if e["family"] == family and e["jobid"] != jobid]
            out.append(fingerprint_point(jobid, family, fp, end_ns))
            self.stats["fingerprints_written"] += 1
            outliers = fingerprint_outliers(
                fp, history, sigma=self.fingerprint_sigma,
                min_runs=self.fingerprint_min_runs)
            if not outliers:
                return
            ev = "; ".join(
                f"{o['metric']} {o['quantile']}={o['value']:.6g} vs "
                f"fleet mean {o['mean']:.6g} "
                f"(z={o['z']:.1f}, {o['runs']} past runs)"
                for o in outliers[:3])
            a = Alert(rule="fingerprint_outlier", severity="warning",
                      host="", jobid=jobid, start_ns=end_ns,
                      last_ns=end_ns, end_ns=end_ns, state="resolved",
                      evidence=ev)
            self.alerts.append(a)
            self._trim_alerts()
            self.stats["alerts_fired"] += 1
            self.stats["alerts_resolved"] += 1
            self.stats["fingerprint_outliers"] += 1
            out.append(self._alert_point(a, "resolved", end_ns))
            fired.append(a)
        except Exception:   # noqa: BLE001 - teardown must complete
            self.stats["fingerprint_errors"] = \
                self.stats.get("fingerprint_errors", 0) + 1

    # -- job footprint reports ------------------------------------------------

    def job_report(self, jobid: str) -> Optional[dict]:
        """Footprint summary + pattern classification for one job: live
        (recomputed from the rollup windows) while the job runs, the
        persisted report afterwards."""
        db = self._db()
        if db is None:
            return None
        with self._lock:
            if jobid in self._ended:
                return load_job_report(db, jobid)
            return self._build_report(db, jobid, running=True)

    def _build_report(self, db, jobid: str, *, running: bool) \
            -> Optional[dict]:
        """Time-weighted per-metric stats (means averaged over the uniform
        rollup windows, i.e. time-weighted at window granularity) plus the
        pattern-tree classification — the paper's "statistical foundation
        about application specific system usage" per job."""
        tags = {"jobid": jobid}
        metrics: dict = {}
        hosts: set = set()
        span = [None, None]
        rollups = getattr(db, "rollup_config", None) is not None
        for meas in self.report_measurements:
            for fieldname in db.field_keys(meas):
                if fieldname in metrics:
                    continue             # first measurement wins the name
                if rollups:
                    series_list = db.rollup_series(meas, fieldname,
                                                   tags=tags)
                else:
                    series_list = db.select(meas, [fieldname], tags)
                count = 0
                vmin = vmax = None
                wmean_sum = 0.0
                for s in series_list:
                    vals = s.values.get(fieldname) or ()
                    numeric = [v for v in vals
                               if isinstance(v, (int, float)) and
                               not isinstance(v, bool) and v == v]
                    if not numeric:
                        continue
                    hosts.add(s.tags.get("hostname", ""))
                    if s.times:
                        if span[0] is None or s.times[0] < span[0]:
                            span[0] = s.times[0]
                        if span[1] is None or s.times[-1] > span[1]:
                            span[1] = s.times[-1]
                    count += len(numeric)
                    wmean_sum += sum(numeric)
                    lo, hi = min(numeric), max(numeric)
                    vmin = lo if vmin is None else min(vmin, lo)
                    vmax = hi if vmax is None else max(vmax, hi)
                if count:
                    metrics[fieldname] = {
                        "mean": wmean_sum / count, "min": vmin,
                        "max": vmax, "samples": count}
        if not metrics:
            return None
        m = {k: v["mean"] for k, v in metrics.items()}
        # roofline term fractions from the utilization gauges, when present
        cu, mu, iu = (m.get("hw_flops_util"), m.get("hbm_bw_util"),
                      m.get("ici_bw_util"))
        if cu is not None and mu is not None and iu is not None and \
                (cu + mu + iu) > 0:
            tot = cu + mu + iu
            m.setdefault("compute_frac", cu / tot)
            m.setdefault("memory_frac", mu / tot)
            m.setdefault("collective_frac", iu / tot)
        cls = classify_job(m)
        alerts = [a.to_dict() for a in self.alerts if a.jobid == jobid]
        return {"jobid": jobid, "running": running,
                "hosts": sorted(hosts),
                "window_ns": span,
                "metrics": dict(sorted(metrics.items())),
                "pattern": cls["pattern"], "remedy": cls["remedy"],
                "missing": cls["missing"], "path": cls["path"],
                "alerts": alerts,
                "status": "unhealthy" if any(
                    a["severity"] == "critical" for a in alerts) else "ok"}

    # -- restart recovery (the WAL brought the analysis series back) ---------

    def recover(self) -> dict:
        """Reinstate persisted alert state after a restart: active alerts
        continue as the same episode (adopted when their series next
        ticks); alerts whose job ended while the engine was down are
        resolved; resolved history seeds per-series cursors so old
        stretches are not re-fired as duplicates."""
        db = self._db()
        if db is None:
            return {"alerts_recovered": 0, "alerts_closed": 0}
        out: list = []
        recovered = closed = 0
        dead_jobs: set = set()
        with self._lock:
            for a in load_alerts(db):
                key = (a.rule, a.host, a.jobid)
                job_dead = a.jobid and not self._job_live(db, a.jobid)
                if a.active and job_dead:
                    # its job ended while the engine was down
                    a.end_ns = a.last_ns
                    a.state = "resolved"
                    out.append(self._alert_point(a, "resolved", a.last_ns))
                    closed += 1
                    dead_jobs.add(a.jobid)
                elif a.active:
                    recovered += 1
                # the full history (resolved episodes included) comes back
                # so a post-restart job report still lists every episode
                self.alerts.append(a)
                # cursor floor per key (latest episode wins): an already-
                # reported stretch is never re-evaluated -> no duplicate
                # re-fire after restart
                cur = self._recovered.get(key)
                if cur is None or a.last_ns >= cur.last_ns:
                    self._recovered[key] = a
            # jobs that ended while the engine was down never got their
            # footprint report written — write it now (alerting jobs only;
            # quiet jobs that ended while down stay report-less)
            for jid in sorted(dead_jobs):
                if load_job_report(db, jid) is None:
                    report = self._build_report(db, jid, running=False)
                    if report is not None:
                        out.append(Point(
                            ANALYSIS_MEASUREMENT,
                            {"kind": "job_report", "jobid": jid},
                            {"report": json.dumps(report),
                             "pattern": report["pattern"],
                             "status": report["status"],
                             "alerts_total":
                                 float(len(report["alerts"]))},
                            report["window_ns"][1] or now_ns()))
                        self.stats["reports_written"] += 1
            self.stats["alerts_recovered"] += recovered
        self._emit(out, [])
        return {"alerts_recovered": recovered, "alerts_closed": closed}

    # -- read API -------------------------------------------------------------

    def active_alerts(self, jobid: Optional[str] = None) -> list:
        with self._lock:
            return [a for a in self.alerts if a.active and
                    (jobid is None or a.jobid == jobid)]

    def resolved_alerts(self, jobid: Optional[str] = None) -> list:
        with self._lock:
            return [a for a in self.alerts if not a.active and
                    (jobid is None or a.jobid == jobid)]

    def engine_stats(self) -> dict:
        with self._lock:
            return {**self.stats, "series_tracked": len(self._series),
                    "alerts_active": sum(a.active for a in self.alerts),
                    "jobs_running": len(self._running),
                    "jobs_closed": len(self._ended)}


# ==========================================================================
# 2. Performance-pattern decision tree
# ==========================================================================


@dataclass
class PatternNode:
    """Internal node: test ``metric op threshold``; leaf: pattern+remedy.

    Missing inputs are never silently treated as 0.0 (the seed behavior,
    which routed jobs down arbitrary branches): a pathology test (``>`` /
    ``>=``) with no data means "no evidence of that pathology" — the
    false branch is taken and the gap recorded in the decision path and
    the ``missing`` list; a goodness test (``<`` / ``<=``) cannot certify
    either branch without data and classifies as ``insufficient-data``.
    """

    pattern: Optional[str] = None
    remedy: Optional[str] = None
    metric: Optional[str] = None
    op: Optional[str] = None
    threshold: Optional[float] = None
    if_true: Optional["PatternNode"] = None
    if_false: Optional["PatternNode"] = None

    def classify(self, metrics: dict, path: Optional[list] = None,
                 missing: Optional[list] = None):
        path = path if path is not None else []
        missing = missing if missing is not None else []
        if self.pattern is not None:
            return self.pattern, self.remedy, path, missing
        v = metrics.get(self.metric)
        if v is None or (isinstance(v, float) and math.isnan(v)):
            missing.append(self.metric)
            if self.op in ("<", "<="):
                path.append(f"{self.metric}=missing -> insufficient-data")
                return (INSUFFICIENT_DATA,
                        "metrics missing for classification: "
                        + ", ".join(missing), path, missing)
            path.append(f"{self.metric}=missing -> False (no evidence)")
            return self.if_false.classify(metrics, path, missing)
        taken = _OPS[self.op](v, self.threshold)
        path.append(f"{self.metric}={v:.3g} {self.op} {self.threshold}"
                    f" -> {taken}")
        nxt = self.if_true if taken else self.if_false
        return nxt.classify(metrics, path, missing)


def leaf(pattern, remedy):
    return PatternNode(pattern=pattern, remedy=remedy)


def node(metric, op, threshold, if_true, if_false):
    return PatternNode(metric=metric, op=op, threshold=threshold,
                       if_true=if_true, if_false=if_false)


# TPU adaptation of the FEPA decision tree: discriminate on the roofline
# term fractions + goodput metrics.  Inputs (all in [0, ~1]):
#   compute_frac / memory_frac / collective_frac : term_i / sum(terms)
#   mfu            : model FLOPs utilization
#   useful_flop_ratio : model_flops / hlo_flops
#   data_stall_frac, straggler_skew
DEFAULT_TREE = node(
    "data_stall_frac", ">", 0.3,
    leaf("ingest-bound",
         "input pipeline too slow: add prefetch/workers, shard files"),
    node("straggler_skew", ">", 0.15,
         leaf("load-imbalance",
              "straggler host: checkpoint-restart without it (elastic), "
              "check MoE expert balance"),
         node("collective_frac", ">", 0.4,
              leaf("collective-bound",
                   "overlap collectives with compute, rethink sharding axes, "
                   "gradient compression, larger per-device batch"),
              node("memory_frac", ">", 0.5,
                   node("useful_flop_ratio", "<", 0.6,
                        leaf("recompute-heavy memory-bound",
                             "relax remat policy; fuse attention (flash) to "
                             "cut activation traffic"),
                        leaf("memory-bound",
                             "increase arithmetic intensity: fuse ops, "
                             "quantize weights/cache, batch decode requests")),
                   node("mfu", "<", 0.25,
                        leaf("latency/overhead-bound",
                             "kernel launch / small-batch overheads: grow "
                             "per-device batch, unroll scan, check host "
                             "callbacks"),
                        leaf("compute-bound",
                             "good: push block shapes / MXU alignment; "
                             "consider int8/fp8 matmuls"))))))


def classify_job(metrics: dict, tree: PatternNode = DEFAULT_TREE) -> dict:
    pattern, remedy, path, missing = tree.classify(dict(metrics))
    return {"pattern": pattern, "remedy": remedy, "path": path,
            "missing": missing}


# ==========================================================================
# 3. Roofline analyzer (assignment §Roofline; feeds the tree above)
# ==========================================================================


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def terms(self) -> dict:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}

    @property
    def dominant(self) -> str:
        return max(self.terms, key=self.terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / bound — 1.0 means perfectly compute-limited."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def fractions(self) -> dict:
        tot = sum(self.terms.values()) or 1.0
        return {f"{k}_frac": v / tot for k, v in self.terms.items()}

    def classify(self, extra_metrics: Optional[dict] = None) -> dict:
        m = {**self.fractions(),
             "useful_flop_ratio": self.useful_flop_ratio,
             "mfu": self.roofline_fraction,   # upper-bound MFU from terms
             "data_stall_frac": 0.0, "straggler_skew": 0.0}
        if extra_metrics:
            m.update(extra_metrics)
        return classify_job(m)


class RooflineAnalyzer:
    """Three-term roofline from dry-run artifacts (per-chip quantities)."""

    def __init__(self, peak_flops: float = PEAK_FLOPS,
                 hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW):
        self.peak_flops = peak_flops
        self.hbm_bw = hbm_bw
        self.ici_bw = ici_bw

    def analyze(self, *, arch: str, shape: str, mesh: str, chips: int,
                hlo_flops: float, hbm_bytes: float, collective_bytes: float,
                model_flops: float) -> RooflineResult:
        """All inputs are *global* (whole-program) quantities; terms are
        per-chip seconds assuming perfect balance (cost_analysis reports the
        SPMD-partitioned module, i.e. per-device work, times 1; we pass
        per-device numbers scaled up by ``chips`` for clarity)."""
        return RooflineResult(
            arch=arch, shape=shape, mesh=mesh, chips=chips,
            compute_s=hlo_flops / (chips * self.peak_flops),
            memory_s=hbm_bytes / (chips * self.hbm_bw),
            collective_s=collective_bytes / (chips * self.ici_bw),
            model_flops=model_flops, hlo_flops=hlo_flops,
            hbm_bytes=hbm_bytes, collective_bytes=collective_bytes)
