"""Data-analysis methodology (paper §V).

Three analysis layers, exactly as the paper structures them:

1. **Pathological-job detection** — simple rules over resource-utilization
   metrics using *thresholds and timeouts* (paper Fig. 4: FP rate and memory
   bandwidth below thresholds for more than 10 minutes => "break in
   computation").  Implemented as :class:`ThresholdRule` evaluated over TSDB
   series, plus a streaming evaluator subscribed to the router for instant
   feedback.

2. **Performance-pattern decision tree** — marking applications with
   significant optimization potential (Treibig/Hager performance patterns,
   refined into a decision tree in the FEPA project).  Implemented as a data-
   driven tree over derived metrics; on the TPU the discriminating metrics
   are the three roofline terms, so the tree classifies jobs as compute-,
   memory- or collective-bound (+ load imbalance / ingest-stall branches)
   and attaches a remedy.

3. **RooflineAnalyzer** — the assignment's three-term roofline, computed per
   (arch x shape x mesh) cell from the dry-run's compiled artifact.  It both
   fills EXPERIMENTS.md §Roofline and feeds layer 2.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.line_protocol import Point, now_ns
from repro.core.perf_groups import HBM_BW, ICI_BW, PEAK_FLOPS

# ==========================================================================
# 1. Threshold + timeout rules
# ==========================================================================

_OPS = {
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
}


@dataclass(frozen=True)
class ThresholdRule:
    """``metric op threshold`` sustained for ``min_duration_s`` => finding."""

    name: str
    measurement: str
    metric: str
    op: str
    threshold: float
    min_duration_s: float
    severity: str = "warning"          # warning | critical
    description: str = ""

    def check(self, value: float) -> bool:
        if value is None or (isinstance(value, float) and math.isnan(value)):
            return self.op in ("<", "<=")   # NaN counts as "below threshold"
        return _OPS[self.op](value, self.threshold)


@dataclass
class Finding:
    rule: str
    severity: str
    host: str
    start_ns: int
    end_ns: int
    evidence: str

    @property
    def duration_s(self) -> float:
        return (self.end_ns - self.start_ns) / 1e9


# Default rule set: the paper's elementary resource-utilization checks,
# translated to TPU-job metrics (DESIGN.md §2).  Thresholds are config knobs.
def default_rules(*, mfu_floor: float = 0.02, mem_floor_gbs: float = 1.0,
                  idle_timeout_s: float = 60.0,
                  straggler_skew: float = 0.15) -> list:
    return [
        ThresholdRule("compute_break", "hpm", "mfu", "<", mfu_floor,
                      idle_timeout_s, "critical",
                      "FP rate below threshold for too long -> break in "
                      "computation (paper Fig. 4)"),
        ThresholdRule("membw_break", "hpm", "mem_gb_per_s", "<",
                      mem_floor_gbs, idle_timeout_s, "warning",
                      "memory bandwidth below threshold -> idle/stalled"),
        ThresholdRule("data_stall", "hpm", "data_stall_frac", ">", 0.3,
                      idle_timeout_s, "warning",
                      "input pipeline starves the accelerator"),
        ThresholdRule("step_time_straggler", "hpm", "straggler_skew", ">",
                      straggler_skew, idle_timeout_s / 2, "warning",
                      "per-host step time skew -> straggler"),
    ]


def evaluate_rule(rule: ThresholdRule, times: list, values: list,
                  host: str = "") -> list:
    """Offline evaluation over one series -> list of Finding.

    A finding opens when the condition first holds and closes when it stops;
    only stretches longer than the rule's timeout are reported (Fig. 4).
    """
    findings = []
    open_start = None
    last_t = None
    for t, v in zip(times, values):
        if rule.check(v):
            if open_start is None:
                open_start = t
        else:
            if open_start is not None and \
                    (t - open_start) / 1e9 >= rule.min_duration_s:
                findings.append(Finding(rule.name, rule.severity, host,
                                        open_start, t, rule.description))
            open_start = None
        last_t = t
    if open_start is not None and last_t is not None and \
            (last_t - open_start) / 1e9 >= rule.min_duration_s:
        findings.append(Finding(rule.name, rule.severity, host, open_start,
                                last_t, rule.description))
    return findings


def evaluate_rules_on_db(db, rules: list, *, jobid: Optional[str] = None,
                         group_by_tag: str = "hostname",
                         use_rollups: object = "auto") -> list:
    """Run every rule over every matching host series in a Database.

    ``db`` is duck-typed: a plain ``Database``, a sharded one
    (``repro.core.shard.ShardedDatabase``) or a ``FederatedQuery`` view
    all work — ``rollup_series``/``select`` federate by concatenation
    (each host series lives on exactly one shard), so pathological-job
    findings are shard-transparent.

    With ``use_rollups`` (the default), rule evaluation reads the finest
    rollup tier — per-window means with window starts as timestamps —
    instead of rescanning raw points, so the cost is O(#windows) and the
    rules keep working after retention dropped the raw data.  Threshold +
    timeout semantics are preserved: a sustained excursion spans the same
    windows it spans points (tier windows are far shorter than any rule
    timeout).  ``use_rollups=False`` forces the raw scan; ``True`` forces
    the rollup path and raises on a rollup-disabled database rather than
    silently evaluating nothing.
    """
    rollups_available = getattr(db, "rollup_config", None) is not None
    if use_rollups is True and not rollups_available:
        raise ValueError(f"database {getattr(db, 'name', '?')!r} has "
                         "rollups disabled; cannot force use_rollups=True")
    findings = []
    for rule in rules:
        tags = {"jobid": jobid} if jobid else None
        series_list = None
        if use_rollups is not False and rollups_available:
            series_list = db.rollup_series(rule.measurement, rule.metric,
                                           agg="mean", tags=tags)
        if not series_list and use_rollups is not True:
            series_list = db.select(rule.measurement, [rule.metric], tags)
        for series in series_list or []:
            vals = series.values.get(rule.metric)
            if not vals:
                continue
            host = series.tags.get(group_by_tag, "")
            findings.extend(evaluate_rule(rule, series.times, vals, host))
    return findings


class StreamAnalyzer:
    """Online rule evaluation — subscribes to the router (ZeroMQ analogue).

    Keeps per-(rule, host) condition state and fires ``on_finding`` the
    moment a threshold+timeout trips: the paper's "detect badly behaving
    jobs directly for instant user feedback".
    """

    def __init__(self, rules: Optional[list] = None,
                 on_finding: Optional[Callable] = None):
        self.rules = rules if rules is not None else default_rules()
        self.on_finding = on_finding
        self._open: dict = {}            # (rule, host) -> start ns
        self._fired: dict = {}
        self.findings: list = []

    def __call__(self, kind: str, payload):
        if kind != "points":
            return
        for p in payload:
            self.observe(p)

    def observe(self, p: Point):
        host = p.tags.get("hostname", "")
        ts = p.timestamp if p.timestamp is not None else now_ns()
        for rule in self.rules:
            if rule.measurement != p.measurement or \
                    rule.metric not in p.fields:
                continue
            key = (rule.name, host)
            if rule.check(p.fields[rule.metric]):
                start = self._open.setdefault(key, ts)
                if (ts - start) / 1e9 >= rule.min_duration_s and \
                        not self._fired.get(key):
                    f = Finding(rule.name, rule.severity, host, start, ts,
                                rule.description)
                    self.findings.append(f)
                    self._fired[key] = True
                    if self.on_finding:
                        self.on_finding(f)
            else:
                self._open.pop(key, None)
                self._fired.pop(key, None)


# ==========================================================================
# 2. Performance-pattern decision tree
# ==========================================================================


@dataclass
class PatternNode:
    """Internal node: test ``metric op threshold``; leaf: pattern+remedy."""

    pattern: Optional[str] = None
    remedy: Optional[str] = None
    metric: Optional[str] = None
    op: Optional[str] = None
    threshold: Optional[float] = None
    if_true: Optional["PatternNode"] = None
    if_false: Optional["PatternNode"] = None

    def classify(self, metrics: dict, path: Optional[list] = None):
        path = path if path is not None else []
        if self.pattern is not None:
            return self.pattern, self.remedy, path
        v = metrics.get(self.metric, 0.0)
        taken = _OPS[self.op](v, self.threshold)
        path.append(f"{self.metric}={v:.3g} {self.op} {self.threshold}"
                    f" -> {taken}")
        nxt = self.if_true if taken else self.if_false
        return nxt.classify(metrics, path)


def leaf(pattern, remedy):
    return PatternNode(pattern=pattern, remedy=remedy)


def node(metric, op, threshold, if_true, if_false):
    return PatternNode(metric=metric, op=op, threshold=threshold,
                       if_true=if_true, if_false=if_false)


# TPU adaptation of the FEPA decision tree: discriminate on the roofline
# term fractions + goodput metrics.  Inputs (all in [0, ~1]):
#   compute_frac / memory_frac / collective_frac : term_i / sum(terms)
#   mfu            : model FLOPs utilization
#   useful_flop_ratio : model_flops / hlo_flops
#   data_stall_frac, straggler_skew
DEFAULT_TREE = node(
    "data_stall_frac", ">", 0.3,
    leaf("ingest-bound",
         "input pipeline too slow: add prefetch/workers, shard files"),
    node("straggler_skew", ">", 0.15,
         leaf("load-imbalance",
              "straggler host: checkpoint-restart without it (elastic), "
              "check MoE expert balance"),
         node("collective_frac", ">", 0.4,
              leaf("collective-bound",
                   "overlap collectives with compute, rethink sharding axes, "
                   "gradient compression, larger per-device batch"),
              node("memory_frac", ">", 0.5,
                   node("useful_flop_ratio", "<", 0.6,
                        leaf("recompute-heavy memory-bound",
                             "relax remat policy; fuse attention (flash) to "
                             "cut activation traffic"),
                        leaf("memory-bound",
                             "increase arithmetic intensity: fuse ops, "
                             "quantize weights/cache, batch decode requests")),
                   node("mfu", "<", 0.25,
                        leaf("latency/overhead-bound",
                             "kernel launch / small-batch overheads: grow "
                             "per-device batch, unroll scan, check host "
                             "callbacks"),
                        leaf("compute-bound",
                             "good: push block shapes / MXU alignment; "
                             "consider int8/fp8 matmuls"))))))


def classify_job(metrics: dict, tree: PatternNode = DEFAULT_TREE) -> dict:
    pattern, remedy, path = tree.classify(dict(metrics))
    return {"pattern": pattern, "remedy": remedy, "path": path}


# ==========================================================================
# 3. Roofline analyzer (assignment §Roofline; feeds the tree above)
# ==========================================================================


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def terms(self) -> dict:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}

    @property
    def dominant(self) -> str:
        return max(self.terms, key=self.terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / bound — 1.0 means perfectly compute-limited."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def fractions(self) -> dict:
        tot = sum(self.terms.values()) or 1.0
        return {f"{k}_frac": v / tot for k, v in self.terms.items()}

    def classify(self, extra_metrics: Optional[dict] = None) -> dict:
        m = {**self.fractions(),
             "useful_flop_ratio": self.useful_flop_ratio,
             "mfu": self.roofline_fraction,   # upper-bound MFU from terms
             "data_stall_frac": 0.0, "straggler_skew": 0.0}
        if extra_metrics:
            m.update(extra_metrics)
        return classify_job(m)


class RooflineAnalyzer:
    """Three-term roofline from dry-run artifacts (per-chip quantities)."""

    def __init__(self, peak_flops: float = PEAK_FLOPS,
                 hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW):
        self.peak_flops = peak_flops
        self.hbm_bw = hbm_bw
        self.ici_bw = ici_bw

    def analyze(self, *, arch: str, shape: str, mesh: str, chips: int,
                hlo_flops: float, hbm_bytes: float, collective_bytes: float,
                model_flops: float) -> RooflineResult:
        """All inputs are *global* (whole-program) quantities; terms are
        per-chip seconds assuming perfect balance (cost_analysis reports the
        SPMD-partitioned module, i.e. per-device work, times 1; we pass
        per-device numbers scaled up by ``chips`` for clarity)."""
        return RooflineResult(
            arch=arch, shape=shape, mesh=mesh, chips=chips,
            compute_s=hlo_flops / (chips * self.peak_flops),
            memory_s=hbm_bytes / (chips * self.hbm_bw),
            collective_s=collective_bytes / (chips * self.ici_bw),
            model_flops=model_flops, hlo_flops=hlo_flops,
            hbm_bytes=hbm_bytes, collective_bytes=collective_bytes)
