"""Job registry: (de)allocation signals and the tags they carry (§III.A-B).

In the paper, compute nodes (or the scheduler) send signals at job
(de)allocation; the router keeps a *tag store* keyed by hostname so every
metric arriving from a participating host is enriched with the job's tags.
A TPU-pod training/serving run is one job; hosts are the per-process workers
(one per TPU VM host at scale, simulated hostnames on CPU).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.line_protocol import now_ns


@dataclass
class JobInfo:
    job_id: str
    user: str
    hosts: list
    tags: dict = field(default_factory=dict)
    start_ns: int = 0
    end_ns: Optional[int] = None

    @property
    def running(self) -> bool:
        return self.end_ns is None

    def all_tags(self) -> dict:
        return {"jobid": self.job_id, "username": self.user, **self.tags}


class JobRegistry:
    """Tracks jobs + the host->tags store used by the router."""

    def __init__(self):
        self._lock = threading.RLock()
        self._jobs: dict = {}
        self._host_tags: dict = {}        # hostname -> tags dict

    def start(self, job_id: str, user: str, hosts: list,
              tags: Optional[dict] = None, ts: Optional[int] = None) -> JobInfo:
        with self._lock:
            job = JobInfo(job_id, user, list(hosts), dict(tags or {}),
                          ts if ts is not None else now_ns())
            self._jobs[job_id] = job
            for h in hosts:
                self._host_tags[h] = job.all_tags()
            return job

    def end(self, job_id: str, ts: Optional[int] = None) -> Optional[JobInfo]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                return None
            job.end_ns = ts if ts is not None else now_ns()
            for h in job.hosts:
                if self._host_tags.get(h, {}).get("jobid") == job_id:
                    del self._host_tags[h]
            return job

    def tags_for_host(self, hostname: str) -> dict:
        with self._lock:
            return dict(self._host_tags.get(hostname, {}))

    def get(self, job_id: str) -> Optional[JobInfo]:
        with self._lock:
            return self._jobs.get(job_id)

    def running_jobs(self) -> list:
        with self._lock:
            return [j for j in self._jobs.values() if j.running]

    def all_jobs(self) -> list:
        with self._lock:
            return list(self._jobs.values())
