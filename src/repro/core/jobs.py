"""Job registry: (de)allocation signals and the tags they carry (§III.A-B).

In the paper, compute nodes (or the scheduler) send signals at job
(de)allocation; the router keeps a *tag store* keyed by hostname so every
metric arriving from a participating host is enriched with the job's tags.
A TPU-pod training/serving run is one job; hosts are the per-process workers
(one per TPU VM host at scale, simulated hostnames on CPU).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.line_protocol import now_ns


@dataclass
class JobInfo:
    job_id: str
    user: str
    hosts: list
    tags: dict = field(default_factory=dict)
    start_ns: int = 0
    end_ns: Optional[int] = None

    @property
    def running(self) -> bool:
        return self.end_ns is None

    def all_tags(self) -> dict:
        return {"jobid": self.job_id, "username": self.user, **self.tags}


class JobRegistry:
    """Tracks jobs + the host->job store used by the router.

    Per host the registry keeps a *stack* of allocations (most recent
    last), not a single tags dict: schedulers do overlap jobs on a host
    (shared nodes, epilog/prolog races), and the old flat store had two
    bugs — ``start`` of a second job silently overwrote the first job's
    enrichment for good, and ``end`` of the newer job dropped the host
    from the store entirely instead of re-exposing the older job's tags.
    ``tags_for_host`` now resolves to the most recently started job still
    running on that host.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._jobs: dict = {}
        self._host_jobs: dict = {}        # hostname -> [job_id, ...] stack
        self._end_hooks: list = []

    def on_end(self, fn):
        """Register ``fn(JobInfo)`` to run when a job ends — the hook the
        analysis engine uses to close a job's open alert state and prune
        its per-series evaluation state.  Hooks run *outside* the registry
        lock (they may query/write the TSDB) and are exception-guarded: a
        broken hook must not break job deallocation."""
        with self._lock:
            self._end_hooks.append(fn)
        return fn

    def start(self, job_id: str, user: str, hosts: list,
              tags: Optional[dict] = None, ts: Optional[int] = None) -> JobInfo:
        with self._lock:
            # restarted/requeued job id: drop the OLD allocation from every
            # host it held (the new one may be smaller — de-allocated hosts
            # must stop receiving the job's tags)
            old = self._jobs.get(job_id)
            if old is not None:
                self._drop_from_hosts(job_id, old.hosts)
            job = JobInfo(job_id, user, list(hosts), dict(tags or {}),
                          ts if ts is not None else now_ns())
            self._jobs[job_id] = job
            for h in hosts:
                self._host_jobs.setdefault(h, []).append(job_id)
            return job

    def _drop_from_hosts(self, job_id: str, hosts: list):
        for h in hosts:
            stack = self._host_jobs.get(h)
            if stack and job_id in stack:
                stack.remove(job_id)
                if not stack:
                    del self._host_jobs[h]

    def end(self, job_id: str, ts: Optional[int] = None) -> Optional[JobInfo]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                return None
            job.end_ns = ts if ts is not None else now_ns()
            self._drop_from_hosts(job_id, job.hosts)
            hooks = list(self._end_hooks)
        for fn in hooks:
            try:
                fn(job)
            except Exception:       # noqa: BLE001 — see on_end
                pass
        return job

    def tags_for_host(self, hostname: str) -> dict:
        with self._lock:
            stack = self._host_jobs.get(hostname)
            if not stack:
                return {}
            for jid in reversed(stack):
                job = self._jobs.get(jid)
                if job is not None and job.running:
                    return job.all_tags()
            return {}

    def get(self, job_id: str) -> Optional[JobInfo]:
        with self._lock:
            return self._jobs.get(job_id)

    def running_jobs(self) -> list:
        with self._lock:
            return [j for j in self._jobs.values() if j.running]

    def all_jobs(self) -> list:
        with self._lock:
            return list(self._jobs.values())
