"""InfluxDB line protocol — the single wire format of the LMS (paper §III.A).

    measurement[,tag_key=tag_val...] field_key=field_val[,...] [timestamp_ns]

The paper chose this protocol because (a) it separates metric values from
metric *tags*, (b) multiple lines concatenate for batched transmission, and
(c) it is human-readable.  This module implements a faithful encoder/decoder
pair (escaping rules per the InfluxDB 1.x reference) that round-trips —
property-tested with hypothesis in ``tests/test_line_protocol.py``.

Field values: floats (``1.0``), integers (``42i``), booleans (``t``/``f``)
and strings (``"..."`` with ``\\"`` escapes).  Events (paper §IV) are simply
points whose fields are strings.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional, Union

FieldValue = Union[float, int, bool, str]


@dataclass
class Point:
    """One measurement line."""

    measurement: str
    tags: dict = field(default_factory=dict)
    fields: dict = field(default_factory=dict)
    timestamp: Optional[int] = None        # ns since epoch

    def with_tags(self, extra: dict) -> "Point":
        if not extra:
            return self
        merged = dict(self.tags)
        merged.update(extra)
        return Point(self.measurement, merged, self.fields, self.timestamp)

    def is_event(self) -> bool:
        return any(isinstance(v, str) for v in self.fields.values())


def now_ns() -> int:
    return time.time_ns()


# --------------------------------------------------------------------------
# Escaping (InfluxDB 1.x rules)
# --------------------------------------------------------------------------

_MEAS_ESC = {",": "\\,", " ": "\\ "}
_TAG_ESC = {",": "\\,", " ": "\\ ", "=": "\\="}


def _escape(s: str, table: dict) -> str:
    out = s.replace("\\", "\\\\")
    for raw, esc in table.items():
        out = out.replace(raw, esc)
    return out


def _unescape(s: str) -> str:
    out = []
    i = 0
    while i < len(s):
        if s[i] == "\\" and i + 1 < len(s):
            out.append(s[i + 1])
            i += 2
        else:
            out.append(s[i])
            i += 1
    return "".join(out)


def _encode_field_value(v: FieldValue) -> str:
    if isinstance(v, bool):
        return "t" if v else "f"
    if isinstance(v, int):
        return f"{v}i"
    if isinstance(v, float):
        if math.isnan(v):
            return "nan"          # extension: InfluxDB rejects NaN; we need
        if math.isinf(v):         # it to transport pathological-job evidence
            return "inf" if v > 0 else "-inf"
        return repr(v)
    if isinstance(v, str):
        # extension: CR/LF inside string fields are escaped (the protocol is
        # newline-framed; InfluxDB clients commonly do the same)
        body = (v.replace("\\", "\\\\").replace('"', '\\"')
                .replace("\n", "\\n").replace("\r", "\\r"))
        return '"' + body + '"'
    raise TypeError(f"unsupported field value {v!r}")


def encode_point(p: Point) -> str:
    parts = [_escape(p.measurement, _MEAS_ESC)]
    for k in sorted(p.tags):
        v = p.tags[k]
        parts.append(f",{_escape(str(k), _TAG_ESC)}={_escape(str(v), _TAG_ESC)}")
    if not p.fields:
        raise ValueError("point must have at least one field")
    fields = ",".join(
        f"{_escape(str(k), _TAG_ESC)}={_encode_field_value(v)}"
        for k, v in sorted(p.fields.items()))
    line = "".join(parts) + " " + fields
    if p.timestamp is not None:
        line += f" {int(p.timestamp)}"
    return line


def encode_batch(points: Iterable[Point]) -> str:
    """Concatenate lines for batched transmission (paper §III.A)."""
    return "\n".join(encode_point(p) for p in points)


# --------------------------------------------------------------------------
# Parsing
# --------------------------------------------------------------------------


class LineProtocolError(ValueError):
    pass


def _parse_ts(s: str) -> int:
    try:
        return int(s)
    except ValueError:
        raise LineProtocolError(f"bad timestamp {s!r}") from None


def _split_unescaped(s: str, sep: str, maxsplit: int = -1) -> list:
    """Split on ``sep`` outside escapes and double quotes."""
    out, cur = [], []
    in_quotes = False
    i = 0
    while i < len(s):
        c = s[i]
        if c == "\\" and i + 1 < len(s):
            cur.append(c)
            cur.append(s[i + 1])
            i += 2
            continue
        if c == '"':
            in_quotes = not in_quotes
            cur.append(c)
        elif c == sep and not in_quotes and maxsplit != 0:
            out.append("".join(cur))
            cur = []
            if maxsplit > 0:
                maxsplit -= 1
        else:
            cur.append(c)
        i += 1
    out.append("".join(cur))
    return out


_TRUE = frozenset(("t", "T", "true", "True"))
_FALSE = frozenset(("f", "F", "false", "False"))


def _parse_field_value(s: str) -> FieldValue:
    if s.startswith('"'):
        if not s.endswith('"') or len(s) < 2:
            raise LineProtocolError(f"bad string field {s!r}")
        body = s[1:-1]
        out, i = [], 0
        special = {"n": "\n", "r": "\r"}
        while i < len(body):
            if body[i] == "\\" and i + 1 < len(body):
                out.append(special.get(body[i + 1], body[i + 1]))
                i += 2
            else:
                out.append(body[i])
                i += 1
        return "".join(out)
    if s.endswith("i"):
        # a malformed integer ("12xi") must surface as a protocol error,
        # not a bare ValueError the batch decoder cannot attribute
        try:
            return int(s[:-1])
        except ValueError:
            raise LineProtocolError(f"bad integer field {s!r}") from None
    try:
        return float(s)          # also accepts nan / inf / -inf
    except ValueError:
        pass
    if s in _TRUE:
        return True
    if s in _FALSE:
        return False
    raise LineProtocolError(f"bad field value {s!r}")


def _decode_line_fast(line: str, head_cache: Optional[dict] = None) -> Point:
    """Decode a line containing no escapes and no quoted strings.

    Machine-emitted metric lines (the batched ingest hot path) virtually
    never use escaping, so plain ``str.split`` replaces the char-by-char
    escape-aware splitter.  Semantics match :func:`decode_line` exactly:
    any construct that would decode differently (a bare ``=`` inside a
    tag/field value) raises, as the slow path does.

    ``head_cache`` (used by :func:`decode_batch`) memoizes the parsed
    ``measurement,tag=val...`` head — lines of one batch overwhelmingly
    share a handful of heads, so tag parsing amortizes to a dict copy.
    """
    parts = line.split(" ")
    np_ = len(parts)
    if np_ == 2 and parts[0] and parts[1]:
        ts = None
    elif np_ >= 3 and parts[0] and parts[1] and parts[2]:
        ts = _parse_ts(parts[2])
    else:                       # rare: repeated separators
        parts = [h for h in parts if h]
        if len(parts) < 2:
            raise LineProtocolError(f"no fields in {line!r}")
        ts = _parse_ts(parts[2]) if len(parts) >= 3 else None
    head = parts[0]
    cached = head_cache.get(head) if head_cache is not None else None
    if cached is None:
        hp = head.split(",")
        measurement = hp[0]
        if not measurement:
            raise LineProtocolError("empty measurement")
        tags = {}
        for t in hp[1:]:
            k, sep, v = t.partition("=")
            if not sep or "=" in v:
                raise LineProtocolError(f"bad tag {t!r}")
            tags[k] = v
        if head_cache is not None:
            head_cache[head] = (measurement, tags)
    else:
        measurement, tags = cached
    fields = {}
    for f in parts[1].split(","):
        k, sep, v = f.partition("=")
        if not sep or "=" in v:
            raise LineProtocolError(f"bad field {f!r}")
        fields[k] = _parse_field_value(v)
    return Point(measurement, dict(tags), fields, ts)


def decode_line(line: str) -> Point:
    line = line.strip()
    if not line or line.startswith("#"):
        raise LineProtocolError("empty/comment line")
    if "\\" not in line and '"' not in line:
        return _decode_line_fast(line)
    head_fields = _split_unescaped(line, " ")
    head_fields = [h for h in head_fields if h != ""]
    if len(head_fields) < 2:
        raise LineProtocolError(f"no fields in {line!r}")
    head = head_fields[0]
    fields_str = head_fields[1]
    ts = None
    if len(head_fields) >= 3:
        ts = _parse_ts(head_fields[2])

    head_parts = _split_unescaped(head, ",")
    measurement = _unescape(head_parts[0])
    if not measurement:
        raise LineProtocolError("empty measurement")
    tags = {}
    for t in head_parts[1:]:
        kv = _split_unescaped(t, "=")
        if len(kv) != 2:
            raise LineProtocolError(f"bad tag {t!r}")
        tags[_unescape(kv[0])] = _unescape(kv[1])

    fields = {}
    for f in _split_unescaped(fields_str, ","):
        kv = _split_unescaped(f, "=", maxsplit=1)
        if len(kv) != 2:
            raise LineProtocolError(f"bad field {f!r}")
        fields[_unescape(kv[0])] = _parse_field_value(kv[1])
    return Point(measurement, tags, fields, ts)


def decode_batch(data: str) -> list:
    points = []
    head_cache: dict = {}
    # frame on \n only — str.splitlines() would also split on \x0c etc.,
    # which are legal inside quoted string fields
    for line in data.split("\n"):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "\\" not in line and '"' not in line:
            points.append(_decode_line_fast(line, head_cache))
        else:
            points.append(decode_line(line))
    return points


def decode_batch_errors(data: str):
    """Partial-decode of one batched payload: ``(points, errors)``.

    Every line that parses becomes a :class:`Point`; every line that does
    not contributes ``{"line": <1-based line number>, "error": msg}``
    WITHOUT aborting its siblings — one malformed line in a 500-line
    agent batch must not drop the other 499 points
    (``MetricsRouter.write_lines`` partial-write semantics).
    """
    points, errors = [], []
    head_cache: dict = {}
    for lineno, line in enumerate(data.split("\n"), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            if "\\" not in line and '"' not in line:
                points.append(_decode_line_fast(line, head_cache))
            else:
                points.append(decode_line(line))
        except ValueError as e:             # incl. LineProtocolError
            errors.append({"line": lineno, "error": str(e)})
    return points, errors
