"""Embedded time-series database — the LMS DB back-end (paper §III.C).

The paper uses InfluxDB; an air-gapped TPU pod slice gets an embedded
equivalent with the properties the paper relies on:

* floats *and* strings as input values (metrics + events),
* tag-indexed storage with time-range / tag-filter / windowed-aggregation
  queries (what the dashboard agent and the analysis rules consume),
* multiple named databases (global + per-user/per-job duplication, §III.B),
* a retention policy to keep the generated data volume under control (§II),
* streaming rollups (``repro.core.rollup``): tiered windowed aggregates
  maintained incrementally at write time, so windowed queries are served
  from O(#windows) summaries and survive raw-point retention,
* crash-safe durability (``repro.core.wal``): a segmented write-ahead log
  plus snapshot/compaction, so job histories survive restarts and even
  mid-write crashes (torn tails are truncated, never fatal).

Writes take whole batches: points are grouped per series first, then
appended column-wise under one lock acquisition, which is what makes the
batched ingest path (``line_protocol.decode_batch`` -> ``MetricsRouter``
-> here) amortize to near the raw-append cost.

Thread-safe: the router may write from the training thread while the HTTP
endpoint and analyzers read concurrently.  A single ``Database`` serializes
on one lock; ``TSDBServer(shards=N)`` swaps in the hash-partitioned
``repro.core.shard.ShardedDatabase`` (per-shard locks, scatter-gather
queries) for write paths that must scale past that lock.

``aggregate_partials`` / ``rollup_window_partials`` expose *mergeable*
aggregate state (``WindowAgg``): the exact building block the federation
layer combines across shards and across remote LMS instances.
"""

from __future__ import annotations

import bisect
import math
import operator
import os
import random
import threading
from collections import defaultdict
from dataclasses import dataclass
from typing import Iterable, Optional

from repro.core.line_protocol import Point, now_ns
from repro.core.rollup import (ROLLUP_AGGS, RollupConfig, SeriesRollups,
                               WindowAgg, finalize_scalar, finalize_windowed,
                               known_agg, merge_window_maps, quantile_of)


@dataclass
class Series:
    """One (measurement, tags) series: parallel time/values columns."""

    measurement: str
    tags: dict
    times: list
    values: dict                     # field name -> list


def _tags_key(tags: dict) -> tuple:
    return tuple(sorted(tags.items()))


_first = operator.itemgetter(0)


class Database:
    """One named database: measurement -> {tags_key -> _SeriesStore}.

    ``rollup_config`` enables streaming rollups (on by default); pass
    ``rollup_config=None`` for a raw-only database.
    """

    def __init__(self, name: str,
                 rollup_config: Optional[RollupConfig] = RollupConfig()):
        self.name = name
        self._lock = threading.RLock()
        self._meas: dict = defaultdict(dict)     # meas -> tags_key -> store
        self._count = 0
        # per-measurement ingest watermark (monotonic; bumped by writes,
        # snapshot restores and retention) — what the query-engine result
        # cache keys on (repro.core.query).  The random per-instance
        # epoch makes watermarks from different database *incarnations*
        # disjoint: without it, a long-lived client engine could cache a
        # result at counter N, watch the backend restart and re-count its
        # way back to exactly N with different data, and serve the stale
        # entry as a hit.
        self._versions: dict = defaultdict(int)
        # SystemRandom: immune to user random.seed() calls, which would
        # otherwise reproduce identical epochs across incarnations
        self._version_epoch = random.SystemRandom().getrandbits(62)
        self.rollup_config = rollup_config
        # optional cold-tier read view (repro.core.coldstore.ColdView):
        # sealed immutable fragments merged under the hot columns in
        # select() — every raw consumer above inherits it from there
        self._cold = None

    # -- write --------------------------------------------------------------

    @staticmethod
    def group_points(points: Iterable[Point]):
        """Group a batch per series key: ``(by_series, tags_of)`` with
        ``by_series[(meas, tags_key)] = [(ts, fields), ...]``.  Shared by
        the direct write path and ``ShardedDatabase`` (which groups once,
        routes per *series*, and hands each shard its pre-grouped slice —
        no per-point re-sorting or re-hashing)."""
        by_series: dict = {}
        tags_of: dict = {}
        for p in points:
            ts = p.timestamp if p.timestamp is not None else now_ns()
            key = (p.measurement, _tags_key(p.tags))
            items = by_series.get(key)
            if items is None:
                items = by_series[key] = []
                tags_of[key] = p.tags
            items.append((ts, p.fields))
        return by_series, tags_of

    def write(self, points: Iterable[Point]):
        # group per series outside the lock: one store lookup + one
        # column-extend per series instead of per point
        by_series, tags_of = self.group_points(points)
        if by_series:
            self.write_grouped(by_series, tags_of)

    def write_grouped(self, by_series: dict, tags_of: dict,
                      capture: bool = False):
        """Apply a pre-grouped batch (see :meth:`group_points`) under the
        lock — the single lock acquisition of the batched ingest path.

        With ``capture=True``, returns ``{(meas, key): (sorted_times,
        {field: column})}`` — the columnar form this very apply
        materialized, which the WAL (``repro.core.wal``) logs without a
        second pass over the batch.  The captured lists are private
        copies, safe to use after the lock is released.
        """
        captured = {} if capture else None
        with self._lock:
            for (meas, key), items in by_series.items():
                store = self._meas[meas].get(key)
                if store is None:
                    store = _SeriesStore(dict(tags_of[(meas, key)]),
                                         self.rollup_config, meas)
                    self._meas[meas][key] = store
                cap = store.extend(items)
                self._count += len(items)
                self._versions[meas] += 1
                if captured is not None:
                    if cap is None:     # out-of-order fallback path
                        cap = self.transpose_items(items)
                    captured[(meas, key)] = cap
        return captured

    @staticmethod
    def transpose_items(items: list):
        """``[(ts, fields), ...]`` -> ``(sorted_times, {field: column})``
        with ``None`` holes — the columnar form :meth:`write_columns`
        applies and the WAL logs (one transpose, shared by both)."""
        if len(items) > 1:
            items = sorted(items, key=_first)
        names = set()
        for _, fields in items:
            names.update(fields)
        return ([ts for ts, _ in items],
                {k: [fields.get(k) for _, fields in items] for k in names})

    def write_columns(self, by_series_cols: dict, tags_of: dict):
        """Apply a pre-grouped, pre-transposed batch:
        ``by_series_cols[(meas, tags_key)] = (times, {field: column})``
        with per-series ascending times (:meth:`transpose_items`).  The
        columnar twin of :meth:`write_grouped` — the WAL write/replay path.
        """
        with self._lock:
            for (meas, key), (times, cols) in by_series_cols.items():
                store = self._meas[meas].get(key)
                if store is None:
                    store = _SeriesStore(dict(tags_of[(meas, key)]),
                                         self.rollup_config, meas)
                    self._meas[meas][key] = store
                store.extend_columns(times, cols)
                self._count += len(times)
                self._versions[meas] += 1

    # -- snapshot state (repro.core.wal) -------------------------------------

    def snapshot_state(self) -> dict:
        """Deep-copied, JSON-safe dump of the live column stores plus
        rollup window state, captured under the lock — what a WAL snapshot
        persists so recovery is O(live data), not O(all-time writes)."""
        with self._lock:
            series = []
            for meas, stores in self._meas.items():
                for store in stores.values():
                    series.append({
                        "m": meas, "tags": dict(store.tags),
                        "times": list(store.times),
                        "values": {k: list(col)
                                   for k, col in store.values.items()},
                        "rollups": store.rollups.dump_state()
                        if store.rollups is not None else None})
            return {"count": self._count, "series": series}

    def restore_series(self, entries: Iterable[dict]):
        """Install snapshot series (inverse of :meth:`snapshot_state`) —
        no re-sorting, no rollup re-aggregation.  Only for series whose
        keys are not yet present (fresh recovery)."""
        with self._lock:
            for e in entries:
                store = _SeriesStore(dict(e["tags"]), self.rollup_config,
                                     e["m"])
                store.times = list(e["times"])
                store.values = defaultdict(
                    list, {k: list(col) for k, col in e["values"].items()})
                if store.rollups is not None and e.get("rollups"):
                    store.rollups.restore_state(e["rollups"])
                self._meas[e["m"]][_tags_key(store.tags)] = store
                self._versions[e["m"]] += 1

    def add_count(self, n: int):
        """Credit ``n`` toward :meth:`point_count` (snapshot restore: the
        ever-written count includes retention-dropped points)."""
        with self._lock:
            self._count += n

    # -- introspection -------------------------------------------------------

    def measurements(self) -> list:
        with self._lock:
            names = set(self._meas)
            if self._cold is not None:
                names.update(self._cold.measurements())
            return sorted(names)

    def field_keys(self, measurement: str) -> list:
        with self._lock:
            keys = set()
            for store in self._meas.get(measurement, {}).values():
                keys.update(store.values)
                if store.rollups is not None:
                    keys.update(store.rollups.fields())
            if self._cold is not None:
                keys.update(self._cold.field_keys(measurement))
            return sorted(keys)

    def tag_values(self, measurement: str, tag: str) -> list:
        with self._lock:
            vals = {store.tags.get(tag)
                    for store in self._meas.get(measurement, {}).values()}
            if self._cold is not None:
                vals.update(self._cold.tag_values(measurement, tag))
            return sorted(v for v in vals if v is not None)

    def point_count(self) -> int:
        """Points ever written (not reduced by retention)."""
        with self._lock:
            return self._count

    def stored_points(self) -> int:
        """Raw points currently queryable: hot resident plus sealed cold
        (retention *moves* points to the cold tier when one is attached,
        and only then reduces this count)."""
        with self._lock:
            n = sum(len(store.times)
                    for stores in self._meas.values()
                    for store in stores.values())
            if self._cold is not None:
                n += self._cold.stored_points()
            return n

    def data_version(self, measurement: Optional[str] = None) -> int:
        """Ingest watermark: changes whenever the measurement's data
        changes (write batch, snapshot restore, retention trim), and
        never repeats across database incarnations (random epoch base).
        ``None`` covers all measurements.  The query engine
        (``repro.core.query``) keys its result cache on this — O(1) to
        read, and a repeated query is served from cache exactly until
        the data underneath it moved."""
        with self._lock:
            if measurement is None:
                return self._version_epoch + sum(self._versions.values())
            return self._version_epoch + self._versions.get(measurement, 0)

    # -- query ---------------------------------------------------------------

    def _stores(self, measurement: str, tags: Optional[dict]):
        for store in self._meas.get(measurement, {}).values():
            if tags and any(store.tags.get(k) != str(v)
                            for k, v in tags.items()):
                continue
            yield store

    def select(self, measurement: str, fields: Optional[list] = None,
               tags: Optional[dict] = None, t_min: Optional[int] = None,
               t_max: Optional[int] = None) -> list:
        """Return matching Series (copies, safe to use lock-free).

        With a cold tier attached, sealed fragments are merged *under*
        the hot columns right here — so every raw consumer above
        (``aggregate``, ``aggregate_partials``, sharding, federation,
        the query planner) inherits cold transparency from this single
        merge point and answers byte-identically to an uncompacted
        database.  The merge runs under the database lock, the same lock
        ``commit_seal`` trims under, so no query can observe a point in
        both tiers (double-count) or neither (loss) mid-seal.
        """
        with self._lock:
            cold_frags: dict = {}
            if self._cold is not None:
                for tk, _ctags, ctimes, cvals in self._cold.fragments(
                        measurement, fields, tags, t_min, t_max):
                    cold_frags.setdefault(tk, []).append((ctimes, cvals))
            out = []
            for key, store in self._meas.get(measurement, {}).items():
                if tags and any(store.tags.get(k) != str(v)
                                for k, v in tags.items()):
                    continue
                pieces = cold_frags.pop(key, None)
                s = store.slice(t_min, t_max, fields)
                if pieces is None:
                    if s is not None:
                        out.append(Series(measurement, dict(store.tags),
                                          s[0], s[1]))
                    continue
                # sealed fragments (chunk-seq order == seal order) under
                # the hot suffix: reproduces the uncompacted store's row
                # order exactly (seals move strict time-prefixes; equal
                # timestamps keep arrival order)
                if s is not None:
                    pieces.append(s)
                names = fields if fields else list(store.values)
                times, vals = _merge_pieces(
                    pieces, [k for k in names if k in store.values])
                if times and vals:
                    out.append(Series(measurement, dict(store.tags),
                                      times, vals))
            # sealed series whose hot store no longer exists (degraded
            # path: snapshot lost, chunks survived) — deterministic
            # trailing order so repeated queries agree
            for tk in sorted(cold_frags):
                pieces = cold_frags[tk]
                names: list = []
                for _, cvals in pieces:
                    for k in cvals:
                        if k not in names:
                            names.append(k)
                times, vals = _merge_pieces(pieces, names)
                if times and vals:
                    out.append(Series(measurement, dict(tk), times, vals))
            return out

    def aggregate(self, measurement: str, field: str, *, agg: str = "mean",
                  tags: Optional[dict] = None, t_min: Optional[int] = None,
                  t_max: Optional[int] = None,
                  group_by_tag: Optional[str] = None,
                  window_ns: Optional[int] = None,
                  use_rollups: object = "auto"):
        """InfluxDB-style aggregation.

        Without ``window_ns``: scalar per group (dict group -> value).
        With ``window_ns``: dict group -> (window_starts, values).
        agg: mean | max | min | sum | count | last | pNN (quantiles).

        Quantile aggs (``p50``/``p95``/``p99``/any ``pNN``) always route
        through the mergeable-partials path and finalize locally, so a
        local answer is *by construction* identical to the sharded and
        HTTP-federated answers (those also merge partials).  Quantiles are
        served from rollup sketches for fields opted in via
        ``RollupConfig(sketch_fields=...)``; for unsketched fields the
        partials carry no sketch and the result is empty rather than an
        error (``HttpQueryClient`` validates against ``/meta?what=rollups``
        to fail fast instead).

        ``use_rollups`` (windowed form only — the scalar form always
        rescans raw): "auto" serves from the rollup tiers whenever the
        result is provably identical to a raw rescan (window size nests
        into a tier, range boundaries window-aligned); True forces the
        rollup path (whole-window range granularity, works after raw
        retention) and raises ``ValueError`` when no tier can serve the
        window, rather than silently degrading to the retention-truncated
        raw data; False forces the raw rescan.
        """
        if quantile_of(agg) is not None:
            parts = self.aggregate_partials(
                measurement, field, tags=tags, t_min=t_min, t_max=t_max,
                group_by_tag=group_by_tag, window_ns=window_ns,
                use_rollups=use_rollups)
            if window_ns is None:
                return finalize_scalar(parts, agg)
            return finalize_windowed(parts, agg)
        if self._serve_from_rollups(window_ns, agg, t_min, t_max,
                                    use_rollups):
            return self.rollup_aggregate(
                measurement, field, agg=agg, tags=tags, t_min=t_min,
                t_max=t_max, group_by_tag=group_by_tag, window_ns=window_ns)
        series = self.select(measurement, [field], tags, t_min, t_max)
        groups: dict = defaultdict(lambda: ([], []))
        for s in series:
            g = s.tags.get(group_by_tag, "") if group_by_tag else ""
            ts, vs = groups[g]
            ts.extend(s.times)
            vs.extend(s.values.get(field, []))
        out = {}
        for g, (ts, vs) in groups.items():
            pairs = sorted((t, v) for t, v in zip(ts, vs)
                           if isinstance(v, (int, float)) and
                           not isinstance(v, bool))
            if not pairs:
                continue
            if window_ns is None:
                out[g] = _agg([v for _, v in pairs], agg)
            else:
                w0 = pairs[0][0] - pairs[0][0] % window_ns
                wins: dict = defaultdict(list)
                for t, v in pairs:
                    wins[(t - w0) // window_ns].append(v)
                starts = sorted(wins)
                out[g] = ([w0 + i * window_ns for i in starts],
                          [_agg(wins[i], agg) for i in starts])
        return out

    def aggregate_partials(self, measurement: str, field: str, *,
                           tags: Optional[dict] = None,
                           t_min: Optional[int] = None,
                           t_max: Optional[int] = None,
                           group_by_tag: Optional[str] = None,
                           window_ns: Optional[int] = None,
                           use_rollups: object = "auto"):
        """Mergeable partial-aggregate state — the scatter half of the
        federated scatter-gather path (``repro.core.shard``).

        Scalar form (``window_ns=None``): ``{group: WindowAgg}`` built from
        a raw scan.  Windowed form: ``{group: {window_start: WindowAgg}}``
        with epoch-aligned window starts, served from the rollup tiers
        under the same exactness conditions as :meth:`aggregate` (or forced
        / disabled via ``use_rollups``), otherwise from a raw rescan.

        Merging partials from *disjoint* series sets (shards, remote LMS
        instances) with ``WindowAgg.merge`` / ``merge_window_maps`` and
        finalizing with ``WindowAgg.value(agg)`` reproduces
        :meth:`aggregate` exactly for every agg in ``ROLLUP_AGGS`` —
        ``mean`` merges as (sum, count), ``last`` as the lexicographic
        (t, v) max, matching the raw path's sort-then-take-last.
        """
        # Scalar + forced rollups: merge every rollup window of the
        # finest tier into one whole-range partial per group.  The auto
        # path keeps the raw scan (scalar specs historically scan raw),
        # but use_rollups=True means "answer from the tiers" — the only
        # form that survives raw retention, e.g. whole-job p95 after the
        # raw points are gone (range filtering is window-granular, like
        # every forced rollup read).
        if window_ns is None and use_rollups is True:
            if self.rollup_config is None:
                raise ValueError("rollups disabled for this database; "
                                 "use use_rollups='auto' for a raw scan")
            wparts = self.rollup_window_partials(
                measurement, field, tags=tags, t_min=t_min, t_max=t_max,
                group_by_tag=group_by_tag)
            scalars: dict = {}
            for g, wins in wparts.items():
                total = None
                for wa in wins.values():
                    if total is None:
                        total = wa.fresh()
                    total.merge(wa)
                if total is not None and total.count:
                    scalars[g] = total
            return scalars
        # agg=None: every ROLLUP_AGGS aggregate finalizes from WindowAgg
        # state, so servability only depends on tier nesting + alignment
        if self._serve_from_rollups(window_ns, None, t_min, t_max,
                                    use_rollups):
            return self.rollup_window_partials(
                measurement, field, tags=tags, t_min=t_min, t_max=t_max,
                group_by_tag=group_by_tag, window_ns=window_ns)
        # copy the matching slices under the lock (select), build the
        # partial state lock-free: shard locks stay held for O(copy), not
        # O(scan) — the same hygiene as the raw aggregate() path.  The
        # config factory picks the family member, so sketched fields carry
        # sketches even on raw rescans (including cold-sealed data, which
        # select() reads back) and quantiles federate from any path.
        cfg = self.rollup_config
        out: dict = {}
        for s in self.select(measurement, [field], tags, t_min, t_max):
            g = s.tags.get(group_by_tag, "") if group_by_tag else ""
            col = s.values.get(field, ())
            for t, v in zip(s.times, col):
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    continue
                if window_ns is None:
                    agg = out.get(g)
                    if agg is None:
                        agg = out[g] = cfg.new_agg(measurement, field) \
                            if cfg is not None else WindowAgg()
                else:
                    wins = out.get(g)
                    if wins is None:
                        wins = out[g] = {}
                    w0 = t - t % window_ns
                    agg = wins.get(w0)
                    if agg is None:
                        agg = wins[w0] = cfg.new_agg(measurement, field) \
                            if cfg is not None else WindowAgg()
                agg.update(t, v)
        return out

    def rollup_window_partials(self, measurement: str, field: str, *,
                               tags: Optional[dict] = None,
                               t_min: Optional[int] = None,
                               t_max: Optional[int] = None,
                               group_by_tag: Optional[str] = None,
                               window_ns: Optional[int] = None,
                               quantile: bool = True) -> dict:
        """``{group: {window_start: WindowAgg}}`` from the rollup tiers —
        the mergeable form of :meth:`rollup_aggregate` (window-granularity
        range filtering, survives raw retention).  The returned WindowAggs
        are fresh merge products, safe to hand across threads/shards.

        ``quantile`` (default True): partials are federation currency and
        the consumer's agg is usually unknown here, so sketched fields
        decompose to the finest tier and carry their quantile bins (see
        ``SeriesRollups.windows``).  Agg-aware callers serving a *scalar*
        aggregate pass False to stay on the coarsest serving tier — the
        accumulation order then matches a sketch-free config exactly."""
        if self.rollup_config is None:
            return {}
        if window_ns is None:
            window_ns = self.rollup_config.tiers_ns[0]
        with self._lock:
            groups: dict = defaultdict(list)
            for store in self._stores(measurement, tags):
                if store.rollups is None:
                    continue
                g = store.tags.get(group_by_tag, "") if group_by_tag else ""
                groups[g].append(store.rollups.windows(
                    field, window_ns, t_min, t_max, quantile=quantile))
            return {g: merge_window_maps(maps)
                    for g, maps in groups.items()}

    def _serve_from_rollups(self, window_ns: Optional[int],
                            agg: Optional[str], t_min: Optional[int],
                            t_max: Optional[int],
                            use_rollups: object) -> bool:
        """Shared windowed-path decision for :meth:`aggregate` and
        :meth:`aggregate_partials`: True = serve from the rollup tiers;
        forced-but-unservable raises rather than silently degrading to
        retention-truncated raw data."""
        if window_ns is None or use_rollups is False:
            return False
        if self._rollup_serves(window_ns, agg if agg is not None else "mean",
                               t_min, t_max, force=use_rollups is True):
            return True
        if use_rollups is True:
            tiers = self.rollup_config.tiers_ns \
                if self.rollup_config is not None else ()
            what = f" agg={agg!r}" if agg is not None else ""
            raise ValueError(
                f"rollups cannot serve window_ns={window_ns}{what} "
                f"(tiers: {tiers}); use use_rollups='auto' to fall back "
                "to a raw rescan")
        return False

    def _rollup_serves(self, window_ns: int, agg: str,
                       t_min: Optional[int], t_max: Optional[int],
                       force: bool) -> bool:
        if self.rollup_config is None or not known_agg(agg) or \
                self.rollup_config.tier_for(window_ns) is None:
            return False
        if force:
            return True
        # exactness: range bounds must not cut a window in half.  t_min is
        # an inclusive lower bound -> window-aligned is exact; an interior
        # t_max would exclude points in its own window, so only None is
        # provably identical to the raw rescan.
        return (t_min is None or t_min % window_ns == 0) and t_max is None

    def rollup_aggregate(self, measurement: str, field: str, *,
                         agg: str = "mean", tags: Optional[dict] = None,
                         t_min: Optional[int] = None,
                         t_max: Optional[int] = None,
                         group_by_tag: Optional[str] = None,
                         window_ns: Optional[int] = None):
        """Windowed aggregation served from the rollup tiers.

        Same result shape as the windowed form of :meth:`aggregate`.
        Range filtering happens at window granularity (whole epoch-aligned
        windows).  Works after raw points have been dropped by retention.
        """
        parts = self.rollup_window_partials(
            measurement, field, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=group_by_tag, window_ns=window_ns,
            quantile=quantile_of(agg) is not None)
        return finalize_windowed(parts, agg)

    def rollup_series(self, measurement: str, field: str, *,
                      agg: str = "mean", tags: Optional[dict] = None,
                      window_ns: Optional[int] = None,
                      t_min: Optional[int] = None,
                      t_max: Optional[int] = None) -> list:
        """Per-series rollup readout: one :class:`Series` per raw series,
        with window starts as times — the downsampled view the dashboard
        sparklines and the analysis rules consume.  ``t_min``/``t_max``
        bound the range at window granularity (whole epoch-aligned
        windows), which is what the continuous analysis engine uses to
        sweep only windows past its per-series cursor."""
        if self.rollup_config is None:
            return []
        if window_ns is None:
            window_ns = self.rollup_config.tiers_ns[0]
        with self._lock:
            out = []
            for store in self._stores(measurement, tags):
                if store.rollups is None:
                    continue
                wins = store.rollups.windows(
                    field, window_ns, t_min, t_max,
                    quantile=quantile_of(agg) is not None)
                if not wins:
                    continue
                starts = []
                vals = []
                for w in sorted(wins):
                    v = wins[w].value(agg)
                    if v is None:     # empty window / quantile sans sketch
                        continue
                    starts.append(w)
                    vals.append(v)
                if not starts:
                    continue
                out.append(Series(measurement, dict(store.tags), starts,
                                  {field: vals}))
            return out

    def rollup_window_count(self, measurement: str, field: str, *,
                            tags: Optional[dict] = None,
                            tier_ns: Optional[int] = None) -> int:
        """Upper bound on merged window count for a tier (sum of per-series
        stored windows; cheap — lets callers pick a tier *before* paying
        for a merge)."""
        if self.rollup_config is None:
            return 0
        if tier_ns is None:
            tier_ns = self.rollup_config.tiers_ns[0]
        with self._lock:
            return sum(store.rollups.tier_window_count(field, tier_ns)
                       for store in self._stores(measurement, tags)
                       if store.rollups is not None)

    # -- cold tier (repro.core.coldstore) ------------------------------------

    def attach_cold(self, view):
        """Attach a cold-tier read view
        (``repro.core.coldstore.ColdView``).  Sealed fragments merge into
        every raw read from here on; the watermark epoch is re-rolled
        because the visible data just changed incarnation."""
        with self._lock:
            self._cold = view
            self._version_epoch = random.SystemRandom().getrandbits(62)

    def cold_view(self):
        return self._cold

    def has_expired_raw(self, cutoff: int) -> bool:
        """True iff any raw point older than ``cutoff`` is resident —
        what decides whether a retention sweep needs a seal at all."""
        with self._lock:
            return any(store.times and store.times[0] < cutoff
                       for stores in self._meas.values()
                       for store in stores.values())

    def capture_expired(self, cutoff: int) -> list:
        """Copy every raw column prefix older than ``cutoff`` in sealable
        form: ``[(measurement, tags, times, cols), ...]`` (private
        copies, all columns, ``None`` holes preserved).  Does NOT trim —
        :meth:`commit_seal` removes the prefixes atomically with the
        sealed chunk becoming query-visible.  The caller (the WAL layer)
        holds the write barrier between the two, so the captured prefix
        cannot drift."""
        out = []
        with self._lock:
            for meas, stores in self._meas.items():
                for store in stores.values():
                    lo = bisect.bisect_left(store.times, cutoff)
                    if lo <= 0:
                        continue
                    out.append((meas, dict(store.tags), store.times[:lo],
                                {k: col[:lo]
                                 for k, col in store.values.items()}))
        return out

    def commit_seal(self, cutoff: int, seq: Optional[int]) -> int:
        """Reader-side commit point of the seal protocol: under the one
        database lock, trim the raw prefixes older than ``cutoff`` AND
        flip sealed chunk ``seq`` visible — no interleaved query can see
        the moved points twice or not at all.  Rollup windows are kept
        (the seal moves raw history, it is not retention).  Returns the
        number of raw points moved."""
        moved = 0
        with self._lock:
            for meas, stores in self._meas.items():
                changed = False
                for store in stores.values():
                    n = store.trim(cutoff, None)
                    if n:
                        moved += n
                        changed = True
                if changed:
                    self._versions[meas] += 1
            if seq is not None and self._cold is not None:
                self._cold.commit(seq)
        return moved

    def cold_time_range(self, measurement: Optional[str] = None):
        """``(t_min, t_max)`` spanned by sealed chunks (``None`` when no
        cold tier / nothing sealed) — what the query planner consults to
        report which tiers a raw plan spans."""
        if self._cold is None:
            return None
        return self._cold.time_range(measurement)

    # -- retention ------------------------------------------------------------

    def enforce_retention(self, max_age_ns: Optional[int] = None,
                          max_points_per_series: Optional[int] = None,
                          rollup_max_age_ns: Optional[int] = None) -> dict:
        """Drop old raw data (paper §II: keep data volume under control).

        Rollup windows are *kept* — that is the point of the rollup layer —
        unless ``rollup_max_age_ns`` (or the config's ``max_age_ns``) sets
        an independent, typically much longer, horizon for them.

        Returns ``{"raw_points_dropped": n, "rollup_windows_dropped": m}``
        so callers can tell the sweep ran and what it discarded — on a
        persisted server these counts also accumulate into
        ``persistence_stats()`` (no more silent drops).  When a cold tier
        is configured, the WAL layer seals expired prefixes *before*
        calling this, so age-based drops only happen where they are meant
        to: no cold store, or the independent rollup horizon.
        """
        now = now_ns()
        cutoff = now - max_age_ns if max_age_ns else None
        raw_dropped = 0
        rollup_dropped = 0
        with self._lock:
            for meas, stores in self._meas.items():
                changed = False
                for store in stores.values():
                    n = store.trim(cutoff, max_points_per_series)
                    if n:
                        raw_dropped += n
                        changed = True
                    if store.rollups is not None:
                        w = store.rollups.trim(now, rollup_max_age_ns)
                        if w:
                            rollup_dropped += w
                            changed = True
                # invalidate cached query results over this measurement —
                # but only when the sweep actually dropped something, so
                # a periodic retention timer that finds nothing expired
                # does not defeat the O(1)-re-render cache
                if changed:
                    self._versions[meas] += 1
        return {"raw_points_dropped": raw_dropped,
                "rollup_windows_dropped": rollup_dropped}


def _merge_pieces(pieces: list, names: list):
    """Merge per-series column pieces — sealed cold fragments in seal
    order, then the hot suffix — into one ``(times, values)`` pair that
    is row-for-row identical to what the uncompacted store would have
    sliced.  Each piece is ``(times, {field: column})`` with ascending
    times; fields missing from a piece hole-fill with ``None`` (exactly
    the back-fill the live store applies when a field first appears).

    Fast path: seal-produced pieces are disjoint ascending (a seal moves
    a strict time-prefix), so concatenation preserves order.  The
    general fallback is a stable sort on ``(timestamp, piece, row)`` —
    equal timestamps keep seal-then-arrival order, matching the live
    store's stable insert."""
    present = [k for k in names
               if any(k in vals for _, vals in pieces)]
    if not present or not pieces:
        return [], {}
    if len(pieces) == 1:
        t, vals = pieces[0]
        return list(t), {k: list(vals[k]) if k in vals
                         else [None] * len(t) for k in present}
    if all(pieces[i][0][-1] <= pieces[i + 1][0][0]
           for i in range(len(pieces) - 1)):
        times: list = []
        for t, _ in pieces:
            times.extend(t)
        out = {}
        for k in present:
            col: list = []
            for t, vals in pieces:
                c = vals.get(k)
                col.extend(c if c is not None else [None] * len(t))
            out[k] = col
        return times, out
    rows = [(ts, pi, ri)
            for pi, (t, _) in enumerate(pieces)
            for ri, ts in enumerate(t)]
    rows.sort()
    cols = {k: [vals.get(k) for _, vals in pieces] for k in present}
    return ([r[0] for r in rows],
            {k: [c[pi][ri] if c[pi] is not None else None
                 for _, pi, ri in rows]
             for k, c in cols.items()})


def _agg(vals: list, agg: str):
    if agg == "mean":
        return sum(vals) / len(vals)
    if agg == "max":
        return max(vals)
    if agg == "min":
        return min(vals)
    if agg == "sum":
        return sum(vals)
    if agg == "count":
        return float(len(vals))
    if agg == "last":
        return vals[-1]
    q = quantile_of(agg)
    if q is not None:
        # exact nearest-rank percentile (rank ceil(q*n)-1, 0-based) — the
        # convention QuantileSketch.quantile approximates, so raw-rescan
        # ranking (query order_agg) and sketch answers are comparable
        s = sorted(vals)
        return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]
    raise ValueError(f"unknown agg {agg!r}")


class _SeriesStore:
    """Columnar store for one series; times kept sorted."""

    __slots__ = ("tags", "times", "values", "rollups")

    def __init__(self, tags: dict,
                 rollup_config: Optional[RollupConfig] = None,
                 measurement: Optional[str] = None):
        self.tags = tags
        self.times: list = []
        self.values: dict = defaultdict(list)
        self.rollups = SeriesRollups(rollup_config, measurement) \
            if rollup_config is not None else None

    def append(self, ts: int, fields: dict):
        self._insert(ts, fields)
        if self.rollups is not None:
            self.rollups.observe(ts, fields)

    def extend(self, items: list):
        """Batched append of ``(ts, fields)`` pairs (the ingest hot path).

        In-order batches (the overwhelmingly common case) extend all
        columns in one pass and return the ``(sorted_times, segs)``
        columns they materialized — the WAL capture
        (``Database.write_grouped``/``repro.core.wal``) logs exactly
        these, so durability pays no second transpose.  Any out-of-order
        item falls back to the per-point sorted insert and returns None.
        """
        if len(items) > 1:
            items = sorted(items, key=_first)
        if self.times and items[0][0] < self.times[-1]:
            for ts, fields in items:
                self._insert(ts, fields)
            if self.rollups is not None:
                for ts, fields in items:
                    self.rollups.observe(ts, fields)
            return None
        names = set(self.values)
        for _, fields in items:
            names.update(fields)
        n0 = len(self.times)
        new_times = [ts for ts, _ in items]
        self.times.extend(new_times)
        segs = {}
        for k in names:
            col = self.values[k]
            if len(col) < n0:
                col.extend([None] * (n0 - len(col)))
            seg = [fields.get(k) for _, fields in items]
            col.extend(seg)
            segs[k] = seg
        if self.rollups is not None:
            self.rollups.observe_columns(new_times, segs)
        return new_times, segs

    def extend_columns(self, new_times: list, segs: dict):
        """Batched append of pre-transposed columns — the WAL write/replay
        path (``repro.core.wal``), which transposes once and shares the
        result between the log record and this apply.

        ``new_times`` is ascending; ``segs`` maps field -> value list
        aligned with ``new_times`` (``None`` holes for points missing the
        field) — the same segment shape :meth:`extend` builds internally.
        """
        if self.times and new_times[0] < self.times[-1]:
            # rare out-of-order fallback: rebuild rows, per-point insert
            items = [(t, {k: col[i] for k, col in segs.items()
                          if col[i] is not None})
                     for i, t in enumerate(new_times)]
            for ts, fields in items:
                self._insert(ts, fields)
            if self.rollups is not None:
                for ts, fields in items:
                    self.rollups.observe(ts, fields)
            return
        n0 = len(self.times)
        self.times.extend(new_times)
        total = n0 + len(new_times)
        vals = self.values
        for k, seg in segs.items():
            col = vals[k]
            if len(col) < n0:
                col.extend([None] * (n0 - len(col)))
            col.extend(seg)
        if len(vals) > len(segs):
            # pre-existing fields absent from this batch: pad the holes
            for col in vals.values():
                if len(col) < total:
                    col.extend([None] * (total - len(col)))
        if self.rollups is not None:
            self.rollups.observe_columns(new_times, segs)

    def _insert(self, ts: int, fields: dict):
        if self.times and ts < self.times[-1]:
            idx = bisect.bisect_right(self.times, ts)
            self.times.insert(idx, ts)
            for k in self.values:
                self.values[k].insert(idx, fields.get(k))
            for k, v in fields.items():
                if k not in self.values:
                    col = [None] * (len(self.times))
                    col[idx] = v
                    self.values[k] = col
            return
        self.times.append(ts)
        n = len(self.times)
        for k in set(self.values) | set(fields):
            col = self.values[k]
            while len(col) < n - 1:
                col.append(None)
            col.append(fields.get(k))

    def slice(self, t_min, t_max, fields):
        lo = bisect.bisect_left(self.times, t_min) if t_min else 0
        hi = bisect.bisect_right(self.times, t_max) if t_max \
            else len(self.times)
        if lo >= hi:
            return None
        names = fields if fields else list(self.values)
        vals = {k: self.values[k][lo:hi] for k in names if k in self.values}
        if not vals:
            return None
        return self.times[lo:hi], vals

    def trim(self, cutoff, max_points) -> int:
        """Drop raw points before ``cutoff`` / beyond ``max_points``;
        returns the number removed (0 = nothing; retention bumps the
        measurement's data version and counts its drops only then)."""
        lo = 0
        if cutoff is not None:
            lo = bisect.bisect_left(self.times, cutoff)
        if max_points is not None:
            lo = max(lo, len(self.times) - max_points)
        if lo > 0:
            self.times = self.times[lo:]
            # must stay a defaultdict: append/extend rely on self.values[k]
            # materializing columns for fields first seen after a trim
            self.values = defaultdict(
                list, {k: v[lo:] for k, v in self.values.items()})
            return lo
        return 0


class TSDBServer:
    """Named-database manager (the "database back-end" box in Fig. 1).

    ``shards=N`` (N > 1) backs every named database with a
    :class:`repro.core.shard.ShardedDatabase` — N independent
    :class:`Database` partitions with per-shard locks, rollups and
    retention, query-federated behind the same interface — so concurrent
    batched writes from different hosts no longer contend on one lock.

    ``persist_dir`` enables crash-safe durability (``repro.core.wal``):
    every :meth:`write` batch goes through a per-database (per-shard, when
    sharded) segmented write-ahead log before it is applied, with
    ``fsync`` picking the durability/throughput trade-off
    (``none|batch|always``).  :meth:`load_persisted` recovers snapshot +
    WAL (tolerating torn tails from unclean shutdowns and importing the
    legacy ``*.jsonl`` format), :meth:`snapshot` compacts the log, and
    :meth:`enforce_retention` drops whole expired segments.
    """

    def __init__(self, persist_dir: Optional[str] = None,
                 rollup_config: Optional[RollupConfig] = RollupConfig(),
                 shards: int = 1, fsync: str = "batch",
                 wal_segment_bytes: int = 4 * 1024 * 1024,
                 cold: bool = False):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if fsync not in ("none", "batch", "always"):
            raise ValueError(f"fsync must be none|batch|always, "
                             f"got {fsync!r}")
        if cold and not persist_dir:
            raise ValueError("cold tier requires persist_dir (chunks are "
                             "sealed from the snapshot/compaction path)")
        self._dbs: dict = {}
        self._stores: dict = {}          # name -> wal.DurableStore
        self._engines: dict = {}         # name -> query.QueryEngine
        self._lock = threading.RLock()
        self._persist_dir = persist_dir
        self._rollup_config = rollup_config
        self._shards = int(shards)
        self._fsync = fsync
        self._wal_segment_bytes = int(wal_segment_bytes)
        self._cold = bool(cold)
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)

    def db(self, name: str = "global") -> Database:
        with self._lock:
            if name not in self._dbs:
                if self._shards > 1:
                    from repro.core.shard import ShardedDatabase
                    self._dbs[name] = ShardedDatabase(
                        name, shards=self._shards,
                        rollup_config=self._rollup_config)
                else:
                    self._dbs[name] = Database(name, self._rollup_config)
            return self._dbs[name]

    def store(self, name: str = "global"):
        """The durable store (WAL + snapshot) behind one database; None
        when the server runs without ``persist_dir``.

        The database name becomes a directory under ``persist_dir``, so
        names that would escape it (path separators, ``..``) are
        rejected — ``/write?db=`` and ``/admin/snapshot?db=`` are
        remote-reachable surfaces.
        """
        if not self._persist_dir:
            return None
        if name != os.path.basename(name) or name in ("", ".", ".."):
            raise ValueError(f"invalid database name {name!r}")
        with self._lock:
            if name not in self._stores:
                from repro.core.wal import DurableStore
                self._stores[name] = DurableStore(
                    self.db(name),
                    os.path.join(self._persist_dir, name),
                    fsync=self._fsync,
                    segment_max_bytes=self._wal_segment_bytes,
                    cold=self._cold)
            return self._stores[name]

    def query_engine(self, name: str = "global"):
        """The shared derived-metric query engine over one database
        (``repro.core.query.QueryEngine``) — one per database, so the
        HTTP ``/query/v2`` endpoint and the dashboard agent hit the same
        watermark-keyed result cache."""
        with self._lock:
            eng = self._engines.get(name)
            if eng is None:
                from repro.core.query import QueryEngine
                eng = self._engines[name] = QueryEngine(self.db(name))
            return eng

    def databases(self) -> list:
        with self._lock:
            return sorted(self._dbs)

    def write(self, points: Iterable[Point], db: str = "global"):
        store = self.store(db)
        if store is None:
            self.db(db).write(points)
        else:
            store.write(points)

    def write_columns(self, by_cols: dict, tags_of: dict,
                      db: str = "global"):
        """Columnar twin of :meth:`write` — the binary ingest plane
        (``repro.core.ingest``) lands here: ``by_cols[(meas, tags_key)] =
        (times, {field: column})`` with ascending per-series times.  On a
        persisted database the WAL logs the same columnar form, encoded
        with the same codec the wire used (near-zero-copy ingest→WAL)."""
        store = self.store(db)
        if store is None:
            self.db(db).write_columns(by_cols, tags_of)
        else:
            store.write_columns(by_cols, tags_of)

    # -- durability (repro.core.wal) -----------------------------------------

    def load_persisted(self) -> dict:
        """Recover every persisted database: latest snapshot, then WAL
        replay (torn tails truncated with a warning, never an abort),
        then any legacy ``<db>.jsonl`` logs (imported into the WAL and
        renamed ``*.jsonl.imported``).  Returns per-database recovery
        stats.  Safe on an empty/fresh ``persist_dir``."""
        if not self._persist_dir:
            return {}
        from repro.core.wal import import_legacy_jsonl
        out = {}
        for fn in sorted(os.listdir(self._persist_dir)):
            path = os.path.join(self._persist_dir, fn)
            if os.path.isdir(path):
                out[fn] = self.store(fn).recover()
        for fn in sorted(os.listdir(self._persist_dir)):
            if fn.endswith(".jsonl"):
                name = fn[:-len(".jsonl")]
                stats = import_legacy_jsonl(
                    os.path.join(self._persist_dir, fn), self.store(name))
                out.setdefault(name, {})["legacy_import"] = stats
        return out

    # the modern name; load_persisted is kept for API continuity
    recover = load_persisted

    def snapshot(self, db: Optional[str] = None) -> dict:
        """Snapshot + compact one database (or all): capture live column
        stores + rollup state, then drop every WAL segment the snapshot
        covers.  Returns per-database snapshot stats."""
        if not self._persist_dir:
            return {}
        names = [db] if db is not None else self.databases()
        return {name: self.store(name).snapshot() for name in names}

    def persistence_stats(self) -> dict:
        """Per-database WAL/snapshot stats (httpd ``/meta`` surface)."""
        if not self._persist_dir:
            return {"enabled": False}
        with self._lock:
            stores = dict(self._stores)
        return {"enabled": True, "fsync": self._fsync,
                "persist_dir": self._persist_dir,
                "databases": {name: s.stats()
                              for name, s in sorted(stores.items())}}

    def enforce_retention(self, max_age_ns: Optional[int] = None,
                          max_points_per_series: Optional[int] = None,
                          rollup_max_age_ns: Optional[int] = None,
                          db: Optional[str] = None) -> dict:
        """Apply retention to one database (or all).  With persistence
        enabled this also drops whole expired WAL segments (compacting
        through a snapshot first, so rollup windows survive recovery
        exactly like they survive in-memory retention); with the cold
        tier (``cold=True``) expired raw prefixes are *sealed* into
        compressed chunks instead of dropped.  Returns per-database
        retention reports (dropped/sealed counts) — never silent."""
        names = [db] if db is not None else self.databases()
        out = {}
        for name in names:
            store = self.store(name)
            if store is None:
                out[name] = self.db(name).enforce_retention(
                    max_age_ns, max_points_per_series, rollup_max_age_ns)
            else:
                out[name] = store.enforce_retention(
                    max_age_ns, max_points_per_series, rollup_max_age_ns)
        return out

    def close(self):
        """Seal and flush every WAL (no final snapshot: recovery replays)."""
        with self._lock:
            stores = list(self._stores.values())
        for s in stores:
            s.close()
