"""Embedded time-series database — the LMS DB back-end (paper §III.C).

The paper uses InfluxDB; an air-gapped TPU pod slice gets an embedded
equivalent with the properties the paper relies on:

* floats *and* strings as input values (metrics + events),
* tag-indexed storage with time-range / tag-filter / windowed-aggregation
  queries (what the dashboard agent and the analysis rules consume),
* multiple named databases (global + per-user/per-job duplication, §III.B),
* a retention policy to keep the generated data volume under control (§II),
* optional write-ahead persistence (JSONL) so dashboards survive restarts.

Thread-safe: the router may write from the training thread while the HTTP
endpoint and analyzers read concurrently.
"""

from __future__ import annotations

import bisect
import json
import os
import threading
from collections import defaultdict
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from repro.core.line_protocol import Point, now_ns


@dataclass
class Series:
    """One (measurement, tags) series: parallel time/values columns."""

    measurement: str
    tags: dict
    times: list
    values: dict                     # field name -> list


def _tags_key(tags: dict) -> tuple:
    return tuple(sorted(tags.items()))


class Database:
    """One named database: measurement -> {tags_key -> _SeriesStore}."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.RLock()
        self._meas: dict = defaultdict(dict)     # meas -> tags_key -> store
        self._count = 0

    # -- write --------------------------------------------------------------

    def write(self, points: Iterable[Point]):
        with self._lock:
            for p in points:
                key = _tags_key(p.tags)
                store = self._meas[p.measurement].get(key)
                if store is None:
                    store = _SeriesStore(dict(p.tags))
                    self._meas[p.measurement][key] = store
                store.append(p.timestamp if p.timestamp is not None
                             else now_ns(), p.fields)
                self._count += 1

    # -- introspection -------------------------------------------------------

    def measurements(self) -> list:
        with self._lock:
            return sorted(self._meas)

    def field_keys(self, measurement: str) -> list:
        with self._lock:
            keys = set()
            for store in self._meas.get(measurement, {}).values():
                keys.update(store.values)
            return sorted(keys)

    def tag_values(self, measurement: str, tag: str) -> list:
        with self._lock:
            vals = {store.tags.get(tag)
                    for store in self._meas.get(measurement, {}).values()}
            return sorted(v for v in vals if v is not None)

    def point_count(self) -> int:
        with self._lock:
            return self._count

    # -- query ---------------------------------------------------------------

    def select(self, measurement: str, fields: Optional[list] = None,
               tags: Optional[dict] = None, t_min: Optional[int] = None,
               t_max: Optional[int] = None) -> list:
        """Return matching Series (copies, safe to use lock-free)."""
        with self._lock:
            out = []
            for store in self._meas.get(measurement, {}).values():
                if tags and any(store.tags.get(k) != str(v)
                                for k, v in tags.items()):
                    continue
                s = store.slice(t_min, t_max, fields)
                if s is not None:
                    out.append(Series(measurement, dict(store.tags),
                                      s[0], s[1]))
            return out

    def aggregate(self, measurement: str, field: str, *, agg: str = "mean",
                  tags: Optional[dict] = None, t_min: Optional[int] = None,
                  t_max: Optional[int] = None,
                  group_by_tag: Optional[str] = None,
                  window_ns: Optional[int] = None):
        """InfluxDB-style aggregation.

        Without ``window_ns``: scalar per group (dict group -> value).
        With ``window_ns``: dict group -> (window_starts, values).
        agg: mean | max | min | sum | count | last.
        """
        series = self.select(measurement, [field], tags, t_min, t_max)
        groups: dict = defaultdict(lambda: ([], []))
        for s in series:
            g = s.tags.get(group_by_tag, "") if group_by_tag else ""
            ts, vs = groups[g]
            ts.extend(s.times)
            vs.extend(s.values.get(field, []))
        out = {}
        for g, (ts, vs) in groups.items():
            pairs = sorted((t, v) for t, v in zip(ts, vs)
                           if isinstance(v, (int, float)) and
                           not isinstance(v, bool))
            if not pairs:
                continue
            if window_ns is None:
                out[g] = _agg([v for _, v in pairs], agg)
            else:
                w0 = pairs[0][0] - pairs[0][0] % window_ns
                wins: dict = defaultdict(list)
                for t, v in pairs:
                    wins[(t - w0) // window_ns].append(v)
                starts = sorted(wins)
                out[g] = ([w0 + i * window_ns for i in starts],
                          [_agg(wins[i], agg) for i in starts])
        return out

    # -- retention ------------------------------------------------------------

    def enforce_retention(self, max_age_ns: Optional[int] = None,
                          max_points_per_series: Optional[int] = None):
        """Drop old data (paper §II: keep data volume under control)."""
        cutoff = now_ns() - max_age_ns if max_age_ns else None
        with self._lock:
            for stores in self._meas.values():
                for store in stores.values():
                    store.trim(cutoff, max_points_per_series)


def _agg(vals: list, agg: str):
    if agg == "mean":
        return sum(vals) / len(vals)
    if agg == "max":
        return max(vals)
    if agg == "min":
        return min(vals)
    if agg == "sum":
        return sum(vals)
    if agg == "count":
        return float(len(vals))
    if agg == "last":
        return vals[-1]
    raise ValueError(f"unknown agg {agg!r}")


class _SeriesStore:
    """Columnar store for one series; times kept sorted."""

    __slots__ = ("tags", "times", "values")

    def __init__(self, tags: dict):
        self.tags = tags
        self.times: list = []
        self.values: dict = defaultdict(list)

    def append(self, ts: int, fields: dict):
        if self.times and ts < self.times[-1]:
            idx = bisect.bisect_right(self.times, ts)
            self.times.insert(idx, ts)
            for k in self.values:
                self.values[k].insert(idx, fields.get(k))
            for k, v in fields.items():
                if k not in self.values:
                    col = [None] * (len(self.times))
                    col[idx] = v
                    self.values[k] = col
            return
        self.times.append(ts)
        n = len(self.times)
        for k in set(self.values) | set(fields):
            col = self.values[k]
            while len(col) < n - 1:
                col.append(None)
            col.append(fields.get(k))

    def slice(self, t_min, t_max, fields):
        lo = bisect.bisect_left(self.times, t_min) if t_min else 0
        hi = bisect.bisect_right(self.times, t_max) if t_max \
            else len(self.times)
        if lo >= hi:
            return None
        names = fields if fields else list(self.values)
        vals = {k: self.values[k][lo:hi] for k in names if k in self.values}
        if not vals:
            return None
        return self.times[lo:hi], vals

    def trim(self, cutoff, max_points):
        lo = 0
        if cutoff is not None:
            lo = bisect.bisect_left(self.times, cutoff)
        if max_points is not None:
            lo = max(lo, len(self.times) - max_points)
        if lo > 0:
            self.times = self.times[lo:]
            self.values = {k: v[lo:] for k, v in self.values.items()}


class TSDBServer:
    """Named-database manager (the "database back-end" box in Fig. 1)."""

    def __init__(self, persist_dir: Optional[str] = None):
        self._dbs: dict = {}
        self._lock = threading.RLock()
        self._persist_dir = persist_dir
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)

    def db(self, name: str = "global") -> Database:
        with self._lock:
            if name not in self._dbs:
                self._dbs[name] = Database(name)
            return self._dbs[name]

    def databases(self) -> list:
        with self._lock:
            return sorted(self._dbs)

    def write(self, points: Iterable[Point], db: str = "global"):
        points = list(points)
        self.db(db).write(points)
        if self._persist_dir:
            path = os.path.join(self._persist_dir, f"{db}.jsonl")
            with open(path, "a") as f:
                for p in points:
                    f.write(json.dumps({
                        "m": p.measurement, "t": p.tags, "f": p.fields,
                        "ts": p.timestamp}) + "\n")

    def load_persisted(self):
        if not self._persist_dir:
            return
        for fn in os.listdir(self._persist_dir):
            if not fn.endswith(".jsonl"):
                continue
            name = fn[:-len(".jsonl")]
            with open(os.path.join(self._persist_dir, fn)) as f:
                pts = []
                for line in f:
                    d = json.loads(line)
                    pts.append(Point(d["m"], d["t"], d["f"], d["ts"]))
            self.db(name).write(pts)
