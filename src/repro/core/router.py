"""Metrics router — the central LMS component (paper §III.B).

Responsibilities (all from the paper):

* mimic the InfluxDB write interface plus an endpoint for job start/end
  signals (the HTTP face lives in ``repro.core.httpd``; this class is the
  in-process engine both faces share);
* keep a *tag store* keyed by the mandatory ``hostname`` tag and enrich every
  incoming metric with the owning job's tags;
* forward enriched points to the database back-end, duplicating them into
  per-user databases when configured;
* store job signals as events so the dashboards can render annotations;
* publish metrics + meta information to attached subscribers — the ZeroMQ
  fan-out of the paper becomes an in-process subscriber registry with the
  same semantics (stream analyzers, aggregators).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Union

from repro.core.jobs import JobRegistry
from repro.core.line_protocol import (Point, decode_batch, encode_point,
                                      now_ns)
from repro.core.tsdb import TSDBServer


@dataclass
class RouterStats:
    """Monotonic ingest counters.

    Mutated only through :meth:`add` (plain ``+=`` on a shared dataclass
    is a read-modify-write race under concurrent batched writers); read
    via :meth:`snapshot` — both take the internal lock, so a snapshot is
    a consistent cut (e.g. ``points_in == points_out + dropped_no_host``
    holds between batches).
    """

    points_in: int = 0
    points_out: int = 0
    signals: int = 0
    parse_errors: int = 0
    dropped_no_host: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **deltas: int):
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> dict:
        with self._lock:
            return {"points_in": self.points_in,
                    "points_out": self.points_out,
                    "signals": self.signals,
                    "parse_errors": self.parse_errors,
                    "dropped_no_host": self.dropped_no_host}


def _safe_db_name(raw: str) -> str:
    """Remote-supplied usernames/jobids become database names, and a
    persisted database name becomes a directory — a '/' (or a bare
    '.'/'..') in one would make the durable store reject every write to
    that scope forever.  Map the hostile characters instead of failing
    per-write."""
    name = raw.replace("/", "_").replace("\\", "_")
    return name if name not in ("", ".", "..") else name.replace(".", "_")


class MetricsRouter:
    """Tag-enriching, duplicating, publishing metrics router."""

    HOST_TAG = "hostname"

    def __init__(self, backend: TSDBServer, *, global_db: str = "global",
                 per_user_db: bool = False, per_job_db: bool = False,
                 require_host_tag: bool = True):
        self.backend = backend
        self.jobs = JobRegistry()
        self.global_db = global_db
        self.per_user_db = per_user_db
        self.per_job_db = per_job_db
        self.require_host_tag = require_host_tag
        self.stats = RouterStats()
        # the continuous analysis engine serving this router's data, when
        # one is attached (MonitoringStack wires it); the HTTP face uses it
        # for live job reports and engine stats
        self.analysis = None
        self._subs: list = []
        self._lock = threading.RLock()

    # -- pub-sub (ZeroMQ analogue) -------------------------------------------

    def subscribe(self, fn: Callable) -> Callable:
        """fn(kind, payload): kind in {"points", "job_start", "job_end"}."""
        with self._lock:
            self._subs.append(fn)
        return fn

    def unsubscribe(self, fn: Callable):
        with self._lock:
            if fn in self._subs:
                self._subs.remove(fn)

    def _publish(self, kind: str, payload):
        with self._lock:
            subs = list(self._subs)
        for fn in subs:
            try:
                fn(kind, payload)
            except Exception:       # a broken analyzer must not stall ingest
                pass

    # -- job signals -----------------------------------------------------------

    def job_start(self, job_id: str, user: str, hosts: list,
                  tags: Optional[dict] = None, ts: Optional[int] = None):
        job = self.jobs.start(job_id, user, hosts, tags, ts)
        self.stats.add(signals=1)
        # signals are stored as events -> dashboard annotations (paper §III.B)
        self.backend.write([Point(
            "job_event", {"jobid": job_id, "username": user},
            {"event": "start", "hosts": ",".join(hosts)},
            job.start_ns)], self.global_db)
        self._publish("job_start", job)
        return job

    def job_end(self, job_id: str, ts: Optional[int] = None):
        job = self.jobs.end(job_id, ts)
        self.stats.add(signals=1)
        if job is not None:
            self.backend.write([Point(
                "job_event", {"jobid": job_id, "username": job.user},
                {"event": "end"}, job.end_ns)], self.global_db)
            self._publish("job_end", job)
        return job

    # -- ingest ------------------------------------------------------------------

    def write_lines(self, data: str):
        """HTTP body (line protocol, possibly batched) -> route."""
        try:
            points = decode_batch(data)
        except Exception:
            self.stats.add(parse_errors=1)
            raise
        self.write(points)
        return len(points)

    def write(self, points: Union[Point, Iterable[Point]]):
        if isinstance(points, Point):
            points = [points]
        elif not isinstance(points, (list, tuple)):
            points = list(points)
        # batch fast path: the tag-store lookup (a lock per call) is done
        # once per distinct host in the batch, not once per point
        host_tags: dict = {}
        enriched = []
        dropped = 0
        for p in points:
            host = p.tags.get(self.HOST_TAG)
            if host is None and self.require_host_tag:
                dropped += 1
                continue
            if p.timestamp is None:
                p = Point(p.measurement, p.tags, p.fields, now_ns())
            if host is None:
                job_tags = {}
            else:
                job_tags = host_tags.get(host)
                if job_tags is None:
                    job_tags = host_tags[host] = self.jobs.tags_for_host(host)
            enriched.append(p.with_tags(job_tags))
        self.stats.add(points_in=len(points), dropped_no_host=dropped,
                       points_out=len(enriched))
        if not enriched:
            return
        # the backend groups the batch per series — and, for a sharded
        # database, per shard — so this call contends only on the shards
        # the batch's hosts actually map to
        self.backend.write(enriched, self.global_db)
        # duplication into user/job scoped databases (paper §III.B)
        if self.per_user_db or self.per_job_db:
            by_db: dict = {}
            for p in enriched:
                if self.per_user_db and "username" in p.tags:
                    by_db.setdefault(
                        "user_" + _safe_db_name(p.tags["username"]),
                        []).append(p)
                if self.per_job_db and "jobid" in p.tags:
                    by_db.setdefault(
                        "job_" + _safe_db_name(p.tags["jobid"]),
                        []).append(p)
            for db, pts in by_db.items():
                self.backend.write(pts, db)
        self._publish("points", enriched)
