"""Metrics router — the central LMS component (paper §III.B).

Responsibilities (all from the paper):

* mimic the InfluxDB write interface plus an endpoint for job start/end
  signals (the HTTP face lives in ``repro.core.httpd``; this class is the
  in-process engine both faces share);
* keep a *tag store* keyed by the mandatory ``hostname`` tag and enrich every
  incoming metric with the owning job's tags;
* forward enriched points to the database back-end, duplicating them into
  per-user databases when configured;
* store job signals as events so the dashboards can render annotations;
* publish metrics + meta information to attached subscribers — the ZeroMQ
  fan-out of the paper becomes an in-process subscriber registry with the
  same semantics (stream analyzers, aggregators).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Union

from repro.core.jobs import JobRegistry
from repro.core.line_protocol import (Point, decode_batch_errors,
                                      encode_point, now_ns)
from repro.core.tsdb import Database, TSDBServer, _tags_key


@dataclass
class RouterStats:
    """Monotonic ingest counters.

    Mutated only through :meth:`add` (plain ``+=`` on a shared dataclass
    is a read-modify-write race under concurrent batched writers); read
    via :meth:`snapshot` — both take the internal lock, so a snapshot is
    a consistent cut (e.g. ``points_in == points_out + dropped_no_host``
    holds between batches).
    """

    points_in: int = 0
    points_out: int = 0
    signals: int = 0
    parse_errors: int = 0
    dropped_no_host: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **deltas: int):
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> dict:
        with self._lock:
            return {"points_in": self.points_in,
                    "points_out": self.points_out,
                    "signals": self.signals,
                    "parse_errors": self.parse_errors,
                    "dropped_no_host": self.dropped_no_host}


def _safe_db_name(raw: str) -> str:
    """Remote-supplied usernames/jobids become database names, and a
    persisted database name becomes a directory — a '/' (or a bare
    '.'/'..') in one would make the durable store reject every write to
    that scope forever.  Map the hostile characters instead of failing
    per-write."""
    name = raw.replace("/", "_").replace("\\", "_")
    return name if name not in ("", ".", "..") else name.replace(".", "_")


class MetricsRouter:
    """Tag-enriching, duplicating, publishing metrics router."""

    HOST_TAG = "hostname"

    def __init__(self, backend: TSDBServer, *, global_db: str = "global",
                 per_user_db: bool = False, per_job_db: bool = False,
                 require_host_tag: bool = True):
        self.backend = backend
        self.jobs = JobRegistry()
        self.global_db = global_db
        self.per_user_db = per_user_db
        self.per_job_db = per_job_db
        self.require_host_tag = require_host_tag
        self.stats = RouterStats()
        # the continuous analysis engine serving this router's data, when
        # one is attached (MonitoringStack wires it); the HTTP face uses it
        # for live job reports and engine stats
        self.analysis = None
        # the binary ingest plane serving this router, when one is
        # attached (repro.core.ingest.IngestServer wires itself here);
        # the HTTP face reads its shed/queue counters (/meta?what=ingest)
        self.ingest = None
        self._subs: list = []
        self._lock = threading.RLock()

    # -- pub-sub (ZeroMQ analogue) -------------------------------------------

    def subscribe(self, fn: Callable) -> Callable:
        """fn(kind, payload): kind in {"points", "job_start", "job_end"}."""
        with self._lock:
            self._subs.append(fn)
        return fn

    def unsubscribe(self, fn: Callable):
        with self._lock:
            if fn in self._subs:
                self._subs.remove(fn)

    def _publish(self, kind: str, payload):
        with self._lock:
            subs = list(self._subs)
        for fn in subs:
            try:
                fn(kind, payload)
            except Exception:       # a broken analyzer must not stall ingest
                pass

    # -- job signals -----------------------------------------------------------

    def job_start(self, job_id: str, user: str, hosts: list,
                  tags: Optional[dict] = None, ts: Optional[int] = None):
        job = self.jobs.start(job_id, user, hosts, tags, ts)
        self.stats.add(signals=1)
        # signals are stored as events -> dashboard annotations (paper §III.B)
        self.backend.write([Point(
            "job_event", {"jobid": job_id, "username": user},
            {"event": "start", "hosts": ",".join(hosts)},
            job.start_ns)], self.global_db)
        self._publish("job_start", job)
        return job

    def job_end(self, job_id: str, ts: Optional[int] = None):
        job = self.jobs.end(job_id, ts)
        self.stats.add(signals=1)
        if job is not None:
            self.backend.write([Point(
                "job_event", {"jobid": job_id, "username": job.user},
                {"event": "end"}, job.end_ns)], self.global_db)
            self._publish("job_end", job)
        return job

    # -- ingest ------------------------------------------------------------------

    def write_lines(self, data: str) -> dict:
        """HTTP body (line protocol, possibly batched) -> route.

        Partial-write semantics: every line that parses is written; every
        malformed line becomes a per-line error record instead of
        aborting its siblings.  Returns ``{"written": n, "errors":
        [{"line": lineno, "error": msg}, ...]}`` — the ``/write``
        response body.
        """
        points, errors = decode_batch_errors(data)
        if errors:
            self.stats.add(parse_errors=len(errors))
        if points:
            self.write(points)
        return {"written": len(points), "errors": errors}

    def write(self, points: Union[Point, Iterable[Point]]):
        if isinstance(points, Point):
            points = [points]
        elif not isinstance(points, (list, tuple)):
            points = list(points)
        # batch fast path: the tag-store lookup (a lock per call) is done
        # once per distinct host in the batch, not once per point
        host_tags: dict = {}
        enriched = []
        dropped = 0
        for p in points:
            host = p.tags.get(self.HOST_TAG)
            if host is None and self.require_host_tag:
                dropped += 1
                continue
            if p.timestamp is None:
                p = Point(p.measurement, p.tags, p.fields, now_ns())
            if host is None:
                job_tags = {}
            else:
                job_tags = host_tags.get(host)
                if job_tags is None:
                    job_tags = host_tags[host] = self.jobs.tags_for_host(host)
            enriched.append(p.with_tags(job_tags))
        self.stats.add(points_in=len(points), dropped_no_host=dropped,
                       points_out=len(enriched))
        if not enriched:
            return
        # the backend groups the batch per series — and, for a sharded
        # database, per shard — so this call contends only on the shards
        # the batch's hosts actually map to
        self.backend.write(enriched, self.global_db)
        # duplication into user/job scoped databases (paper §III.B)
        if self.per_user_db or self.per_job_db:
            by_db: dict = {}
            for p in enriched:
                if self.per_user_db and "username" in p.tags:
                    by_db.setdefault(
                        "user_" + _safe_db_name(p.tags["username"]),
                        []).append(p)
                if self.per_job_db and "jobid" in p.tags:
                    by_db.setdefault(
                        "job_" + _safe_db_name(p.tags["jobid"]),
                        []).append(p)
            for db, pts in by_db.items():
                self.backend.write(pts, db)
        self._publish("points", enriched)

    # -- columnar ingest (the binary plane, repro.core.ingest) ----------------

    def write_entries(self, entries: Iterable) -> int:
        """Columnar twin of :meth:`write`: route ``[(measurement, tags,
        times, {field: column}), ...]`` series entries (the binary wire
        form, == the WAL record form) without ever materializing
        per-point objects.

        Enrichment (job-tag merge, host-tag requirement) happens once per
        *series*, not per point; the enriched columns go to the backend
        through ``write_columns`` — and, on a persisted backend, into the
        WAL re-encoded with the same codec the wire used.  Returns the
        number of points routed.
        """
        host_tags: dict = {}
        by_cols: dict = {}
        tags_of: dict = {}
        n_in = n_out = dropped = 0
        for m, tags, times, cols in entries:
            n = len(times)
            if not n:
                continue
            n_in += n
            host = tags.get(self.HOST_TAG)
            if host is None and self.require_host_tag:
                dropped += n
                continue
            if host is None:
                job_tags = {}
            else:
                job_tags = host_tags.get(host)
                if job_tags is None:
                    job_tags = host_tags[host] = self.jobs.tags_for_host(host)
            if job_tags:
                tags = dict(tags)
                tags.update(job_tags)
            if any(times[i] > times[i + 1] for i in range(n - 1)):
                # defensive: write_columns requires ascending times per
                # series; a misbehaving client pays a sort, not corruption
                times, cols = Database.transpose_items(
                    [(t, {k: c[i] for k, c in cols.items()
                          if c[i] is not None})
                     for i, t in enumerate(times)])
            key = (m, _tags_key(tags))
            if key in by_cols:      # same series split across entries
                old_t, old_c = by_cols[key]
                by_cols[key] = Database.transpose_items(
                    [(t, {k: c[i] for k, c in old_c.items()
                          if c[i] is not None})
                     for i, t in enumerate(old_t)] +
                    [(t, {k: c[i] for k, c in cols.items()
                          if c[i] is not None})
                     for i, t in enumerate(times)])
            else:
                by_cols[key] = (times, cols)
                tags_of[key] = tags
            n_out += n
        self.stats.add(points_in=n_in, dropped_no_host=dropped,
                       points_out=n_out)
        if not by_cols:
            return 0
        self.backend.write_columns(by_cols, tags_of, self.global_db)
        if self.per_user_db or self.per_job_db:
            # duplication is per *series* here: a series' enriched tags
            # decide its scoped databases once, columns are shared
            by_db: dict = {}
            for key, tc in by_cols.items():
                tags = tags_of[key]
                scopes = []
                if self.per_user_db and "username" in tags:
                    scopes.append("user_" + _safe_db_name(tags["username"]))
                if self.per_job_db and "jobid" in tags:
                    scopes.append("job_" + _safe_db_name(tags["jobid"]))
                for scope in scopes:
                    cols_map, tmap = by_db.setdefault(scope, ({}, {}))
                    cols_map[key] = tc
                    tmap[key] = tags
            for db, (cols_map, tmap) in by_db.items():
                self.backend.write_columns(cols_map, tmap, db)
        self._publish("points", _LazyPoints(by_cols, tags_of))
        return n_out


class _LazyPoints:
    """Deferred Point materialization for the columnar publish path.

    Subscribers that only mark state dirty (``AnalysisEngine``) never
    iterate the payload, so the binary hot path pays nothing; a
    subscriber that really consumes points (``StreamAnalyzer``)
    materializes them on first iteration and the rows are cached for the
    next subscriber.
    """

    __slots__ = ("_by_cols", "_tags_of", "_pts")

    def __init__(self, by_cols: dict, tags_of: dict):
        self._by_cols = by_cols
        self._tags_of = tags_of
        self._pts = None

    def _materialize(self) -> list:
        if self._pts is None:
            pts = []
            for (m, key), (times, cols) in self._by_cols.items():
                tags = self._tags_of[key]
                for i, t in enumerate(times):
                    pts.append(Point(
                        m, tags,
                        {k: c[i] for k, c in cols.items()
                         if c[i] is not None}, t))
            self._pts = pts
        return self._pts

    def __iter__(self):
        return iter(self._materialize())

    def __len__(self):
        return sum(len(times) for times, _ in self._by_cols.values())
