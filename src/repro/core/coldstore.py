"""Compressed columnar cold tier — sealed, immutable, lossless history.

The paper's end goal is "a statistical foundation about application
specific system usage", which needs months of cheap raw history; MPCDF's
job-archive design keeps a compressed per-job archive for exactly this
reason.  Before this module, ``enforce_retention`` *dropped* expired raw
columns and only the rollup summaries survived.  With a cold store
configured (``TSDBServer(cold=True)``), the retention sweep instead
*seals* the expired column prefixes into time-partitioned immutable
chunks, so raw history and rollups both survive — and the query layer
answers byte-identically whether the points are resident or sealed.
Quantile queries too: a cold scan rebuilds per-window aggregates through
``RollupConfig.new_agg``, so p50/p95/p99 over sealed history carry the
same sketches (and the same rank-error bound) as the hot rollup path.

Chunk file format (``cold/chunk-<seq>.chk``)::

    LMSCOLD1                                    8-byte magic
    <u32 len, u32 crc32> series-block           one per sealed series
    <u32 len, u32 crc32> index-block            always last
    <u64 index_off> LMSCEND1                    12-byte trailer

Block framing reuses the WAL conventions (``repro.core.wal``): records
are length-prefixed and CRC-checked, so a flipped bit is *detected* and
the block is skipped with a warning — corruption can hide data (counted
in :meth:`ColdStore.stats`), never return wrong data.  A torn trailer
falls back to a full frame scan; an unrecoverable index skips the whole
chunk.

Series blocks are Gorilla-style compressed columns:

* **timestamps** — delta-of-delta, zigzag + LEB128 varint (regular
  sampling intervals cost ~1 byte/point; out-of-order and duplicate
  timestamps are just negative/zero deltas, still exact);
* **float64 columns** — XOR bit-packing with leading/trailing-zero
  windows (the Facebook Gorilla scheme).  The XOR acts on the raw IEEE
  bits, so NaN payloads, ``±inf`` and ``-0.0`` round-trip exactly;
* **int columns** — delta-of-delta varints (arbitrary-precision, so
  int64 overflow is impossible by construction);
* columns with ``None`` holes add a presence bitmap in front of the
  packed non-``None`` values; mixed/bool/str columns fall back to JSON
  in the block meta — exact types, same rule as the WAL codec.

The per-chunk index (one JSON block) maps each series to its block
offset, ``t_min``/``t_max``, count and field names, so queries skip
whole chunks/blocks by time range without decoding them.

**Seal protocol** (driven by ``repro.core.wal.DurableStore``): under the
snapshot write barrier, the expired prefixes are captured, the chunk is
written with the WAL durability discipline (tmp + fsync + rename +
directory fsync), the hot prefixes are trimmed *atomically* with the
chunk becoming query-visible (per shard, under that shard's lock), and
the post-trim snapshot commits the chunk by recording
``cold_committed = <max chunk seq>``.  The snapshot rename is the commit
point: a crash before it leaves an orphan chunk that recovery deletes
(the raw points are still in the old snapshot + WAL); a crash after it
leaves the chunk live and the raw points gone from the hot tier — never
both, never neither.

Sharding: one :class:`ColdStore` per database directory; each shard's
``Database`` gets a :class:`ColdView` filtering sealed series by the
*current* shard hash (``repro.core.shard.shard_index``), so a chunk
written under one shard layout reads correctly under another and every
sealed series is served by exactly one shard.
"""

from __future__ import annotations

import bisect
import json
import logging
import os
import struct
import threading
import zlib
from collections import OrderedDict
from typing import Iterable, Optional

from repro.core.shard import shard_index

log = logging.getLogger("repro.core.coldstore")

CHUNK_MAGIC = b"LMSCOLD1"
CHUNK_END_MAGIC = b"LMSCEND1"
_HEADER = struct.Struct("<II")          # payload length, crc32(payload)
_TRAILER = struct.Struct("<Q8s")        # index block offset, end magic
_BLOB_LEN = struct.Struct("<I")
_F64 = struct.Struct("<d")
_U64 = struct.Struct("<Q")

_SERIES_TAG = 0x53                      # b"S"
_INDEX_TAG = 0x49                       # b"I"


def _chunk_name(seq: int) -> str:
    return f"chunk-{seq:08d}.chk"


def _parse_chunk_seq(fn: str) -> Optional[int]:
    if not fn.startswith("chunk-") or not fn.endswith(".chk"):
        return None
    try:
        return int(fn[len("chunk-"):-len(".chk")])
    except ValueError:
        return None


# --------------------------------------------------------------------------
# Integer codec: delta-of-delta, zigzag + LEB128 varint
# --------------------------------------------------------------------------


def _zigzag(n: int) -> int:
    return (n << 1) if n >= 0 else ((-n << 1) - 1)


def _unzigzag(z: int) -> int:
    return (z >> 1) if not (z & 1) else -((z + 1) >> 1)


def _write_uvarint(out: bytearray, z: int):
    while True:
        b = z & 0x7F
        z >>= 7
        if z:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def encode_ints(vals: list) -> bytes:
    """Delta-of-delta varint encoding of an int column (timestamps or
    integer values).  Python-int arithmetic: exact for *any* magnitude,
    and counter resets / out-of-order values are just negative deltas."""
    out = bytearray()
    prev = 0
    prev_d = 0
    for i, v in enumerate(vals):
        if i == 0:
            _write_uvarint(out, _zigzag(v))
            prev = v
        else:
            d = v - prev
            _write_uvarint(out, _zigzag(d - prev_d))
            prev_d = d
            prev = v
    return bytes(out)


def decode_ints(blob: bytes, n: int) -> list:
    out = []
    pos = 0
    prev = 0
    prev_d = 0
    for i in range(n):
        z = 0
        shift = 0
        while True:
            if pos >= len(blob):
                raise ValueError("truncated int column")
            b = blob[pos]
            pos += 1
            z |= (b & 0x7F) << shift
            shift += 7
            if not (b & 0x80):
                break
        v = _unzigzag(z)
        if i == 0:
            prev = v
        else:
            prev_d += v
            prev += prev_d
        out.append(prev)
    if pos != len(blob):
        raise ValueError("trailing bytes in int column")
    return out


# --------------------------------------------------------------------------
# Float codec: Gorilla XOR bit-packing
# --------------------------------------------------------------------------


class _BitWriter:
    __slots__ = ("_acc", "_nbits", "_out")

    def __init__(self):
        self._acc = 0
        self._nbits = 0
        self._out = bytearray()

    def write(self, value: int, bits: int):
        self._acc = (self._acc << bits) | (value & ((1 << bits) - 1))
        self._nbits += bits
        while self._nbits >= 8:
            self._nbits -= 8
            self._out.append((self._acc >> self._nbits) & 0xFF)
        self._acc &= (1 << self._nbits) - 1

    def getvalue(self) -> bytes:
        if self._nbits:
            return bytes(self._out) + \
                bytes(((self._acc << (8 - self._nbits)) & 0xFF,))
        return bytes(self._out)


class _BitReader:
    __slots__ = ("_data", "_byte", "_bit")

    def __init__(self, data: bytes):
        self._data = data
        self._byte = 0
        self._bit = 0

    def read(self, nbits: int) -> int:
        out = 0
        data = self._data
        byte_i, bit_i = self._byte, self._bit
        while nbits > 0:
            if byte_i >= len(data):
                raise ValueError("truncated float column")
            avail = 8 - bit_i
            take = avail if avail < nbits else nbits
            out = (out << take) | \
                ((data[byte_i] >> (avail - take)) & ((1 << take) - 1))
            bit_i += take
            nbits -= take
            if bit_i == 8:
                byte_i += 1
                bit_i = 0
        self._byte, self._bit = byte_i, bit_i
        return out


def encode_floats(vals: list) -> bytes:
    """Gorilla XOR compression of a float64 column.  Operates on the raw
    IEEE-754 bits (identical value -> 1 bit; small mantissa drift -> the
    meaningful XOR window), so NaN payloads, ``±inf`` and ``-0.0`` all
    round-trip bit-exactly."""
    bw = _BitWriter()
    prev = _U64.unpack(_F64.pack(vals[0]))[0]
    bw.write(prev, 64)
    lead = -1
    trail = 0
    for v in vals[1:]:
        cur = _U64.unpack(_F64.pack(v))[0]
        x = prev ^ cur
        if x == 0:
            bw.write(0, 1)
        else:
            bw.write(1, 1)
            lz = 64 - x.bit_length()
            if lz > 31:
                lz = 31
            tz = (x & -x).bit_length() - 1
            if lead >= 0 and lz >= lead and tz >= trail:
                # reuse the previous meaningful-bit window
                bw.write(0, 1)
                bw.write(x >> trail, 64 - lead - trail)
            else:
                lead, trail = lz, tz
                mbits = 64 - lead - trail
                bw.write(1, 1)
                bw.write(lead, 5)
                bw.write(mbits - 1, 6)
                bw.write(x >> trail, mbits)
        prev = cur
    return bw.getvalue()


def decode_floats(blob: bytes, n: int) -> list:
    if n == 0:
        return []
    br = _BitReader(blob)
    prev = br.read(64)
    out = [_F64.unpack(_U64.pack(prev))[0]]
    lead = 0
    trail = 64
    for _ in range(n - 1):
        if br.read(1):
            if br.read(1):
                lead = br.read(5)
                mbits = br.read(6) + 1
                trail = 64 - lead - mbits
                if trail < 0:
                    raise ValueError("invalid float block window")
            prev ^= br.read(64 - lead - trail) << trail
        out.append(_F64.unpack(_U64.pack(prev))[0])
    return out


# --------------------------------------------------------------------------
# Column codec selection (mirrors the WAL ``_pack_numeric`` type rules:
# exact type identity, bools/None/mixed fall back to JSON)
# --------------------------------------------------------------------------


_FLOAT_COL = frozenset((float,))
_INT_COL = frozenset((int,))
_NONE = type(None)


def _pack_bitmap(col: list) -> bytes:
    out = bytearray((len(col) + 7) // 8)
    for i, v in enumerate(col):
        if v is not None:
            out[i >> 3] |= 0x80 >> (i & 7)
    return bytes(out)


def _encode_column(col: list):
    """``(code, blobs)``: ``g``/``d`` dense float/int, ``gh``/``dh`` with
    a presence bitmap for ``None`` holes, or ``("j", None)`` for the JSON
    fallback (mixed types, bools, strings)."""
    kinds = set(map(type, col))
    if kinds == _FLOAT_COL:
        return "g", [encode_floats(col)]
    if kinds == _INT_COL:
        return "d", [encode_ints(col)]
    if _NONE in kinds and len(kinds) == 2:
        present = [v for v in col if v is not None]
        dense = kinds - {_NONE}
        if present and dense == _FLOAT_COL:
            return "gh", [_pack_bitmap(col), encode_floats(present)]
        if present and dense == _INT_COL:
            return "dh", [_pack_bitmap(col), encode_ints(present)]
    return "j", None


def _decode_column(code: str, blobs: list, n: int) -> list:
    if code == "g":
        return decode_floats(blobs[0], n)
    if code == "d":
        return decode_ints(blobs[0], n)
    bitmap, data = blobs
    if len(bitmap) != (n + 7) // 8:
        raise ValueError("bad presence bitmap length")
    present = sum(bin(b).count("1") for b in bitmap)
    vals = decode_floats(data, present) if code == "gh" \
        else decode_ints(data, present)
    out = []
    it = iter(vals)
    for i in range(n):
        if bitmap[i >> 3] & (0x80 >> (i & 7)):
            out.append(next(it))
        else:
            out.append(None)
    return out


# --------------------------------------------------------------------------
# Series block <-> bytes
# --------------------------------------------------------------------------


def encode_series_block(measurement: str, tags: dict, times: list,
                        cols: dict) -> bytes:
    """One sealed series -> block payload: tag byte + JSON meta + length-
    prefixed codec blobs (timestamps first, then columns in meta order)."""
    colspec = []
    blobs = [encode_ints(times)]
    for k, col in cols.items():
        code, cblobs = _encode_column(col)
        if code == "j":
            colspec.append([k, "j", col])
        else:
            colspec.append([k, code])
            blobs.extend(cblobs)
    meta = json.dumps([measurement, tags, len(times), colspec],
                      separators=(",", ":")).encode()
    parts = [bytes((_SERIES_TAG,)), _BLOB_LEN.pack(len(meta)), meta]
    for b in blobs:
        parts.append(_BLOB_LEN.pack(len(b)))
        parts.append(b)
    return b"".join(parts)


def decode_series_block(payload: bytes):
    """Block payload -> ``(measurement, tags, times, cols)``.  Raises
    ``ValueError`` on any structural damage (the caller treats the block
    as unreadable — skipped and counted, never wrong data)."""
    if not payload or payload[0] != _SERIES_TAG:
        raise ValueError("not a series block")
    (mlen,) = _BLOB_LEN.unpack_from(payload, 1)
    off = 1 + _BLOB_LEN.size + mlen
    measurement, tags, n, colspec = json.loads(payload[1 + _BLOB_LEN.size:off])

    def read_blob():
        nonlocal off
        (ln,) = _BLOB_LEN.unpack_from(payload, off)
        off += _BLOB_LEN.size
        if off + ln > len(payload):
            raise ValueError("truncated blob")
        b = payload[off:off + ln]
        off += ln
        return b

    times = decode_ints(read_blob(), n)
    cols = {}
    for spec in colspec:
        if spec[1] == "j":
            col = spec[2]
            if len(col) != n:
                raise ValueError("bad JSON column length")
            cols[spec[0]] = col
        elif spec[1] in ("g", "d"):
            cols[spec[0]] = _decode_column(spec[1], [read_blob()], n)
        elif spec[1] in ("gh", "dh"):
            bitmap = read_blob()
            cols[spec[0]] = _decode_column(spec[1], [bitmap, read_blob()], n)
        else:
            raise ValueError(f"unknown column code {spec[1]!r}")
    return measurement, tags, times, cols


def _frame(payload: bytes) -> bytes:
    return _HEADER.pack(len(payload), zlib.crc32(payload)) + payload


# --------------------------------------------------------------------------
# Chunk index
# --------------------------------------------------------------------------


class _ChunkSeries:
    """Index entry for one series block inside a chunk."""

    __slots__ = ("m", "tags", "tags_key", "off", "t_min", "t_max", "n",
                 "fields")

    def __init__(self, m, tags, off, t_min, t_max, n, fields):
        self.m = m
        self.tags = tags
        self.tags_key = tuple(sorted(tags.items()))
        self.off = off
        self.t_min = t_min
        self.t_max = t_max
        self.n = n
        self.fields = fields


class _Chunk:
    __slots__ = ("seq", "path", "series", "points", "nbytes", "raw_bytes",
                 "by_meas")

    def __init__(self, seq, path, series, nbytes):
        self.seq = seq
        self.path = path
        self.series = series
        self.points = sum(e.n for e in series)
        self.nbytes = nbytes
        # what the same rows cost as raw in-memory columns: one 8-byte
        # timestamp plus one 8-byte slot per field column
        self.raw_bytes = sum(8 * e.n * (1 + len(e.fields)) for e in series)
        self.by_meas: dict = {}
        for e in series:
            self.by_meas.setdefault(e.m, []).append(e)


# --------------------------------------------------------------------------
# The store
# --------------------------------------------------------------------------


def _fsync_dir(path: str):
    # same durability helper as repro.core.wal (duplicated to keep this
    # module importable below wal in the layer stack)
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class ColdStore:
    """Immutable chunk archive for one database directory.

    Thread-safety: chunk files are immutable once visible; the in-memory
    index and decoded-block cache are guarded by one lock.  Visibility is
    *per view* (:meth:`make_view`), so a seal can flip each shard's view
    atomically with that shard's hot-prefix trim.
    """

    def __init__(self, directory: str, *, cache_blocks: int = 128):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self._lock = threading.RLock()
        self._chunks: dict = {}             # seq -> _Chunk
        self._views: list = []
        self._cache: OrderedDict = OrderedDict()
        self._cache_max = int(cache_blocks)
        self.corrupt_blocks = 0
        self.skipped_chunks = 0
        self.sealed_points = 0              # points appended this process
        for fn in sorted(os.listdir(directory)):
            seq = _parse_chunk_seq(fn)
            if seq is None:
                continue
            chunk = self._load_chunk_index(seq, os.path.join(directory, fn))
            if chunk is not None:
                self._chunks[seq] = chunk
            else:
                self.skipped_chunks += 1

    # -- open / index ---------------------------------------------------------

    def _load_chunk_index(self, seq: int, path: str) -> Optional[_Chunk]:
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError as e:
            log.warning("cold chunk %s unreadable (%s); skipping", path, e)
            return None
        payload = self._index_payload(data, path)
        if payload is None:
            return None
        try:
            doc = json.loads(payload[1:])
            series = [_ChunkSeries(d["m"], d["tags"], d["off"], d["t_min"],
                                   d["t_max"], d["n"], d["fields"])
                      for d in doc["series"]]
        except (ValueError, KeyError, TypeError) as e:
            log.warning("cold chunk %s has a corrupt index (%s); "
                        "skipping whole chunk", path, e)
            return None
        return _Chunk(seq, path, series, len(data))

    def _index_payload(self, data: bytes, path: str) -> Optional[bytes]:
        """Locate + CRC-verify the index block: trailer pointer first,
        full frame scan as the torn-file fallback."""
        if not data.startswith(CHUNK_MAGIC):
            log.warning("cold chunk %s: bad magic; skipping", path)
            return None
        if len(data) >= _TRAILER.size:
            idx_off, end = _TRAILER.unpack_from(data, len(data)
                                                - _TRAILER.size)
            if end == CHUNK_END_MAGIC:
                payload = self._read_frame(data, idx_off)
                if payload is not None and payload[0] == _INDEX_TAG:
                    return payload
                log.warning("cold chunk %s: trailer points at a corrupt "
                            "index; falling back to a frame scan", path)
        # torn/corrupt trailer: walk the frames, keep the last valid index
        off = len(CHUNK_MAGIC)
        found = None
        while off + _HEADER.size <= len(data):
            payload = self._read_frame(data, off)
            if payload is None:
                break
            if payload and payload[0] == _INDEX_TAG:
                found = payload
            off += _HEADER.size + len(payload)
        if found is None:
            log.warning("cold chunk %s: no valid index block; "
                        "skipping whole chunk", path)
        return found

    @staticmethod
    def _read_frame(data: bytes, off: int) -> Optional[bytes]:
        if off + _HEADER.size > len(data):
            return None
        ln, crc = _HEADER.unpack_from(data, off)
        start = off + _HEADER.size
        end = start + ln
        if end > len(data):
            return None
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            return None
        return payload

    # -- seal (write one chunk) ----------------------------------------------

    def next_seq(self) -> int:
        with self._lock:
            return max(self._chunks, default=0) + 1

    def max_seq(self) -> int:
        """Highest chunk seq on disk — what a committing snapshot records
        as ``cold_committed``."""
        with self._lock:
            return max(self._chunks, default=0)

    def append_chunk(self, entries: Iterable) -> int:
        """Write ``[(measurement, tags, times, cols), ...]`` as one
        immutable chunk with the WAL durability discipline (tmp + fsync +
        rename + directory fsync).  The chunk is registered in the index
        but **not** made query-visible: callers flip each view's
        visibility (``view.commit(seq)``) atomically with the hot-tier
        trim, and the next snapshot commits it durably."""
        with self._lock:
            seq = max(self._chunks, default=0) + 1
            parts = [CHUNK_MAGIC]
            off = len(CHUNK_MAGIC)
            index = []
            series = []
            for m, tags, times, cols in entries:
                if not times:
                    continue
                block = _frame(encode_series_block(m, tags, times, cols))
                t_min, t_max = min(times), max(times)
                index.append({"m": m, "tags": tags, "off": off,
                              "t_min": t_min, "t_max": t_max,
                              "n": len(times), "fields": sorted(cols)})
                series.append(_ChunkSeries(m, tags, off, t_min, t_max,
                                           len(times), sorted(cols)))
                parts.append(block)
                off += len(block)
            if not series:
                raise ValueError("append_chunk needs at least one "
                                 "non-empty series")
            idx_payload = bytes((_INDEX_TAG,)) + json.dumps(
                {"series": index}, separators=(",", ":")).encode()
            parts.append(_frame(idx_payload))
            parts.append(_TRAILER.pack(off, CHUNK_END_MAGIC))
            data = b"".join(parts)
            path = os.path.join(self.directory, _chunk_name(seq))
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            _fsync_dir(self.directory)
            self._chunks[seq] = _Chunk(seq, path, series, len(data))
            self.sealed_points += self._chunks[seq].points
            return seq

    def reconcile(self, committed: Optional[int]) -> int:
        """Drop uncommitted orphan chunks (seq > the snapshot's
        ``cold_committed``) left by a crash between chunk write and
        snapshot commit — their points are still in the snapshot/WAL, so
        keeping them would double-count.  ``None`` keeps everything (an
        unreadable snapshot may have made the chunks the only copy).
        Returns the number of orphans deleted."""
        if committed is None:
            return 0
        dropped = 0
        with self._lock:
            for seq in sorted(self._chunks):
                if seq <= committed:
                    continue
                chunk = self._chunks.pop(seq)
                for view in self._views:
                    view.live.discard(seq)
                try:
                    os.remove(chunk.path)
                except OSError:
                    pass
                log.warning("cold chunk %s was never committed by a "
                            "snapshot (crash mid-seal); dropped", chunk.path)
                dropped += 1
            if dropped:
                _fsync_dir(self.directory)
        return dropped

    # -- views ----------------------------------------------------------------

    def make_view(self, shard_i: int = 0, n_shards: int = 1) -> "ColdView":
        with self._lock:
            view = ColdView(self, shard_i, n_shards, set(self._chunks))
            self._views.append(view)
            return view

    # -- read path (always through a view) ------------------------------------

    def _block(self, chunk: _Chunk, ent: _ChunkSeries):
        """Decode (with caching) one series block; ``None`` if the block
        is corrupt — skipped and counted, never wrong data."""
        key = (chunk.seq, ent.off)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                return hit
        try:
            with open(chunk.path, "rb") as f:
                f.seek(ent.off)
                head = f.read(_HEADER.size)
                ln, crc = _HEADER.unpack(head)
                payload = f.read(ln)
            if len(payload) != ln or zlib.crc32(payload) != crc:
                raise ValueError("CRC mismatch")
            m, tags, times, cols = decode_series_block(payload)
            if len(times) != ent.n:
                raise ValueError("row count disagrees with index")
        except (OSError, ValueError, KeyError, TypeError, struct.error) as e:
            with self._lock:
                self.corrupt_blocks += 1
            log.warning("cold chunk %s: corrupt series block at %d (%s); "
                        "skipping", chunk.path, ent.off, e)
            return None
        block = (times, cols)
        with self._lock:
            self._cache[key] = block
            self._cache.move_to_end(key)
            while len(self._cache) > self._cache_max:
                self._cache.popitem(last=False)
        return block

    def _entries(self, live: set, measurement: Optional[str] = None):
        with self._lock:
            chunks = [self._chunks[s] for s in sorted(live)
                      if s in self._chunks]
        for chunk in chunks:
            ents = chunk.by_meas.get(measurement, ()) \
                if measurement is not None \
                else [e for es in chunk.by_meas.values() for e in es]
            for ent in ents:
                yield chunk, ent

    # -- introspection --------------------------------------------------------

    def chunk_count(self) -> int:
        with self._lock:
            return len(self._chunks)

    def stats(self) -> dict:
        with self._lock:
            chunks = list(self._chunks.values())
            points = sum(c.points for c in chunks)
            nbytes = sum(c.nbytes for c in chunks)
            raw = sum(c.raw_bytes for c in chunks)
            return {"chunks": len(chunks), "points": points,
                    "bytes": nbytes,
                    "bytes_per_point": nbytes / points if points else 0.0,
                    "raw_bytes": raw,
                    "compression_ratio": raw / nbytes if nbytes else 0.0,
                    "sealed_points": self.sealed_points,
                    "corrupt_blocks": self.corrupt_blocks,
                    "skipped_chunks": self.skipped_chunks}


class ColdView:
    """One shard's read view of a :class:`ColdStore`.

    ``live`` gates chunk visibility (flipped by ``commit`` atomically
    with the shard's hot-prefix trim); series are filtered to this
    shard by the stable crc32 hash, so re-hashing on restart keeps every
    sealed series on exactly one shard.  The query methods mirror the
    ``Database`` slice semantics bit-for-bit (inclusive bounds, falsy
    ``t_min``/``t_max`` meaning unbounded) — tier parity depends on it.
    """

    def __init__(self, store: ColdStore, shard_i: int, n_shards: int,
                 live: set):
        self.store = store
        self.shard_i = int(shard_i)
        self.n_shards = int(n_shards)
        self.live = live

    def commit(self, seq: int):
        self.live.add(seq)

    def _mine(self, ent: _ChunkSeries) -> bool:
        if self.n_shards <= 1:
            return True
        return shard_index(ent.m, ent.tags_key, self.n_shards) == \
            self.shard_i

    @staticmethod
    def _tags_match(ent: _ChunkSeries, tags: Optional[dict]) -> bool:
        return not tags or all(ent.tags.get(k) == str(v)
                               for k, v in tags.items())

    def fragments(self, measurement: str, fields: Optional[list] = None,
                  tags: Optional[dict] = None, t_min: Optional[int] = None,
                  t_max: Optional[int] = None) -> list:
        """Sealed column fragments overlapping the range, in chunk order:
        ``[(tags_key, tags, times, {field: column}), ...]`` — what
        ``Database.select`` merges under the hot fragments."""
        out = []
        for chunk, ent in self.store._entries(self.live, measurement):
            if not self._mine(ent) or not self._tags_match(ent, tags):
                continue
            if (t_min and ent.t_max < t_min) or \
                    (t_max and ent.t_min > t_max):
                continue
            block = self.store._block(chunk, ent)
            if block is None:
                continue
            times, cols = block
            lo = bisect.bisect_left(times, t_min) if t_min else 0
            hi = bisect.bisect_right(times, t_max) if t_max else len(times)
            if lo >= hi:
                continue
            names = fields if fields else list(cols)
            vals = {k: cols[k][lo:hi] for k in names if k in cols}
            if not vals:
                continue
            out.append((ent.tags_key, ent.tags, times[lo:hi], vals))
        return out

    def measurements(self) -> set:
        return {ent.m for _, ent in self.store._entries(self.live)
                if self._mine(ent)}

    def field_keys(self, measurement: str) -> set:
        keys: set = set()
        for _, ent in self.store._entries(self.live, measurement):
            if self._mine(ent):
                keys.update(ent.fields)
        return keys

    def tag_values(self, measurement: str, tag: str) -> set:
        vals = {ent.tags.get(tag)
                for _, ent in self.store._entries(self.live, measurement)
                if self._mine(ent)}
        vals.discard(None)
        return vals

    def stored_points(self) -> int:
        return sum(ent.n for _, ent in self.store._entries(self.live)
                   if self._mine(ent))

    def time_range(self, measurement: Optional[str] = None):
        """``(t_min, t_max)`` over this shard's sealed data (``None``
        when empty) — what the query planner consults to report whether a
        raw plan spans the cold tier."""
        lo = hi = None
        for _, ent in self.store._entries(self.live, measurement):
            if not self._mine(ent):
                continue
            if lo is None or ent.t_min < lo:
                lo = ent.t_min
            if hi is None or ent.t_max > hi:
                hi = ent.t_max
        return None if lo is None else (lo, hi)

    def stats(self) -> dict:
        return self.store.stats()
