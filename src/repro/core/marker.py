"""Marker-region instrumentation + query-side rooflines (ROADMAP item 3).

The LIKWID marker API (``pylikwid.markerstartregion`` /
``markerstopregion``, SNIPPETS.md snippet 1) is how application phases
get attributed HPM data in the paper's stack.  This module is its LMS
analogue for the repo's own jax/pallas workloads:

* :class:`MarkerSession` — per-process region accounting with
  **thread-local region stacks**, so nested regions get exact
  inclusive/exclusive wall time and concurrent threads never corrupt
  each other's nesting.  Per region it accumulates call count,
  inclusive/exclusive seconds and user-supplied work counters (flops,
  bytes, tokens, ...).
* Emission: accumulated *deltas since the last flush* leave through any
  ``UserMetric``-shaped emitter as the ``marker`` measurement — tags
  ``{region}`` plus the emitter's defaults (hostname; the router adds
  jobid/username while a job is live), fields ``{time_s, excl_time_s,
  calls, <counters>...}``.  Delta emission makes ``QuerySpec(agg="sum")``
  over rollup windows yield exact per-window totals, which is what the
  ROOFLINE rate formulas need.
* Query side: the ``ROOFLINE`` performance group
  (``repro.core.perf_groups``) derives ``intensity`` (flops/byte),
  ``achieved_gflops`` and ``roofline_frac`` = achieved / min(peak_flops,
  peak_bw * intensity) from stored marker fields — evaluated by the
  existing query engine over rollup tiers, so per-region roofline
  placement federates, caches and survives raw-point retention like any
  derived metric.  :func:`roofline_spec` is the one canonical
  ``QuerySpec`` the dashboard panel, the analysis rule, ``/query/v2``
  callers and the tests all share.

Calibration-point convention: measured machine peaks (e.g. from
``benchmarks/roofline.py`` microbenchmarks) are stored as ordinary
``marker`` points under the reserved region :data:`CALIB_REGION` with
fields ``peak_flops`` / ``peak_bw``.  :func:`roofline_peaks` reads the
latest one back; :func:`register_roofline_group` re-registers ROOFLINE
with the peaks baked in as numeric literals.  Because a ``QuerySpec``
resolves ``@metric`` references to formula *text* at construction, a
calibrated spec ships its peaks inside the spec — remote federation
stays byte-identical with zero remote calibration state.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.core.line_protocol import now_ns
from repro.core.perf_groups import (formula_for, register_group,
                                    roofline_group_text)
from repro.core.query import QuerySpec

__all__ = [
    "CALIB_REGION", "MARKER_MEASUREMENT", "MarkerSession", "calibrate",
    "low_roofline_rule", "register_roofline_group", "roofline_group_text",
    "roofline_peaks", "roofline_spec",
]

MARKER_MEASUREMENT = "marker"
# reserved region name carrying machine-peak calibration points; never a
# real code region (leading underscore keeps it sorted apart and obvious)
CALIB_REGION = "_calib"


class _Frame:
    """One open region on one thread's stack."""

    __slots__ = ("name", "t0", "child_s", "counters")

    def __init__(self, name: str, t0: float):
        self.name = name
        self.t0 = t0
        self.child_s = 0.0          # inclusive seconds of finished children
        self.counters = None


class Region:
    """Context manager handle; ``seconds`` holds the inclusive wall time
    after exit.  Exception-safe: the region stops (and is accounted) even
    when the body raises — LIKWID's stop-on-error discipline without the
    boilerplate."""

    __slots__ = ("_session", "name", "counters", "seconds", "_frame")

    def __init__(self, session: "MarkerSession", name: str,
                 counters: Optional[dict]):
        self._session = session
        self.name = name
        self.counters = dict(counters) if counters else None
        self.seconds = None
        self._frame = None

    def add(self, **counters):
        """Add work counters from inside the region body."""
        if self.counters is None:
            self.counters = {}
        for k, v in counters.items():
            self.counters[k] = self.counters.get(k, 0.0) + float(v)
        return self

    def __enter__(self):
        self._frame = self._session.start_region(self.name)
        return self

    def __exit__(self, exc_type, exc, tb):
        self.seconds = self._session._stop_frame(self._frame, self.counters)
        self._frame = None
        return False


class MarkerSession:
    """pylikwid-style marker session over an LMS emitter.

    ``emitter`` is anything with ``.metric(name, fields, tags=, ts=)``
    (a :class:`~repro.core.usermetric.UserMetric`); ``None`` accumulates
    only — :meth:`flush` still returns the drained per-region deltas, so
    a session is usable standalone (tests, overhead benchmarks).

    ``clock`` is injectable for deterministic tests.  All public methods
    are thread-safe; region *stacks* are thread-local by design (nesting
    is a per-thread property), the accumulator table is shared under a
    lock (totals merge across threads).
    """

    def __init__(self, emitter=None, *, emit_interval_s: float = 5.0,
                 measurement: str = MARKER_MEASUREMENT,
                 clock: Callable[[], float] = time.monotonic):
        self._emitter = emitter
        self.emit_interval_s = float(emit_interval_s)
        self.measurement = measurement
        self._clock = clock
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._pending: dict = {}        # region -> delta acc since flush
        self._totals: dict = {}         # region -> lifetime acc
        self._last_emit = clock()
        self._closed = False

    # -- region stack (thread-local) ----------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def start_region(self, name: str) -> _Frame:
        """Open a region on the calling thread; returns its frame token."""
        fr = _Frame(str(name), self._clock())
        self._stack().append(fr)
        return fr

    def stop_region(self, name: Optional[str] = None,
                    counters: Optional[dict] = None) -> float:
        """Close the innermost open region; returns inclusive seconds.

        ``name`` (when given) must match the innermost region —
        mismatched stop order is a caller bug and raises rather than
        silently misattributing time.  Prefer :meth:`region`, which is
        exception-safe by construction.
        """
        st = self._stack()
        if not st:
            raise ValueError(f"stop_region({name!r}): no region open "
                             "on this thread")
        if name is not None and st[-1].name != name:
            raise ValueError(f"stop_region({name!r}): innermost open "
                             f"region is {st[-1].name!r}")
        return self._stop_frame(st[-1], counters)

    def _stop_frame(self, frame: _Frame, counters: Optional[dict]) -> float:
        """Close ``frame`` (and any regions leaked open inside it)."""
        st = self._stack()
        if frame not in st:
            raise ValueError(f"region {frame.name!r} is not open "
                             "on this thread")
        now = self._clock()
        # close leaked children first so their time still attributes
        # correctly (a child started but never stopped must not swallow
        # the parent's exclusive time)
        while st[-1] is not frame:
            self._pop(st, now, None)
        incl = self._pop(st, now, counters)
        self._maybe_emit(now)
        return incl

    def _pop(self, st: list, now: float, counters: Optional[dict]) -> float:
        fr = st.pop()
        incl = max(now - fr.t0, 0.0)
        excl = max(incl - fr.child_s, 0.0)
        if st:
            st[-1].child_s += incl
        merged = fr.counters
        if counters:
            merged = dict(merged) if merged else {}
            for k, v in counters.items():
                merged[k] = merged.get(k, 0.0) + float(v)
        self._accumulate(fr.name, 1, incl, excl, merged)
        return incl

    def region(self, name: str, counters: Optional[dict] = None) -> Region:
        """``with session.region("fwd", counters={"flops": f}):`` —
        counters are credited once per call on exit (static per-call
        costs: pass them up front; measured ones: ``r.add(...)``)."""
        return Region(self, name, counters)

    def record(self, name: str, seconds: float,
               counters: Optional[dict] = None, calls: int = 1):
        """Account an externally-timed region (a wait measured by someone
        else, e.g. ``DataLoader.wait_time_s``) without entering the
        stack: inclusive == exclusive == ``seconds``."""
        s = float(seconds)
        self._accumulate(str(name), calls, s, s,
                         dict(counters) if counters else None)
        self._maybe_emit(self._clock())

    # -- accumulators ---------------------------------------------------------

    @staticmethod
    def _merge(acc: dict, calls: int, incl: float, excl: float,
               counters: Optional[dict]):
        acc["calls"] = acc.get("calls", 0.0) + float(calls)
        acc["time_s"] = acc.get("time_s", 0.0) + incl
        acc["excl_time_s"] = acc.get("excl_time_s", 0.0) + excl
        if counters:
            for k, v in counters.items():
                acc[k] = acc.get(k, 0.0) + float(v)

    def _accumulate(self, name: str, calls: int, incl: float, excl: float,
                    counters: Optional[dict]):
        with self._lock:
            self._merge(self._pending.setdefault(name, {}), calls, incl,
                        excl, counters)
            self._merge(self._totals.setdefault(name, {}), calls, incl,
                        excl, counters)

    def _maybe_emit(self, now: float):
        if self._emitter is None:
            return
        with self._lock:
            due = now - self._last_emit >= self.emit_interval_s
        if due:
            self.flush()

    def snapshot(self) -> dict:
        """Lifetime per-region totals (never reset by flush)."""
        with self._lock:
            return {name: dict(acc) for name, acc in self._totals.items()}

    def open_regions(self) -> list:
        """Names of regions open on the *calling* thread, outermost first."""
        return [fr.name for fr in self._stack()]

    # -- emission -------------------------------------------------------------

    def flush(self, ts: Optional[int] = None) -> dict:
        """Drain pending deltas; emit one ``marker`` point per region (all
        points of one flush share one timestamp, so cross-region queries
        align).  Returns ``{region: fields}`` of what was emitted."""
        with self._lock:
            pending, self._pending = self._pending, {}
            self._last_emit = self._clock()
        if not pending:
            return {}
        t = ts if ts is not None else now_ns()
        out = {}
        for name in sorted(pending):
            fields = {k: float(v) for k, v in pending[name].items()}
            out[name] = fields
            if self._emitter is not None:
                self._emitter.metric(self.measurement, fields,
                                     tags={"region": name}, ts=t)
        if out and self._emitter is not None:
            # push through the emitter's buffer now (UserMetric's internal
            # flush, NOT its public one — that would re-drain this session
            # recursively); failures re-buffer there and never raise into
            # the instrumented code path
            push = getattr(self._emitter, "_flush", None)
            if push is not None:
                push(raise_errors=False)
        return out

    def close(self) -> dict:
        """Final flush (the emitter is NOT closed — it is shared)."""
        self._closed = True
        return self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# --------------------------------------------------------------------------
# ROOFLINE query side
# --------------------------------------------------------------------------

def register_roofline_group(peak_flops: Optional[float] = None,
                            peak_bw: Optional[float] = None):
    """(Re-)register ROOFLINE, optionally with calibrated peaks baked in.
    Specs built *afterwards* resolve ``@ROOFLINE.*`` to the new text."""
    return register_group(roofline_group_text(peak_flops, peak_bw))


def calibrate(emitter, peak_flops: float, peak_bw: float, *,
              register: bool = True, ts: Optional[int] = None):
    """Persist measured machine peaks as a ``marker`` calibration point
    (region :data:`CALIB_REGION`) and, by default, re-register ROOFLINE
    so new specs use them."""
    emitter.metric(MARKER_MEASUREMENT,
                   {"peak_flops": float(peak_flops),
                    "peak_bw": float(peak_bw)},
                   tags={"region": CALIB_REGION},
                   ts=ts if ts is not None else now_ns())
    flush = getattr(emitter, "flush", None)
    if flush is not None:
        flush()                 # a calibration point must land now
    if register:
        register_roofline_group(peak_flops, peak_bw)


def roofline_peaks(db) -> Optional[tuple]:
    """Latest stored calibration point -> ``(peak_flops, peak_bw)`` or
    ``None``.  ``db`` is any Database-shaped view (plain, sharded,
    federated, HTTP client)."""
    best = None
    for s in db.select(MARKER_MEASUREMENT, ["peak_flops", "peak_bw"],
                       {"region": CALIB_REGION}):
        pf = s.values.get("peak_flops", [])
        bw = s.values.get("peak_bw", [])
        for i, t in enumerate(s.times):
            if i < len(pf) and i < len(bw) and \
                    (best is None or t > best[0]):
                best = (t, float(pf[i]), float(bw[i]))
    return None if best is None else (best[1], best[2])


def roofline_spec(jobid: Optional[str] = None, *,
                  window_ns: int = 10 * 10**9,
                  t_min: Optional[int] = None, t_max: Optional[int] = None,
                  region: Optional[str] = None,
                  limit: Optional[int] = None) -> QuerySpec:
    """THE canonical per-region roofline query — one spec shared by the
    dashboard panel, the ``/query/v2`` acceptance path and the tests.

    ``agg="sum"`` turns the delta-emitted marker fields into exact
    per-window totals, so every ROOFLINE rate formula sees true window
    rates; ``group_by="region"`` yields one group per code region.
    The ``@ROOFLINE.*`` references resolve to formula text *here*, at
    construction — a calibrated group registered before this call is
    carried inside the spec to shards and remote instances.
    """
    tags = {}
    if jobid:
        tags["jobid"] = jobid
    if region:
        tags["region"] = region
    return QuerySpec(measurement=MARKER_MEASUREMENT,
                     metrics=("time_s", "calls", "@ROOFLINE.intensity",
                              "@ROOFLINE.achieved_gflops",
                              "@ROOFLINE.roofline_frac"),
                     tags=tags, t_min=t_min, t_max=t_max,
                     window_ns=window_ns, group_by="region", agg="sum",
                     limit=limit)


def low_roofline_rule(frac: float = 0.05, *, min_duration_s: float = 60.0,
                      clear_duration_s: float = 15.0,
                      severity: str = "warning"):
    """``ThresholdRule`` flagging regions that sustain below ``frac`` of
    their attainable roofline.  Query-time derived (``expr``): marker
    points never carry ``roofline_frac``; the engine evaluates the
    ROOFLINE formula per rollup window.  Regions without flops/bytes
    counters produce no derived windows and can never fire."""
    from repro.core.analysis import ThresholdRule
    return ThresholdRule(
        "low_roofline", MARKER_MEASUREMENT, "roofline_frac", "<",
        float(frac), min_duration_s, severity,
        "region sustains a low fraction of its attainable roofline "
        "(compute- or bandwidth-starved phase)", clear_duration_s,
        expr=formula_for("ROOFLINE.roofline_frac"))
