"""Binary ingest plane — persistent connections, backpressure, shedding.

ROADMAP item 1: one-shot HTTP per batch is the wrong shape for millions
of emitting agents (connection setup per batch, text encode/decode per
point, and a silent *stall* is the only overload response).  This module
is the transport the edge actually needs, shaped like the collection
planes of MPCDF's monitoring system and PerSyst: persistent sockets,
length-prefixed binary frames, bounded per-connection queues, and
*explicit* load shedding the client can act on.

Wire format
-----------

The connection opens with a fixed handshake::

    client -> MAGIC b"LMSBIN01"  <u16 db_len>  db_name_utf8
    server -> T_HELLO frame (payload: JSON server parameters)

after which both directions speak length-prefixed frames::

    <u8 type> <u32 req_id> <u32 payload_len> payload

======== ======= ==================================================
type     dir     payload
======== ======= ==================================================
T_HELLO  s->c    JSON {"db", "queue_max", "max_frame_bytes"}
T_WRITE  c->s    columnar batch — ``wal.encode_batch_payload`` bytes
T_OK     s->c    <u32 points_written>
T_SHED   s->c    <f64 retry_after_s> (queue full; batch NOT applied)
T_ERR    s->c    utf-8 error message (batch rejected)
T_PING   c->s    empty
T_PONG   s->c    empty
======== ======= ==================================================

``req_id`` is chosen by the client and echoed verbatim in the response,
so a client may keep several writes in flight on one socket and match
responses out of order (the server answers T_PING immediately from its
reader thread, ahead of queued writes).

A T_WRITE payload is *exactly* a WAL record payload
(``wal.encode_batch_payload`` / ``wal.decode_batch_payload``: JSON meta
+ raw little-endian int64/float64 column blobs).  The same bytes appear
on the wire and in the write-ahead log, and the decoded columns feed
``MetricsRouter.write_entries`` -> ``Database.write_columns`` without
ever materializing per-point objects — ingest -> WAL is near-zero-copy.

Backpressure and shedding
-------------------------

Each connection owns a bounded queue between its reader thread (frame
parsing) and its worker thread (decode + route).  When the queue is
full the reader answers T_SHED *immediately* with a retry-after hint —
the batch was **not** applied, so a client resend after a shed is
exactly-once.  Nothing ever silently stalls and nothing is silently
dropped: every overload response is an explicit client-visible frame.

Client fallback rules (:class:`BinarySink`)
-------------------------------------------

* **T_SHED**: sleep ``retry_after_s`` (with backoff, bounded by
  ``max_shed_retries``) and resend — safe, the server did not apply.
* **socket death** mid-request: reconnect and resend — *at-least-once*
  (the server may have applied the batch before the connection died).
* **transport failure** (connect refused, handshake failure, reconnect
  exhausted): fall back to the HTTP line path (``fallback`` sink) when
  one is configured, and retry the binary path after
  ``fallback_cooldown_s``.
* **T_ERR** (malformed batch): raised to the caller — re-sending the
  same bytes over HTTP would fail the same way.
"""

from __future__ import annotations

import json
import queue
import socket
import struct
import threading
import time
from typing import Iterable, Optional

from repro.core.line_protocol import Point
from repro.core.tsdb import Database
from repro.core.wal import decode_batch_payload, encode_batch_payload

MAGIC = b"LMSBIN01"

_HELLO_DB = struct.Struct("<H")         # db name length
_FRAME = struct.Struct("<BII")          # type, req_id, payload_len
_OK_BODY = struct.Struct("<I")          # points written
_SHED_BODY = struct.Struct("<d")        # retry-after seconds

T_HELLO = 1
T_WRITE = 2
T_OK = 3
T_SHED = 4
T_ERR = 5
T_PING = 6
T_PONG = 7

DEFAULT_MAX_FRAME_BYTES = 8 * 1024 * 1024
DEFAULT_QUEUE_MAX = 64
DEFAULT_SHED_RETRY_AFTER_S = 0.05


class IngestError(Exception):
    """The server rejected a batch (T_ERR) or shed it past the client's
    retry budget — the batch was NOT applied (exactly-once safe for
    sheds; a T_ERR batch is malformed and must not be resent)."""


def points_to_entries(points) -> list:
    """``[Point, ...]`` -> wire/WAL entries ``[(measurement, tags,
    times, {field: column}), ...]`` with per-series ascending times —
    one grouping + one transpose, shared with the row write path."""
    if isinstance(points, Point):
        points = [points]
    by_series, tags_of = Database.group_points(points)
    out = []
    for (meas, key), items in by_series.items():
        times, cols = Database.transpose_items(items)
        out.append((meas, tags_of[(meas, key)], times, cols))
    return out


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ConnectionError on EOF."""
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks) if len(chunks) != 1 else chunks[0]


def _send_frame(sock: socket.socket, ftype: int, req_id: int,
                payload: bytes = b""):
    sock.sendall(_FRAME.pack(ftype, req_id, len(payload)) + payload)


class _Connection:
    """One accepted socket: reader thread + worker thread + bounded
    queue between them (the backpressure boundary)."""

    def __init__(self, server: "IngestServer", sock: socket.socket):
        self.server = server
        self.sock = sock
        self.q: queue.Queue = queue.Queue(maxsize=server.queue_max)
        self.db = None
        self.closed = threading.Event()
        # reader and worker both write to the socket (SHED/PONG vs
        # OK/ERR) — frames must not interleave
        self._send_lock = threading.Lock()
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True, name="lms-ingest-reader")
        self._worker = threading.Thread(
            target=self._work_loop, daemon=True, name="lms-ingest-worker")

    def start(self):
        self._reader.start()
        self._worker.start()

    def close(self):
        self.closed.set()
        try:
            self.sock.close()
        except OSError:
            pass

    def _reply(self, ftype: int, req_id: int, payload: bytes = b""):
        try:
            with self._send_lock:
                _send_frame(self.sock, ftype, req_id, payload)
        except OSError:
            self.closed.set()

    # -- reader: handshake, framing, ping, shed ---------------------------

    def _read_loop(self):
        try:
            self._handshake()
            while not self.closed.is_set():
                hdr = _recv_exact(self.sock, _FRAME.size)
                ftype, req_id, ln = _FRAME.unpack(hdr)
                if ftype == T_PING:
                    if ln:
                        self._drain(ln)
                    self.server._count(pings=1)
                    self._reply(T_PONG, req_id)
                    continue
                if ftype != T_WRITE:
                    self._drain(ln)
                    self.server._count(frame_errors=1)
                    self._reply(T_ERR, req_id,
                                f"unexpected frame type {ftype}".encode())
                    continue
                if ln > self.server.max_frame_bytes:
                    # oversized: drain in chunks (keep the stream in
                    # sync) and reject — the binary twin of HTTP 413
                    self._drain(ln)
                    self.server._count(frame_errors=1, oversized_frames=1)
                    self._reply(T_ERR, req_id,
                                f"frame of {ln} bytes exceeds limit "
                                f"{self.server.max_frame_bytes}".encode())
                    continue
                payload = _recv_exact(self.sock, ln)
                self.server._count(frames_in=1)
                try:
                    self.q.put_nowait((req_id, payload))
                except queue.Full:
                    # explicit shed: the batch was NOT enqueued, so a
                    # client resend is exactly-once — never a stall,
                    # never a silent drop
                    self.server._count(shed_frames=1)
                    self._reply(T_SHED, req_id, _SHED_BODY.pack(
                        self.server.shed_retry_after_s))
        except (ConnectionError, OSError):
            pass
        finally:
            self.close()
            self.server._forget(self)

    def _handshake(self):
        magic = _recv_exact(self.sock, len(MAGIC))
        if magic != MAGIC:
            raise ConnectionError(f"bad magic {magic!r}")
        (db_len,) = _HELLO_DB.unpack(_recv_exact(self.sock, _HELLO_DB.size))
        self.db = _recv_exact(self.sock, db_len).decode() if db_len \
            else "global"
        self._reply(T_HELLO, 0, json.dumps({
            "db": self.db,
            "queue_max": self.server.queue_max,
            "max_frame_bytes": self.server.max_frame_bytes,
        }).encode())

    def _drain(self, n: int):
        while n:
            n -= len(_recv_exact(self.sock, min(n, 1 << 16)))

    # -- worker: decode + route ------------------------------------------

    def _work_loop(self):
        while not self.closed.is_set():
            try:
                req_id, payload = self.q.get(timeout=0.2)
            except queue.Empty:
                continue
            try:
                entries = decode_batch_payload(payload)
                n = self.server.router.write_entries(entries)
            except Exception as e:          # noqa: BLE001 — per-batch
                self.server._count(batch_errors=1)
                self._reply(T_ERR, req_id, str(e)[:1024].encode())
                continue
            self.server._count(batches_ok=1, points_ok=n)
            self._reply(T_OK, req_id, _OK_BODY.pack(min(n, 0xFFFFFFFF)))


class IngestServer:
    """Persistent-socket binary ingest endpoint for one router.

    Serves alongside the HTTP endpoint (``MonitoringStack(serve_ingest=
    True)``); attaches itself as ``router.ingest`` so the HTTP face can
    surface its counters (``GET /meta?what=ingest``).
    """

    def __init__(self, router, host: str = "127.0.0.1", port: int = 0, *,
                 queue_max: int = DEFAULT_QUEUE_MAX,
                 max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES,
                 shed_retry_after_s: float = DEFAULT_SHED_RETRY_AFTER_S):
        self.router = router
        self.queue_max = int(queue_max)
        self.max_frame_bytes = int(max_frame_bytes)
        self.shed_retry_after_s = float(shed_retry_after_s)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.host, self.port = self._sock.getsockname()[:2]
        self._conns: set = set()
        self._lock = threading.Lock()
        self._stats = {"connections_total": 0, "frames_in": 0,
                       "batches_ok": 0, "points_ok": 0, "shed_frames": 0,
                       "frame_errors": 0, "oversized_frames": 0,
                       "batch_errors": 0, "pings": 0,
                       "join_timeouts": 0}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        router.ingest = self

    @property
    def address(self) -> tuple:
        return (self.host, self.port)

    def start(self) -> "IngestServer":
        self._thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="lms-ingest-accept")
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            c.close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            if self._thread.is_alive():
                # surfaced, not silent: a leaked accept thread shows up
                # in /meta?what=ingest instead of just outliving us
                self._count(join_timeouts=1)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return                  # listener closed (stop())
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Connection(self, sock)
            with self._lock:
                self._conns.add(conn)
                self._stats["connections_total"] += 1
            conn.start()

    def _forget(self, conn: _Connection):
        with self._lock:
            self._conns.discard(conn)

    def _count(self, **deltas: int):
        with self._lock:
            for k, v in deltas.items():
                self._stats[k] += v

    def stats(self) -> dict:
        """Shed/queue counters — the ``/meta?what=ingest`` payload."""
        with self._lock:
            out = dict(self._stats)
            conns = list(self._conns)
        out["connections_active"] = len(conns)
        out["queued_batches"] = sum(c.q.qsize() for c in conns)
        out["queue_max"] = self.queue_max
        out["max_frame_bytes"] = self.max_frame_bytes
        return out


class BinarySink:
    """Persistent-connection binary client with automatic reconnect,
    shed-aware retry, and fallback to the HTTP line path.

    Drop-in for :class:`repro.core.httpd.HttpSink` anywhere a sink with
    ``.write(points)`` is expected (``UserMetric``, ``HostAgent``,
    forward agents) — same points in, same database state out, at a
    fraction of the per-batch cost.

    Thread-safe: one in-flight request at a time per sink (an internal
    lock); spin up one sink per emitting thread for parallelism.
    """

    def __init__(self, host: str, port: int, *, db: str = "global",
                 timeout_s: float = 5.0, fallback=None,
                 fallback_cooldown_s: float = 30.0,
                 max_shed_retries: int = 8,
                 max_reconnects: int = 1):
        self.host = host
        self.port = int(port)
        self.db = db
        self.timeout_s = float(timeout_s)
        self.fallback = fallback
        self.fallback_cooldown_s = float(fallback_cooldown_s)
        self.max_shed_retries = int(max_shed_retries)
        self.max_reconnects = int(max_reconnects)
        self._sock: Optional[socket.socket] = None
        self._req_id = 0
        self._lock = threading.Lock()
        self._fallback_until = 0.0
        self._stats = {"batches": 0, "points": 0, "sheds": 0,
                       "reconnects": 0, "fallback_batches": 0,
                       "fallback_points": 0}

    # -- connection management --------------------------------------------

    def _connect(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            db = self.db.encode()
            sock.sendall(MAGIC + _HELLO_DB.pack(len(db)) + db)
            ftype, _, ln = _FRAME.unpack(_recv_exact(sock, _FRAME.size))
            body = _recv_exact(sock, ln)
            if ftype != T_HELLO:
                raise ConnectionError(
                    f"handshake failed: frame type {ftype}")
            self.server_params = json.loads(body) if body else {}
        except Exception:
            sock.close()
            raise
        return sock

    def _ensure_sock(self) -> socket.socket:
        if self._sock is None:
            self._sock = self._connect()
        return self._sock

    def _drop_sock(self):
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # -- write -------------------------------------------------------------

    def write(self, points) -> int:
        """Send one batch; returns the number of points the server
        routed.  See the module docstring for the retry/fallback rules.
        """
        entries = points_to_entries(points)
        if not entries:
            return 0
        payload = encode_batch_payload(entries)
        with self._lock:
            if self.fallback is not None and \
                    time.monotonic() < self._fallback_until:
                return self._write_fallback(points, entries)
            try:
                n = self._write_binary(payload)
            except (OSError, ConnectionError):
                self._drop_sock()
                if self.fallback is None:
                    raise
                self._fallback_until = time.monotonic() + \
                    self.fallback_cooldown_s
                return self._write_fallback(points, entries)
            self._stats["batches"] += 1
            self._stats["points"] += n
            return n

    def _write_fallback(self, points, entries) -> int:
        if isinstance(points, Point):
            points = [points]
        self.fallback.write(points)
        n = sum(len(times) for _, _, times, _ in entries)
        self._stats["fallback_batches"] += 1
        self._stats["fallback_points"] += n
        return n

    def _write_binary(self, payload: bytes) -> int:
        sheds = 0
        reconnects = 0
        retry_after = DEFAULT_SHED_RETRY_AFTER_S
        while True:
            sock = self._ensure_sock()
            self._req_id = (self._req_id + 1) & 0xFFFFFFFF
            req_id = self._req_id
            try:
                _send_frame(sock, T_WRITE, req_id, payload)
                ftype, rid, body = self._read_response(sock, req_id)
            except (OSError, ConnectionError):
                # socket died mid-request: the server may or may not
                # have applied the batch — reconnect-and-resend is
                # at-least-once (documented)
                self._drop_sock()
                if reconnects >= self.max_reconnects:
                    raise
                reconnects += 1
                self._stats["reconnects"] += 1
                continue
            if ftype == T_OK:
                (n,) = _OK_BODY.unpack(body)
                return n
            if ftype == T_SHED:
                # not applied server-side: resending is exactly-once
                (retry_after,) = _SHED_BODY.unpack(body)
                sheds += 1
                self._stats["sheds"] += 1
                if sheds > self.max_shed_retries:
                    raise IngestError(
                        f"server shed the batch {sheds} times "
                        f"(retry_after={retry_after:.3f}s)")
                time.sleep(min(retry_after * sheds, 1.0))
                continue
            if ftype == T_ERR:
                raise IngestError(body.decode(errors="replace"))
            raise ConnectionError(f"unexpected frame type {ftype}")

    def _read_response(self, sock: socket.socket, req_id: int):
        """Read frames until the one matching ``req_id`` (responses to
        other in-flight requests on a shared socket are skipped — this
        sink keeps one in flight, so a mismatch means a stale frame
        from a reconnect-abandoned request)."""
        while True:
            ftype, rid, ln = _FRAME.unpack(_recv_exact(sock, _FRAME.size))
            body = _recv_exact(sock, ln) if ln else b""
            if rid == req_id or ftype == T_HELLO:
                if ftype == T_HELLO:
                    continue
                return ftype, rid, body

    # -- misc --------------------------------------------------------------

    def ping(self) -> bool:
        """Round-trip a T_PING; False on any transport failure."""
        with self._lock:
            try:
                sock = self._ensure_sock()
                self._req_id = (self._req_id + 1) & 0xFFFFFFFF
                _send_frame(sock, T_PING, self._req_id)
                ftype, _, _ = self._read_response(sock, self._req_id)
                return ftype == T_PONG
            except (OSError, ConnectionError):
                self._drop_sock()
                return False

    @property
    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats)

    def close(self):
        with self._lock:
            self._drop_sock()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
