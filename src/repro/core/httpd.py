"""HTTP face of the LMS (paper §III: "the communication protocol inside the
whole system (HTTP) is commonly available on all machines").

Server: mimics the InfluxDB 1.x write API plus the router's job-signal
endpoint, so any existing collector that can POST line protocol (Diamond,
curl cronjobs, Ganglia pull-proxies in the paper) integrates unchanged:

    POST /write?db=global           body: line protocol (batched);
                                    partial-write semantics — every line
                                    that parses is written, the response
                                    is ``{"written": n, "errors":
                                    [{"line", "error"}, ...]}`` (400 only
                                    when nothing parsed); bodies past the
                                    configurable cap (8 MiB) answer 413
    POST /job/start                 body: JSON {jobid, user, hosts, tags}
    POST /job/end                   body: JSON {jobid}
    POST /query/v2[?db=]            body: JSON {"spec": QuerySpec.to_dict(),
                                    "mode": "result"|"partials"} — the
                                    derived-metric query engine
                                    (``repro.core.query``).  mode=result
                                    executes the whole spec server-side
                                    (planned against this instance's
                                    tiers, served from the watermark-
                                    keyed cache) and returns the
                                    finalized groups; mode=partials
                                    returns the *mergeable* per-input
                                    WindowAgg partials — the federated
                                    pushdown wire format
                                    (``HttpQueryClient.query_partials``)
    GET  /ping
    GET  /query?db=&m=&field=&agg=  simple JSON query (dashboards/tests);
                                    &window_ns= adds windowed aggregation
                                    served from the rollup tiers;
                                    &t_min=/&t_max= bound the range;
                                    &rollups=auto|force|raw picks the path;
                                    &partials=1 returns *mergeable* partial
                                    aggregates (WindowAgg state) — the
                                    scatter half of cross-instance
                                    federation (``repro.core.shard``);
                                    &partials=rollup forces the rollup-tier
                                    windowed form (window_ns defaults to
                                    the finest tier, survives retention)
    GET  /meta?what=measurements    introspection (also what=fields&m=,
                                    what=tags&m=&tag=, what=persistence:
                                    WAL/snapshot stats of the durability
                                    layer, what=analysis: continuous-
                                    engine counters, and what=ingest:
                                    binary ingest plane shed/queue
                                    counters) for remote clients
    GET  /alerts?[db=][&jobid=][&rule=][&state=active|resolved|all]
                                    alert episodes reconstructed from the
                                    persisted ``analysis`` measurement
                                    (``repro.core.analysis``) — reads the
                                    DB, not engine memory, so it answers
                                    for recovered state and federates
                                    like any other series query
    GET  /jobs/<id>/report          per-job footprint report: live from
                                    the attached engine while the job
                                    runs, the persisted report afterwards
    GET  /dbs                       list databases
    POST /admin/snapshot[?db=]      snapshot + compact the WAL of one or
                                    all persisted databases
                                    (``repro.core.wal``)

The server is a ``ThreadingHTTPServer``: each request runs on its own
thread, so with a sharded backend (``TSDBServer(shards=N)``) concurrent
``/write`` POSTs from different hosts really do take different shard
locks, and ``/query`` scatter-gathers across the shards.

Clients: :class:`HttpSink` POSTs batched lines — the transport used by the
out-of-process ``usermetric_cli`` and by forward agents.
:class:`HttpQueryClient` is the read side: a Database-shaped query surface
over a remote LMS instance, usable directly or as a
``repro.core.shard.FederatedQuery`` backend (multi-router federation).
"""

from __future__ import annotations

import json
import threading
import urllib.error
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.core.analysis import Alert, load_alerts, load_job_report
from repro.core.line_protocol import Point, encode_batch
from repro.core.router import MetricsRouter
from repro.core.rollup import ROLLUP_AGGS, SCALAR_AGGS, quantile_of
from repro.core.shard import (decode_partials, encode_partials,
                              finalize_scalar, finalize_windowed)
from repro.core.tsdb import Series

_ROLLUPS_PARAM = {"auto": "auto", "force": True, "raw": False}
_UNSET = object()           # HttpQueryClient's not-yet-fetched sentinel


DEFAULT_MAX_BODY_BYTES = 8 * 1024 * 1024


class _PayloadTooLarge(Exception):
    """Request body exceeds the handler's cap (-> 413)."""


class LMSRequestHandler(BaseHTTPRequestHandler):
    router: MetricsRouter = None      # set by make_server
    max_body_bytes: int = DEFAULT_MAX_BODY_BYTES

    def log_message(self, fmt, *args):   # quiet
        pass

    def _send(self, code: int, payload: Optional[dict] = None):
        self.send_response(code)
        if code == 204:
            # RFC 9110 §6.4.1: a 204 response MUST NOT carry a body —
            # a body here desynchronizes keep-alive clients
            self.end_headers()
            return
        body = json.dumps(payload or {}).encode()
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> bytes:
        n = int(self.headers.get("Content-Length", 0))
        if n > self.max_body_bytes:
            # refuse before reading: an unbounded (or hostile)
            # Content-Length must not buffer gigabytes per request
            raise _PayloadTooLarge(
                f"request body of {n} bytes exceeds limit "
                f"{self.max_body_bytes}")
        return self.rfile.read(n) if n else b""

    def _known_db(self, name: str) -> bool:
        """True for databases that already exist (or the router's global
        scope, which may simply not have ingested yet)."""
        return name == self.router.global_db or \
            name in self.router.backend.databases()

    def do_GET(self):
        try:
            self._do_get()
        except Exception as e:                      # noqa: BLE001
            # bad query params (window_ns=abc, unknown agg) must produce a
            # 400, not a dropped connection
            self._send(400, {"error": str(e)})

    def _do_get(self):
        url = urllib.parse.urlparse(self.path)
        # keep_blank_values: a tag filter on an empty tag value (tag_k=)
        # must filter, not silently vanish
        q = dict(urllib.parse.parse_qsl(url.query, keep_blank_values=True))
        if url.path == "/ping":
            self._send(204)
        elif url.path == "/dbs":
            self._send(200, {"databases": self.router.backend.databases()})
        elif url.path == "/query":
            dbname = q.get("db", "global")
            if not self._known_db(dbname):
                # resolve-before-check would *register* the typo'd name
                # server-side (remote-fillable memory); see /query/v2
                self._send(404, {"error": f"unknown database {dbname!r}"})
                return
            db = self.router.backend.db(dbname)
            meas = q.get("m", "")
            fieldname = q.get("field", "value")
            tags = {k[4:]: v for k, v in q.items() if k.startswith("tag_")}
            t_min = int(q["t_min"]) if "t_min" in q else None
            t_max = int(q["t_max"]) if "t_max" in q else None
            window = int(q["window_ns"]) if "window_ns" in q else None
            rollups = q.get("rollups", "auto")
            if rollups not in _ROLLUPS_PARAM:
                raise ValueError(f"unknown rollups={rollups!r} "
                                 "(expected auto|force|raw)")
            use_rollups = _ROLLUPS_PARAM[rollups]
            if q.get("partials") == "rollup":
                # always windowed: window_ns=None means the finest tier,
                # exactly like the local rollup_window_partials default
                parts = db.rollup_window_partials(
                    meas, fieldname, tags=tags, t_min=t_min, t_max=t_max,
                    group_by_tag=q.get("group_by"), window_ns=window)
                self._send(200, {"windowed": True,
                                 "partials": encode_partials(parts, True)})
            elif q.get("partials") in ("1", "true"):
                parts = db.aggregate_partials(
                    meas, fieldname, tags=tags, t_min=t_min, t_max=t_max,
                    group_by_tag=q.get("group_by"), window_ns=window,
                    use_rollups=use_rollups)
                self._send(200, {
                    "windowed": window is not None,
                    "partials": encode_partials(parts, window is not None)})
            elif q.get("rollup_series") in ("1", "true"):
                series = db.rollup_series(meas, fieldname,
                                          agg=q.get("agg", "mean"),
                                          tags=tags, window_ns=window,
                                          t_min=t_min, t_max=t_max)
                self._send(200, {"series": [
                    {"tags": s.tags, "times": s.times,
                     "values": s.values.get(fieldname, [])}
                    for s in series]})
            elif "agg" in q or window is not None:
                out = db.aggregate(meas, fieldname, agg=q.get("agg", "mean"),
                                   tags=tags, t_min=t_min, t_max=t_max,
                                   group_by_tag=q.get("group_by"),
                                   window_ns=window,
                                   use_rollups=use_rollups)
                self._send(200, {"result": out})
            elif "field" in q:
                series = db.select(meas, [fieldname], tags, t_min, t_max)
                self._send(200, {"series": [
                    {"tags": s.tags, "times": s.times,
                     "values": s.values.get(fieldname, [])}
                    for s in series]})
            else:
                # no field param: all fields per series (events etc.)
                series = db.select(meas, None, tags, t_min, t_max)
                self._send(200, {"series": [
                    {"tags": s.tags, "times": s.times, "fields": s.values}
                    for s in series]})
        elif url.path == "/meta":
            what = q.get("what", "measurements")
            if what in ("query_cache", "data_version"):
                # checked BEFORE backend.db() resolves (and registers)
                # the name: these metas are hit programmatically per
                # cache check, and an unknown database must 404, not
                # mint a database (+ engine) per caller-supplied name
                name = q.get("db", "global")
                if not self._known_db(name):
                    self._send(404, {"error": f"unknown database "
                                              f"{name!r}"})
                elif what == "query_cache":
                    self._send(200, {"query_cache": self.router.backend
                                     .query_engine(name).cache_info()})
                else:
                    # the query-cache ingest watermark (repro.core.query):
                    # lets a *local* engine cache results over this remote
                    self._send(200, {"version": self.router.backend
                                     .db(name).data_version(
                                         q.get("m") or None)})
                return
            name = q.get("db", "global")
            if not self._known_db(name):
                self._send(404, {"error": f"unknown database {name!r}"})
                return
            db = self.router.backend.db(name)
            if what == "measurements":
                self._send(200, {"values": db.measurements()})
            elif what == "fields":
                self._send(200, {"values": db.field_keys(q.get("m", ""))})
            elif what == "tags":
                self._send(200, {"values": db.tag_values(q.get("m", ""),
                                                         q.get("tag", ""))})
            elif what == "rollup_config":
                cfg = getattr(db, "rollup_config", None)
                self._send(200, {"rollup_config": None if cfg is None else {
                    "tiers_ns": list(cfg.tiers_ns),
                    "max_age_ns": cfg.max_age_ns,
                    "sketch_fields": cfg.sketch_field_map(),
                    "sketch_rel_acc": cfg.sketch_rel_acc,
                    "sketch_max_bins": cfg.sketch_max_bins}})
            elif what == "rollups":
                # the aggregate family this instance serves: scalar aggs,
                # tier layout, and per-measurement quantile-sketch opt-in
                # (gamma/bin cap) — what HttpQueryClient validates a
                # requested agg against before paying a round trip
                cfg = getattr(db, "rollup_config", None)
                self._send(200, {"rollups": {
                    "aggs": list(ROLLUP_AGGS),
                    "quantiles": "pNN",
                    "tiers_ns": list(cfg.tiers_ns) if cfg else [],
                    "sketch": None if cfg is None else {
                        "fields": cfg.sketch_field_map(),
                        "rel_acc": cfg.sketch_rel_acc,
                        "gamma": cfg.sketch_gamma,
                        "max_bins": cfg.sketch_max_bins}}})
            elif what == "point_count":
                self._send(200, {"count": db.point_count()})
            elif what == "stored_points":
                self._send(200, {"count": db.stored_points()})
            elif what == "rollup_window_count":
                tier = int(q["tier_ns"]) if "tier_ns" in q else None
                tags = {k[4:]: v for k, v in q.items()
                        if k.startswith("tag_")}
                self._send(200, {"count": db.rollup_window_count(
                    q.get("m", ""), q.get("field", "value"), tags=tags,
                    tier_ns=tier)})
            elif what == "persistence":
                self._send(200,
                           {"persistence":
                            self.router.backend.persistence_stats()})
            elif what == "analysis":
                engine = self.router.analysis
                self._send(200, {"analysis": engine.engine_stats()
                                 if engine is not None else None})
            elif what == "ingest":
                # binary ingest plane shed/queue counters
                # (repro.core.ingest); null when no plane is attached
                ingest = self.router.ingest
                self._send(200, {"ingest": ingest.stats()
                                 if ingest is not None else None})
            elif what == "cold":
                # compressed cold tier (repro.core.coldstore): chunk /
                # compression / corruption counters plus the sealed time
                # span; null when no cold tier is configured
                view = getattr(db, "cold_view", None)
                view = view() if view is not None else None
                if view is None and getattr(db, "shards", None):
                    for sdb in db.shards:
                        view = sdb.cold_view()
                        if view is not None:
                            break
                rng = db.cold_time_range(q.get("m") or None) \
                    if hasattr(db, "cold_time_range") else None
                self._send(200, {"cold": None if view is None else dict(
                    view.stats(), time_range=list(rng) if rng else None)})
            elif what == "roofline":
                # the ROOFLINE perf group as this instance resolves it
                # (formula text a QuerySpec would embed), plus the latest
                # calibration point, if any ("_calib" marker convention)
                from repro.core.marker import roofline_peaks
                from repro.core.perf_groups import GROUPS
                grp = GROUPS["ROOFLINE"]
                peaks = roofline_peaks(db)
                self._send(200, {"roofline": {
                    "metrics": dict(sorted(grp.metrics)),
                    "calibrated": None if peaks is None else
                    {"peak_flops": peaks[0], "peak_bw": peaks[1]}}})
            else:
                self._send(400, {"error": f"unknown meta {what!r}"})
        elif url.path == "/alerts":
            dbname = q.get("db", "global")
            if not self._known_db(dbname):
                self._send(404, {"error": f"unknown database {dbname!r}"})
                return
            engine = self.router.analysis
            if engine is not None:
                engine.flush()      # read-your-writes for fresh ingest
            alerts = load_alerts(
                self.router.backend.db(dbname),
                jobid=q.get("jobid"), host=q.get("host"),
                rule=q.get("rule"), state=q.get("state", "all"))
            self._send(200, {"alerts": [a.to_dict() for a in alerts]})
        elif url.path.startswith("/jobs/") and url.path.endswith("/report"):
            jid = urllib.parse.unquote(url.path[len("/jobs/"):
                                                -len("/report")])
            engine = self.router.analysis
            if engine is not None:
                report = engine.flush().job_report(jid)
            else:
                dbname = q.get("db", "global")
                if not self._known_db(dbname):
                    self._send(404, {"error": f"unknown database "
                                              f"{dbname!r}"})
                    return
                report = load_job_report(
                    self.router.backend.db(dbname), jid)
            if report is None:
                self._send(404, {"error": f"no report for job {jid!r}"})
            else:
                self._send(200, {"report": report})
        else:
            self._send(404, {"error": "not found"})

    def do_POST(self):
        url = urllib.parse.urlparse(self.path)
        try:
            body = self._body()
        except _PayloadTooLarge as e:
            # the oversized body was never read off the socket, so this
            # connection cannot be reused for a next request
            self.close_connection = True
            self._send(413, {"error": str(e),
                             "max_body_bytes": self.max_body_bytes})
            return
        try:
            if url.path == "/write":
                res = self.router.write_lines(body.decode())
                # partial-write semantics: 200 reports per-line errors
                # alongside the written count; only a batch where
                # *nothing* parsed is a 400
                code = 400 if res["errors"] and not res["written"] else 200
                self._send(code, res)
            elif url.path == "/job/start":
                d = json.loads(body)
                self.router.job_start(d["jobid"], d.get("user", "unknown"),
                                      d.get("hosts", []), d.get("tags"))
                self._send(200, {"ok": True})
            elif url.path == "/job/end":
                d = json.loads(body)
                self.router.job_end(d["jobid"])
                self._send(200, {"ok": True})
            elif url.path == "/query/v2":
                from repro.core.query import (QuerySpec,
                                              encode_plan_partials)
                q = dict(urllib.parse.parse_qsl(url.query,
                                                keep_blank_values=True))
                d = json.loads(body)
                spec = QuerySpec.from_dict(d["spec"])
                name = q.get("db", d.get("db", "global"))
                if not self._known_db(name):
                    # like /admin/snapshot: a caller-supplied name must
                    # not register a fresh database + engine per request
                    # (a remote-fillable leak)
                    self._send(404, {"error": f"unknown database "
                                              f"{name!r}"})
                    return
                engine = self.router.backend.query_engine(name)
                if d.get("mode") == "partials":
                    # the pushdown half: this instance plans against its
                    # own tiers/retention and ships mergeable partials
                    windowed = spec.window_ns is not None
                    collected = engine.collect(spec)
                    self._send(200, {
                        "windowed": windowed,
                        "inputs": encode_plan_partials(collected,
                                                       windowed)})
                else:
                    res = engine.query(spec)
                    self._send(200, {"result": res.to_dict(),
                                     "meta": res.meta})
            elif url.path == "/admin/snapshot":
                # operator trigger: snapshot + compact one database (the
                # ?db= param) or every persisted database
                q = dict(urllib.parse.parse_qsl(url.query,
                                                keep_blank_values=True))
                backend = self.router.backend
                name = q.get("db")
                if not backend.persistence_stats().get("enabled"):
                    self._send(409, {"error": "persistence not enabled "
                                              "(no persist_dir)"})
                elif name is not None and \
                        name not in backend.databases():
                    # a typo'd name must not silently register a fresh
                    # empty database (and its on-disk WAL directories)
                    self._send(404, {"error": f"unknown database "
                                              f"{name!r}"})
                else:
                    self._send(200, {"snapshots": backend.snapshot(name)})
            else:
                self._send(404, {"error": "not found"})
        except Exception as e:                      # noqa: BLE001
            self._send(400, {"error": str(e)})


class _LMSThreadingHTTPServer(ThreadingHTTPServer):
    # stdlib default backlog is 5: a burst of connects from a few dozen
    # concurrent agents overflows the accept queue and the kernel resets
    # the excess.  Match the binary ingest plane's listen(128).
    request_queue_size = 128


def make_server(router: MetricsRouter, host: str = "127.0.0.1",
                port: int = 0,
                max_body_bytes: int = DEFAULT_MAX_BODY_BYTES
                ) -> ThreadingHTTPServer:
    """Create (but do not start) the HTTP endpoint; port=0 picks a free one."""
    handler = type("BoundHandler", (LMSRequestHandler,),
                   {"router": router,
                    "max_body_bytes": int(max_body_bytes)})
    return _LMSThreadingHTTPServer((host, port), handler)


class LMSHttpServer:
    """Server lifecycle helper (background thread)."""

    def __init__(self, router: MetricsRouter, host: str = "127.0.0.1",
                 port: int = 0,
                 max_body_bytes: int = DEFAULT_MAX_BODY_BYTES):
        self.httpd = make_server(router, host, port, max_body_bytes)
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)

    @property
    def url(self) -> str:
        h, p = self.httpd.server_address[:2]
        return f"http://{h}:{p}"

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self.httpd.shutdown()
        self.httpd.server_close()
        # bounded: serve_forever returns promptly after shutdown(), but
        # a wedged handler must not hang teardown forever
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


class HttpSink:
    """Batched line-protocol POST client (forward agent / CLI transport)."""

    def __init__(self, url: str, db: str = "global", timeout_s: float = 5.0):
        self.url = url.rstrip("/")
        self.db = db
        self.timeout_s = timeout_s

    def write(self, points):
        if isinstance(points, Point):
            points = [points]
        data = encode_batch(points).encode()
        req = urllib.request.Request(
            f"{self.url}/write?db={self.db}", data=data, method="POST",
            headers={"Content-Type": "text/plain"})
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            return r.status

    def job_start(self, jobid: str, user: str, hosts: list,
                  tags: Optional[dict] = None):
        self._post_json("/job/start", {"jobid": jobid, "user": user,
                                       "hosts": hosts, "tags": tags or {}})

    def job_end(self, jobid: str):
        self._post_json("/job/end", {"jobid": jobid})

    def _post_json(self, path: str, payload: dict):
        req = urllib.request.Request(
            self.url + path, data=json.dumps(payload).encode(),
            method="POST", headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            return r.status


class HttpQueryClient:
    """Database-shaped query surface over a remote LMS ``/query`` endpoint.

    Exposes the partials protocol (``aggregate_partials`` /
    ``rollup_window_partials``) plus ``select``/``aggregate``/meta lookups,
    so an instance can stand in for a local ``Database`` inside a
    ``repro.core.shard.FederatedQuery`` — scatter-gather across multiple
    LMS router instances, merged with exact WindowAgg semantics.

    ``select`` fetches one field per request (the ``/query`` series form is
    single-field); pass ``fields=[name]``.
    """

    # FederatedQuery fans remote backends out concurrently (a federated
    # query costs ~the slowest instance, not the sum of round-trips)
    is_remote = True

    def __init__(self, url: str, db: str = "global", timeout_s: float = 5.0):
        self.url = url.rstrip("/")
        self.db = db
        self.timeout_s = timeout_s
        self._rollup_config = _UNSET
        self._rollups_meta = _UNSET

    @property
    def rollup_config(self):
        """The remote database's rollup layout (fetched once, cached) —
        lets rollup-aware readers (dashboards, rule evaluation) treat a
        remote instance exactly like a local database.  Sketch keys are
        read with ``.get`` so older servers (plain tiers/max-age form)
        still reconstruct."""
        if self._rollup_config is _UNSET:
            d = self._get("/meta", {"db": self.db,
                                    "what": "rollup_config"})["rollup_config"]
            from repro.core.rollup import RollupConfig
            self._rollup_config = None if d is None else RollupConfig(
                tiers_ns=tuple(d["tiers_ns"]), max_age_ns=d["max_age_ns"],
                sketch_fields=d.get("sketch_fields") or (),
                sketch_rel_acc=d.get("sketch_rel_acc", 0.01),
                sketch_max_bins=d.get("sketch_max_bins", 2048))
        return self._rollup_config

    def rollups_meta(self):
        """``/meta?what=rollups`` — the aggregate family the remote
        serves — fetched once and cached; None against an older server
        that predates the endpoint (validation is then skipped)."""
        if self._rollups_meta is _UNSET:
            try:
                self._rollups_meta = self._get(
                    "/meta", {"db": self.db, "what": "rollups"})["rollups"]
            except ValueError:
                self._rollups_meta = None
        return self._rollups_meta

    def _check_agg(self, agg: str, measurement: str, field: str):
        """Fail fast on an agg the remote cannot serve — a clear local
        ValueError instead of a remote 500/empty answer.  Scalar aggs are
        checked against the served list; quantiles additionally require
        the (measurement, field) to be sketch-enabled remotely."""
        meta = self.rollups_meta()
        if meta is None:            # pre-family server: no validation
            return
        if quantile_of(agg) is None:
            if agg not in meta.get("aggs", SCALAR_AGGS):
                raise ValueError(
                    f"agg {agg!r} is not served by {self.url} "
                    f"(served: {meta.get('aggs')})")
            return
        sketch = meta.get("sketch")
        fields = (sketch or {}).get("fields", {}).get(measurement)
        if fields != "*" and (not fields or field not in fields):
            raise ValueError(
                f"agg {agg!r} needs a quantile sketch on "
                f"{measurement}.{field} at {self.url}; the remote "
                f"sketches {((sketch or {}).get('fields')) or 'nothing'} "
                f"— opt in via RollupConfig(sketch_fields=...)")

    def _get(self, path: str, params: dict) -> dict:
        qs = urllib.parse.urlencode(
            {k: v for k, v in params.items() if v is not None})
        try:
            with urllib.request.urlopen(f"{self.url}{path}?{qs}",
                                        timeout=self.timeout_s) as r:
                return json.loads(r.read())
        except urllib.error.HTTPError as e:
            # surface the server's error (e.g. an unservable forced-rollup
            # window) as the same ValueError the local path raises
            try:
                msg = json.loads(e.read()).get("error", str(e))
            except Exception:               # noqa: BLE001
                msg = str(e)
            raise ValueError(f"remote query failed: {msg}") from None

    def _post(self, path: str, payload: dict) -> dict:
        req = urllib.request.Request(
            f"{self.url}{path}", data=json.dumps(payload).encode(),
            method="POST", headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
                return json.loads(r.read())
        except urllib.error.HTTPError as e:
            try:
                msg = json.loads(e.read()).get("error", str(e))
            except Exception:               # noqa: BLE001
                msg = str(e)
            raise ValueError(f"remote query failed: {msg}") from None

    # -- derived-metric query engine (repro.core.query) -----------------------

    def query_partials(self, spec) -> dict:
        """Whole-spec pushdown: one ``POST /query/v2`` carrying the spec;
        the remote plans against its own tiers/retention and returns
        *mergeable* per-input ``WindowAgg`` partials — no raw series
        cross the wire.  This is what a ``FederatedQuery`` /
        ``QueryEngine`` calls when this client is a backend."""
        from repro.core.query import decode_plan_partials
        resp = self._post("/query/v2", {"db": self.db, "mode": "partials",
                                        "spec": spec.to_dict()})
        return decode_plan_partials(resp["inputs"], resp["windowed"])

    def query(self, spec):
        """Execute a full spec remotely (``mode=result``): planned,
        cached and finalized server-side — repeated dashboard-shape
        queries hit the remote's watermark-keyed cache."""
        from repro.core.query import QueryResult
        resp = self._post("/query/v2", {"db": self.db, "mode": "result",
                                        "spec": spec.to_dict()})
        return QueryResult.from_dict(resp["result"], resp.get("meta"))

    def data_version(self, measurement=None) -> int:
        """The remote ingest watermark — lets a local engine cache
        results over this remote (one cheap ``/meta`` round trip per
        cache check instead of re-running the query)."""
        return self._get("/meta", {"db": self.db, "what": "data_version",
                                   "m": measurement})["version"]

    def _query_params(self, measurement, field, tags, t_min, t_max,
                      group_by_tag, window_ns, use_rollups="auto") -> dict:
        params = {"db": self.db, "m": measurement, "field": field,
                  "t_min": t_min, "t_max": t_max, "group_by": group_by_tag,
                  "window_ns": window_ns}
        if use_rollups != "auto":
            params["rollups"] = "force" if use_rollups is True else "raw"
        for k, v in (tags or {}).items():
            params[f"tag_{k}"] = v
        return params

    def aggregate_partials(self, measurement: str, field: str, *,
                           tags: Optional[dict] = None,
                           t_min: Optional[int] = None,
                           t_max: Optional[int] = None,
                           group_by_tag: Optional[str] = None,
                           window_ns: Optional[int] = None,
                           use_rollups: object = "auto") -> dict:
        params = self._query_params(measurement, field, tags, t_min, t_max,
                                    group_by_tag, window_ns, use_rollups)
        params["partials"] = "1"
        resp = self._get("/query", params)
        return decode_partials(resp["partials"], resp["windowed"])

    def rollup_window_partials(self, measurement: str, field: str, *,
                               tags: Optional[dict] = None,
                               t_min: Optional[int] = None,
                               t_max: Optional[int] = None,
                               group_by_tag: Optional[str] = None,
                               window_ns: Optional[int] = None) -> dict:
        params = self._query_params(measurement, field, tags, t_min, t_max,
                                    group_by_tag, window_ns)
        params["partials"] = "rollup"
        resp = self._get("/query", params)
        return decode_partials(resp["partials"], resp["windowed"])

    def aggregate(self, measurement: str, field: str, *, agg: str = "mean",
                  tags: Optional[dict] = None, t_min: Optional[int] = None,
                  t_max: Optional[int] = None,
                  group_by_tag: Optional[str] = None,
                  window_ns: Optional[int] = None,
                  use_rollups: object = "auto"):
        self._check_agg(agg, measurement, field)
        merged = self.aggregate_partials(
            measurement, field, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=group_by_tag, window_ns=window_ns,
            use_rollups=use_rollups)
        if window_ns is None:
            return finalize_scalar(merged, agg)
        return finalize_windowed(merged, agg)

    def select(self, measurement: str, fields: Optional[list] = None,
               tags: Optional[dict] = None, t_min: Optional[int] = None,
               t_max: Optional[int] = None) -> list:
        if fields is not None and len(fields) != 1:
            raise ValueError("HttpQueryClient.select takes one field per "
                             f"request (or None for all), got {fields!r}")
        fieldname = fields[0] if fields else None
        params = self._query_params(measurement, fieldname, tags, t_min,
                                    t_max, None, None)
        resp = self._get("/query", params)
        if fieldname is None:       # all-fields form (events etc.)
            return [Series(measurement, s["tags"], s["times"], s["fields"])
                    for s in resp["series"]]
        return [Series(measurement, s["tags"], s["times"],
                       {fieldname: s["values"]})
                for s in resp["series"]]

    def rollup_aggregate(self, measurement: str, field: str, *,
                         agg: str = "mean", tags: Optional[dict] = None,
                         t_min: Optional[int] = None,
                         t_max: Optional[int] = None,
                         group_by_tag: Optional[str] = None,
                         window_ns: Optional[int] = None):
        self._check_agg(agg, measurement, field)
        return finalize_windowed(self.rollup_window_partials(
            measurement, field, tags=tags, t_min=t_min, t_max=t_max,
            group_by_tag=group_by_tag, window_ns=window_ns), agg)

    def rollup_series(self, measurement: str, field: str, *,
                      agg: str = "mean", tags: Optional[dict] = None,
                      window_ns: Optional[int] = None,
                      t_min: Optional[int] = None,
                      t_max: Optional[int] = None) -> list:
        self._check_agg(agg, measurement, field)
        params = self._query_params(measurement, field, tags, t_min, t_max,
                                    None, window_ns)
        params["rollup_series"] = "1"
        params["agg"] = agg
        resp = self._get("/query", params)
        return [Series(measurement, s["tags"], s["times"],
                       {field: s["values"]})
                for s in resp["series"]]

    # -- analysis surface (repro.core.analysis) ------------------------------

    def alerts(self, *, jobid: Optional[str] = None,
               rule: Optional[str] = None, host: Optional[str] = None,
               state: str = "all") -> list:
        """Alert episodes from the remote instance's persisted ``analysis``
        measurement, as :class:`repro.core.analysis.Alert` objects —
        concatenable across instances exactly like ``load_alerts`` over a
        federated view."""
        params = {"db": self.db, "jobid": jobid, "rule": rule,
                  "host": host, "state": state}
        return [Alert.from_dict(d)
                for d in self._get("/alerts", params)["alerts"]]

    def job_report(self, jobid: str) -> Optional[dict]:
        """The remote instance's footprint report for one job, or None
        when it has none (404)."""
        try:
            return self._get(
                f"/jobs/{urllib.parse.quote(jobid, safe='')}/report",
                {"db": self.db})["report"]
        except ValueError:
            return None

    def rollup_window_count(self, measurement: str, field: str, *,
                            tags: Optional[dict] = None,
                            tier_ns: Optional[int] = None) -> int:
        params = {"db": self.db, "what": "rollup_window_count",
                  "m": measurement, "field": field, "tier_ns": tier_ns}
        for k, v in (tags or {}).items():
            params[f"tag_{k}"] = v
        return self._get("/meta", params)["count"]

    def point_count(self) -> int:
        return self._get("/meta", {"db": self.db,
                                   "what": "point_count"})["count"]

    def stored_points(self) -> int:
        return self._get("/meta", {"db": self.db,
                                   "what": "stored_points"})["count"]

    def measurements(self) -> list:
        return self._get("/meta", {"db": self.db,
                                   "what": "measurements"})["values"]

    def field_keys(self, measurement: str) -> list:
        return self._get("/meta", {"db": self.db, "what": "fields",
                                   "m": measurement})["values"]

    def tag_values(self, measurement: str, tag: str) -> list:
        return self._get("/meta", {"db": self.db, "what": "tags",
                                   "m": measurement, "tag": tag})["values"]
