"""HTTP face of the LMS (paper §III: "the communication protocol inside the
whole system (HTTP) is commonly available on all machines").

Server: mimics the InfluxDB 1.x write API plus the router's job-signal
endpoint, so any existing collector that can POST line protocol (Diamond,
curl cronjobs, Ganglia pull-proxies in the paper) integrates unchanged:

    POST /write?db=global           body: line protocol (batched)
    POST /job/start                 body: JSON {jobid, user, hosts, tags}
    POST /job/end                   body: JSON {jobid}
    GET  /ping
    GET  /query?db=&m=&field=&agg=  simple JSON query (dashboards/tests);
                                    &window_ns= adds windowed aggregation
                                    served from the rollup tiers
    GET  /dbs                       list databases

Client: :class:`HttpSink` POSTs batched lines — the transport used by the
out-of-process ``usermetric_cli`` and by forward agents.
"""

from __future__ import annotations

import json
import threading
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.core.line_protocol import Point, encode_batch
from repro.core.router import MetricsRouter


class LMSRequestHandler(BaseHTTPRequestHandler):
    router: MetricsRouter = None      # set by make_server

    def log_message(self, fmt, *args):   # quiet
        pass

    def _send(self, code: int, payload: Optional[dict] = None):
        body = json.dumps(payload or {}).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> bytes:
        n = int(self.headers.get("Content-Length", 0))
        return self.rfile.read(n) if n else b""

    def do_GET(self):
        try:
            self._do_get()
        except Exception as e:                      # noqa: BLE001
            # bad query params (window_ns=abc, unknown agg) must produce a
            # 400, not a dropped connection
            self._send(400, {"error": str(e)})

    def _do_get(self):
        url = urllib.parse.urlparse(self.path)
        q = dict(urllib.parse.parse_qsl(url.query))
        if url.path == "/ping":
            self._send(204)
        elif url.path == "/dbs":
            self._send(200, {"databases": self.router.backend.databases()})
        elif url.path == "/query":
            db = self.router.backend.db(q.get("db", "global"))
            meas = q.get("m", "")
            fieldname = q.get("field", "value")
            tags = {k[4:]: v for k, v in q.items() if k.startswith("tag_")}
            if "agg" in q or "window_ns" in q:
                window = int(q["window_ns"]) if "window_ns" in q else None
                out = db.aggregate(meas, fieldname, agg=q.get("agg", "mean"),
                                   tags=tags,
                                   group_by_tag=q.get("group_by"),
                                   window_ns=window)
                self._send(200, {"result": out})
            else:
                series = db.select(meas, [fieldname], tags)
                self._send(200, {"series": [
                    {"tags": s.tags, "times": s.times,
                     "values": s.values.get(fieldname, [])}
                    for s in series]})
        else:
            self._send(404, {"error": "not found"})

    def do_POST(self):
        url = urllib.parse.urlparse(self.path)
        body = self._body()
        try:
            if url.path == "/write":
                n = self.router.write_lines(body.decode())
                self._send(204 if n else 200, {"written": n})
            elif url.path == "/job/start":
                d = json.loads(body)
                self.router.job_start(d["jobid"], d.get("user", "unknown"),
                                      d.get("hosts", []), d.get("tags"))
                self._send(200, {"ok": True})
            elif url.path == "/job/end":
                d = json.loads(body)
                self.router.job_end(d["jobid"])
                self._send(200, {"ok": True})
            else:
                self._send(404, {"error": "not found"})
        except Exception as e:                      # noqa: BLE001
            self._send(400, {"error": str(e)})


def make_server(router: MetricsRouter, host: str = "127.0.0.1",
                port: int = 0) -> ThreadingHTTPServer:
    """Create (but do not start) the HTTP endpoint; port=0 picks a free one."""
    handler = type("BoundHandler", (LMSRequestHandler,), {"router": router})
    return ThreadingHTTPServer((host, port), handler)


class LMSHttpServer:
    """Server lifecycle helper (background thread)."""

    def __init__(self, router: MetricsRouter, host: str = "127.0.0.1",
                 port: int = 0):
        self.httpd = make_server(router, host, port)
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)

    @property
    def url(self) -> str:
        h, p = self.httpd.server_address[:2]
        return f"http://{h}:{p}"

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self.httpd.shutdown()
        self.httpd.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


class HttpSink:
    """Batched line-protocol POST client (forward agent / CLI transport)."""

    def __init__(self, url: str, db: str = "global", timeout_s: float = 5.0):
        self.url = url.rstrip("/")
        self.db = db
        self.timeout_s = timeout_s

    def write(self, points):
        if isinstance(points, Point):
            points = [points]
        data = encode_batch(points).encode()
        req = urllib.request.Request(
            f"{self.url}/write?db={self.db}", data=data, method="POST",
            headers={"Content-Type": "text/plain"})
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            return r.status

    def job_start(self, jobid: str, user: str, hosts: list,
                  tags: Optional[dict] = None):
        self._post_json("/job/start", {"jobid": jobid, "user": user,
                                       "hosts": hosts, "tags": tags or {}})

    def job_end(self, jobid: str):
        self._post_json("/job/end", {"jobid": jobid})

    def _post_json(self, path: str, payload: dict):
        req = urllib.request.Request(
            self.url + path, data=json.dumps(payload).encode(),
            method="POST", headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            return r.status
