"""LIKWID Monitoring Stack (LMS), TPU-native — the paper's contribution.

``MonitoringStack`` wires the components of paper Fig. 1 together for the
common case (in-process stack inside a training/serving job); every
component also works standalone, which is the paper's headline design goal
("components can be used as a complete stack, standalone or in parts").
"""

from __future__ import annotations

import threading
import time
import uuid
from typing import Optional

from repro.core.analysis import (
    ANALYSIS_MEASUREMENT, Alert, AnalysisEngine, DEFAULT_TREE, Finding,
    RooflineAnalyzer, RooflineResult, StreamAnalyzer, ThresholdRule,
    classify_job, default_rules, evaluate_rules_on_db, load_alerts,
    load_job_report)
from repro.core.dashboard import DashboardAgent
from repro.core.host_agent import HostAgent
from repro.core.httpd import HttpSink, LMSHttpServer
from repro.core.jobs import JobInfo, JobRegistry
from repro.core.line_protocol import (Point, decode_batch, decode_line,
                                      encode_batch, encode_point, now_ns)
from repro.core.marker import (CALIB_REGION, MARKER_MEASUREMENT,
                               MarkerSession, calibrate, low_roofline_rule,
                               register_roofline_group, roofline_group_text,
                               roofline_peaks, roofline_spec)
from repro.core.perf_groups import (GROUPS, HBM_BW, ICI_BW, PEAK_FLOPS,
                                    CompiledFormula, PerfGroup,
                                    compile_formula, derive_all,
                                    formula_for, parse_group,
                                    register_group)
from repro.core.query import (QueryEngine, QueryResult, QuerySpec,
                              derived_rollup_series, make_plan)
from repro.core.fingerprint import (FINGERPRINT_KIND, fingerprint_outliers,
                                    fingerprint_point, job_fingerprint,
                                    load_fingerprints)
from repro.core.rollup import (DEFAULT_TIERS_NS, QUANTILE_AGGS, ROLLUP_AGGS,
                               QuantileSketch, RollupConfig, SeriesRollups,
                               SketchAgg, WindowAgg, known_agg, quantile_of)
from repro.core.coldstore import ColdStore, ColdView
from repro.core.httpd import HttpQueryClient
from repro.core.ingest import BinarySink, IngestServer
from repro.core.router import MetricsRouter
from repro.core.shard import FederatedQuery, ShardedDatabase, shard_index
from repro.core.tsdb import Database, TSDBServer
from repro.core.usermetric import UserMetric
from repro.core.wal import DurableStore, SegmentedWal, import_legacy_jsonl

__all__ = [
    "ANALYSIS_MEASUREMENT", "Alert", "AnalysisEngine", "BinarySink",
    "CALIB_REGION", "MARKER_MEASUREMENT", "MarkerSession",
    "ColdStore", "ColdView", "CompiledFormula",
    "DEFAULT_TIERS_NS", "DEFAULT_TREE", "Database", "DashboardAgent",
    "DurableStore", "FederatedQuery", "Finding", "GROUPS", "HBM_BW",
    "HostAgent", "IngestServer", "SegmentedWal", "import_legacy_jsonl",
    "HttpQueryClient", "HttpSink", "ICI_BW", "JobInfo", "JobRegistry",
    "LMSHttpServer", "MetricsRouter", "MonitoringStack", "PEAK_FLOPS",
    "PerfGroup", "Point", "QueryEngine", "QueryResult", "QuerySpec",
    "FINGERPRINT_KIND", "QUANTILE_AGGS", "QuantileSketch",
    "ROLLUP_AGGS", "RollupConfig",
    "RooflineAnalyzer", "RooflineResult", "SeriesRollups", "SketchAgg",
    "ShardedDatabase", "StreamAnalyzer", "TSDBServer", "ThresholdRule",
    "UserMetric", "WindowAgg", "calibrate", "classify_job",
    "compile_formula",
    "decode_batch", "decode_line", "default_rules", "derive_all",
    "derived_rollup_series", "encode_batch", "encode_point",
    "evaluate_rules_on_db", "fingerprint_outliers", "fingerprint_point",
    "formula_for", "job_fingerprint", "known_agg", "load_alerts",
    "load_fingerprints", "load_job_report", "low_roofline_rule",
    "make_plan", "now_ns",
    "parse_group", "quantile_of", "register_group",
    "register_roofline_group", "roofline_group_text", "roofline_peaks",
    "roofline_spec", "shard_index",
]


class MonitoringStack:
    """The full Fig. 1 stack, in-process: TSDB + router + agents + analysis.

    Usage::

        stack = MonitoringStack.inprocess(out_dir="runs/lms")
        with stack.job("train-1", user="alice", hosts=hosts,
                       tags={"arch": "lms-demo"}) as job:
            um = stack.usermetric(host=hosts[0])
            agent = stack.host_agent(hosts[0])
            ... per step: agent.collect_step(...), um.metric(...)
        stack.dashboards.write_dashboard(job)
    """

    def __init__(self, *, per_job_db: bool = True, per_user_db: bool = False,
                 rules: Optional[list] = None, out_dir: str = "lms_out",
                 persist_dir: Optional[str] = None, fsync: str = "batch",
                 recover: bool = True,
                 serve_http: bool = False, serve_ingest: bool = False,
                 shards: int = 1, cold_tier: bool = False,
                 rollup_config: Optional[RollupConfig] = RollupConfig()):
        # cold_tier=True (requires persist_dir): retention seals expired
        # raw history into compressed immutable chunks instead of
        # dropping it — months of raw data at a fraction of the bytes,
        # still answering every query (repro.core.coldstore)
        # rollup_config: e.g. RollupConfig(sketch_fields={"hpm": "*"})
        # opts fields into quantile sketches so p50/p95/p99 are served
        # from the rollup tiers; the default carries no sketches
        self.backend = TSDBServer(persist_dir=persist_dir, shards=shards,
                                  fsync=fsync, cold=cold_tier,
                                  rollup_config=rollup_config)
        # crash-safe durability: a restarted stack keeps serving the job
        # histories it had already collected (repro.core.wal)
        self.recovery_stats = self.backend.load_persisted() \
            if (persist_dir and recover) else {}
        self.router = MetricsRouter(self.backend, per_job_db=per_job_db,
                                    per_user_db=per_user_db)
        self._finding_cbs = []
        # continuous analysis engine (repro.core.analysis): evaluates the
        # rollup windows on a background thread (O(1) on the ingest path),
        # persists alert lifecycle + job reports into the TSDB, and closes
        # a job's state through the registry end hook
        self.analysis = AnalysisEngine(
            rules if rules is not None else default_rules(),
            on_finding=self._on_finding, backend=self.backend,
            db_name=self.router.global_db)
        self.analyzer = self.analysis       # pre-engine name, kept working
        self.router.subscribe(self.analysis)
        self.router.analysis = self.analysis
        self.router.jobs.on_end(self.analysis.on_job_end)
        # restart: recovered analysis series bring the alert state back —
        # open episodes continue instead of re-firing
        self.analysis_recovery = self.analysis.recover() \
            if (persist_dir and recover) else {}
        self.dashboards = DashboardAgent(self.backend, out_dir=out_dir)
        self.roofline = RooflineAnalyzer()
        self.http: Optional[LMSHttpServer] = None
        if serve_http:
            self.http = LMSHttpServer(self.router).start()
        # binary ingest plane (repro.core.ingest), served alongside the
        # HTTP endpoint: persistent sockets, backpressure, shed frames
        self.ingest: Optional[IngestServer] = None
        if serve_ingest:
            self.ingest = IngestServer(self.router).start()

    @classmethod
    def inprocess(cls, **kw) -> "MonitoringStack":
        return cls(**kw)

    # -- findings fan-out ------------------------------------------------------

    def on_finding(self, cb):
        self._finding_cbs.append(cb)
        return cb

    def _on_finding(self, f: Finding):
        for cb in self._finding_cbs:
            try:
                cb(f)
            except Exception:
                pass

    # -- components --------------------------------------------------------------

    def usermetric(self, host: Optional[str] = None, **tags) -> UserMetric:
        return UserMetric(self.router, hostname=host,
                          default_tags=tags or None)

    def host_agent(self, hostname: str, **consts) -> HostAgent:
        return HostAgent(self.router, hostname, consts or None)

    def marker_session(self, host: Optional[str] = None,
                       **tags) -> MarkerSession:
        """A :class:`MarkerSession` (repro.core.marker) emitting through a
        fresh UserMetric into this stack — region points arrive as the
        ``marker`` measurement and get the live job's tags from the
        router like any other metric."""
        return self.usermetric(host=host, **tags).markers

    # -- job lifecycle --------------------------------------------------------------

    def job(self, job_id: Optional[str] = None, *, user: str = "user",
            hosts: Optional[list] = None, tags: Optional[dict] = None):
        stack = self
        job_id = job_id or uuid.uuid4().hex[:8]
        hosts = hosts or ["host0"]

        class _JobCtx:
            def __enter__(self):
                self.info = stack.router.job_start(job_id, user, hosts, tags)
                return self.info

            def __exit__(self, exc_type, exc, tb):
                stack.router.job_end(job_id)
                return False
        return _JobCtx()

    def findings(self) -> list:
        """Every fired alert (active + resolved), after a synchronous
        evaluation sweep — read-your-writes for callers that just
        ingested."""
        self.analysis.flush()
        return list(self.analysis.findings)

    def binary_sink(self, db: str = "global", **kw) -> "BinarySink":
        """A client for this stack's binary ingest plane (requires
        ``serve_ingest=True``); pass ``fallback=HttpSink(...)`` to add
        the HTTP line-path fallback."""
        if self.ingest is None:
            raise RuntimeError("stack was built without serve_ingest=True")
        return BinarySink(self.ingest.host, self.ingest.port, db=db, **kw)

    def close(self):
        self.analysis.close()
        if self.http:
            self.http.stop()
        if self.ingest:
            self.ingest.stop()
        self.backend.close()
