"""LIKWID performance groups, TPU-native (paper §V; hardware adaptation §2).

LIKWID abstracts HPM portability behind named *performance groups*: a group
lists the raw counter events to program and formulas for derived metrics.
TPUs expose no user MSRs; the raw "events" here come from the compiled XLA
artifact (cost/memory analysis, HLO collective parse) plus step wall-times —
see DESIGN.md §2 for the full source mapping.

Groups are defined in a LIKWID-like text format::

    GROUP FLOPS
    EVENTSET
      hlo_flops
      step_time_s
    METRICS
      gflops_per_s  hlo_flops / step_time_s / 1e9
      mfu           model_flops / step_time_s / PEAK_FLOPS

and evaluated with a tiny safe arithmetic evaluator (no eval()).
"""

from __future__ import annotations

import ast
import operator
from dataclasses import dataclass, field
from typing import Optional

# --------------------------------------------------------------------------
# Hardware constants (assignment: TPU v5e-class chip)
# --------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ per chip per direction)

HW_CONSTANTS = {
    "PEAK_FLOPS": PEAK_FLOPS,
    "HBM_BW": HBM_BW,
    "ICI_BW": ICI_BW,
}


# --------------------------------------------------------------------------
# Safe formula evaluation
# --------------------------------------------------------------------------

_BINOPS = {ast.Add: operator.add, ast.Sub: operator.sub,
           ast.Mult: operator.mul, ast.Div: operator.truediv,
           ast.Pow: operator.pow, ast.Mod: operator.mod}
_UNOPS = {ast.USub: operator.neg, ast.UAdd: operator.pos}
_FUNCS = {"min": min, "max": max, "abs": abs}


def eval_formula(expr: str, env: dict) -> float:
    """Evaluate an arithmetic expression over ``env`` (names -> numbers)."""
    def ev(node):
        if isinstance(node, ast.Expression):
            return ev(node.body)
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)):
                return float(node.value)
            raise ValueError(f"bad constant {node.value!r}")
        if isinstance(node, ast.Name):
            if node.id in env:
                return float(env[node.id])
            if node.id in HW_CONSTANTS:
                return HW_CONSTANTS[node.id]
            raise KeyError(node.id)
        if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
            return _BINOPS[type(node.op)](ev(node.left), ev(node.right))
        if isinstance(node, ast.UnaryOp) and type(node.op) in _UNOPS:
            return _UNOPS[type(node.op)](ev(node.operand))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in _FUNCS:
            return _FUNCS[node.func.id](*[ev(a) for a in node.args])
        raise ValueError(f"disallowed syntax: {ast.dump(node)}")
    return ev(ast.parse(expr, mode="eval"))


# --------------------------------------------------------------------------
# Group definitions
# --------------------------------------------------------------------------


@dataclass
class PerfGroup:
    name: str
    events: list                       # required raw event names
    metrics: list                      # (metric name, formula) pairs
    description: str = ""

    def derive(self, raw_events: dict, strict: bool = False) -> dict:
        """raw events -> derived metrics; missing events skip the metric."""
        out = {}
        for mname, formula in self.metrics:
            try:
                out[mname] = eval_formula(formula, raw_events)
            except (KeyError, ZeroDivisionError):
                if strict:
                    raise
        return out


def parse_group(text: str) -> PerfGroup:
    """Parse the LIKWID-like group format (GROUP/EVENTSET/METRICS)."""
    name, desc = "", ""
    events, metrics = [], []
    section = None
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("GROUP"):
            name = line.split(None, 1)[1].strip()
        elif line == "EVENTSET":
            section = "events"
        elif line == "METRICS":
            section = "metrics"
        elif line.startswith("DESC"):
            desc = line.split(None, 1)[1].strip()
        elif section == "events":
            events.append(line.split()[0])
        elif section == "metrics":
            parts = line.split(None, 1)
            if len(parts) == 2:
                metrics.append((parts[0], parts[1]))
    if not name:
        raise ValueError("group text missing GROUP header")
    return PerfGroup(name, events, metrics, desc)


# The built-in groups (TPU analogues of the paper's §V metric list).
_GROUP_TEXTS = [
    """
    GROUP FLOPS
    DESC floating point throughput and machine utilization (IPC analogue)
    EVENTSET
      hlo_flops
      model_flops
      step_time_s
    METRICS
      gflops_per_s        hlo_flops / step_time_s / 1e9
      hw_flops_util       hlo_flops / step_time_s / PEAK_FLOPS
      mfu                 model_flops / step_time_s / PEAK_FLOPS
      useful_flop_ratio   model_flops / hlo_flops
    """,
    """
    GROUP MEM
    DESC memory bandwidth and footprint
    EVENTSET
      hlo_bytes
      step_time_s
      hbm_bytes_in_use
    METRICS
      mem_gb_per_s        hlo_bytes / step_time_s / 1e9
      hbm_bw_util         hlo_bytes / step_time_s / HBM_BW
      hbm_used_gb         hbm_bytes_in_use / 1e9
    """,
    """
    GROUP ICI
    DESC interconnect (collective) traffic — the QPI/network analogue
    EVENTSET
      collective_bytes
      step_time_s
    METRICS
      ici_gb_per_s        collective_bytes / step_time_s / 1e9
      ici_bw_util         collective_bytes / step_time_s / ICI_BW
    """,
    """
    GROUP GOODPUT
    DESC end-to-end job progress (the "CPU load" analogue for a TPU job)
    EVENTSET
      step_time_s
      tokens_per_step
      data_wait_s
    METRICS
      tokens_per_s        tokens_per_step / step_time_s
      data_stall_frac     data_wait_s / step_time_s
      steps_per_s         1.0 / step_time_s
    """,
]

GROUPS = {g.name: g for g in (parse_group(t) for t in _GROUP_TEXTS)}


def available_groups() -> list:
    return sorted(GROUPS)


def derive_all(raw_events: dict) -> dict:
    """Run every group whose event set is (partially) satisfied."""
    out = {}
    for g in GROUPS.values():
        out.update(g.derive(raw_events))
    return out
