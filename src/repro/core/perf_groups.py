"""LIKWID performance groups, TPU-native (paper §V; hardware adaptation §2).

LIKWID abstracts HPM portability behind named *performance groups*: a group
lists the raw counter events to program and formulas for derived metrics.
TPUs expose no user MSRs; the raw "events" here come from the compiled XLA
artifact (cost/memory analysis, HLO collective parse) plus step wall-times —
see DESIGN.md §2 for the full source mapping.

Groups are defined in a LIKWID-like text format::

    GROUP FLOPS
    EVENTSET
      hlo_flops
      step_time_s
    METRICS
      gflops_per_s  hlo_flops / step_time_s / 1e9
      mfu           model_flops / step_time_s / PEAK_FLOPS

and evaluated with a tiny safe arithmetic evaluator (no eval()).
"""

from __future__ import annotations

import ast
import functools
import operator
from dataclasses import dataclass, field
from typing import Optional

from repro.core.rollup import quantile_of

# --------------------------------------------------------------------------
# Hardware constants (assignment: TPU v5e-class chip)
# --------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ per chip per direction)

HW_CONSTANTS = {
    "PEAK_FLOPS": PEAK_FLOPS,
    "HBM_BW": HBM_BW,
    "ICI_BW": ICI_BW,
}


# --------------------------------------------------------------------------
# Safe formula evaluation (compiled once, applied many times)
# --------------------------------------------------------------------------

_BINOPS = {ast.Add: operator.add, ast.Sub: operator.sub,
           ast.Mult: operator.mul, ast.Div: operator.truediv,
           ast.Pow: operator.pow, ast.Mod: operator.mod}
_UNOPS = {ast.USub: operator.neg, ast.UAdd: operator.pos}
_FUNCS = {"min": min, "max": max, "abs": abs}


def _build(node, names: list):
    """AST node -> ``fn(env) -> float`` closure (no AST walking at eval
    time).  Only the whitelisted arithmetic subset compiles; anything else
    raises ValueError at *compile* time.  ``names`` collects every bare
    identifier the formula references (first-seen order, deduplicated) —
    what the query planner turns into input columns."""
    if isinstance(node, ast.Expression):
        return _build(node.body, names)
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (int, float)) and \
                not isinstance(node.value, bool):
            c = float(node.value)
            return lambda env: c
        raise ValueError(f"bad constant {node.value!r}")
    if isinstance(node, ast.Name) or (
            isinstance(node, ast.Attribute) and
            isinstance(node.value, ast.Name)):
        # a bare identifier, or the query engine's cross-measurement
        # reference ``measurement.field`` (one dotted level) — both look
        # up ``env`` by their full spelling
        ident = node.id if isinstance(node, ast.Name) \
            else f"{node.value.id}.{node.attr}"
        if ident not in names:
            names.append(ident)

        def name_fn(env, ident=ident):
            if ident in env:
                return float(env[ident])
            if ident in HW_CONSTANTS:
                return HW_CONSTANTS[ident]
            raise KeyError(ident)
        return name_fn
    if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
        op = _BINOPS[type(node.op)]
        left, right = _build(node.left, names), _build(node.right, names)
        return lambda env: op(left(env), right(env))
    if isinstance(node, ast.UnaryOp) and type(node.op) in _UNOPS:
        op = _UNOPS[type(node.op)]
        operand = _build(node.operand, names)
        return lambda env: op(operand(env))
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        if node.func.id in _FUNCS:
            func = _FUNCS[node.func.id]
            args = [_build(a, names) for a in node.args]
            return lambda env: func(*[a(env) for a in args])
        if quantile_of(node.func.id) is not None and len(node.args) == 1 \
                and not node.keywords:
            # a quantile call over one identifier — p95(flops),
            # p99(hpm.step_time_s) — compiles to a *synthetic identifier*
            # "pNN(ident)".  The query planner reduces that input's
            # mergeable partials with the quantile agg and feeds the
            # result back through env; there is no constant fallback
            # (a quantile is data, never a HW constant).
            arg = node.args[0]
            if isinstance(arg, ast.Name):
                inner = arg.id
            elif isinstance(arg, ast.Attribute) and \
                    isinstance(arg.value, ast.Name):
                inner = f"{arg.value.id}.{arg.attr}"
            else:
                raise ValueError(
                    f"{node.func.id}() takes one field or "
                    f"measurement.field identifier")
            ident = f"{node.func.id}({inner})"
            if ident not in names:
                names.append(ident)

            def quantile_fn(env, ident=ident):
                if ident in env:
                    return float(env[ident])
                raise KeyError(ident)
            return quantile_fn
    raise ValueError(f"disallowed syntax: {ast.dump(node)}")


class CompiledFormula:
    """One parsed + compiled formula: a closure tree built once from the
    AST, then applied per evaluation — no re-parse, no AST walk.

    ``eval`` reproduces the historical ``eval_formula`` semantics exactly
    (env lookup first, then ``HW_CONSTANTS``, else ``KeyError``).
    ``eval_columns`` is the query engine's vectorized form: the same
    compiled closure applied across aligned window columns, with a ``None``
    hole wherever the scalar evaluation would have raised ``KeyError`` /
    ``ZeroDivisionError`` (missing input or domain error for that window).
    """

    __slots__ = ("expr", "names", "_fn")

    def __init__(self, expr: str):
        self.expr = expr
        names: list = []
        self._fn = _build(ast.parse(expr, mode="eval"), names)
        self.names = tuple(names)

    def eval(self, env: dict) -> float:
        return self._fn(env)

    def eval_columns(self, cols: dict, n: int) -> list:
        """Apply across ``n`` aligned windows.  ``cols`` maps input name ->
        value list of length ``n`` (``None`` holes where the window has no
        value for that input; names absent from ``cols`` entirely fall back
        to ``HW_CONSTANTS`` exactly like scalar evaluation).

        A window whose evaluation is unanswerable yields ``None``:
        missing input (KeyError) and domain errors — division by zero,
        overflow, or a complex result (``(a-b) ** 0.5`` with a < b) —
        must skip the window, never leak a non-float into query results
        or threshold comparisons."""
        fn = self._fn
        series = [(k, cols.get(k)) for k in self.names]
        out = []
        for i in range(n):
            env = {}
            for k, col in series:
                if col is not None:
                    v = col[i]
                    if v is not None:
                        env[k] = v
            try:
                v = fn(env)
            except (KeyError, ZeroDivisionError, OverflowError):
                v = None
            else:
                if isinstance(v, complex):
                    v = None
            out.append(v)
        return out


# Module-level parse cache: every PerfGroup.derive / query-engine plan
# compiles a given formula text exactly once per process.  Bounded (a
# remote /query/v2 spec carries caller-written formula text, so an
# unbounded cache would be a remote-fillable leak), thread-safe and
# LRU-by-recency — sustained distinct-formula traffic cannot evict the
# hot built-in group formulas that every collection tick derives.
# Parse errors are not cached, so a bad formula raises on every call,
# exactly like direct construction.
compile_formula = functools.lru_cache(maxsize=4096)(CompiledFormula)


def eval_formula(expr: str, env: dict) -> float:
    """Evaluate an arithmetic expression over ``env`` (names -> numbers).

    Compiles through the module-level cache, so repeated evaluation of the
    same formula (every collection tick, every query window) pays the
    parse exactly once."""
    return compile_formula(expr).eval(env)


# --------------------------------------------------------------------------
# Group definitions
# --------------------------------------------------------------------------


@dataclass
class PerfGroup:
    name: str
    events: list                       # required raw event names
    metrics: list                      # (metric name, formula) pairs
    description: str = ""

    def derive(self, raw_events: dict, strict: bool = False,
               skipped: Optional[list] = None) -> dict:
        """raw events -> derived metrics; missing events skip the metric.

        With ``strict=False`` a skipped metric is *recorded*, not silently
        swallowed: pass ``skipped`` (a list) to receive ``(metric_name,
        reason)`` pairs — ``reason`` names the missing event or the
        division by zero.  Formulas are compiled once per process
        (module-level parse cache in :func:`compile_formula`).
        """
        out = {}
        for mname, formula in self.metrics:
            try:
                out[mname] = compile_formula(formula).eval(raw_events)
            except KeyError as e:
                if strict:
                    raise
                if skipped is not None:
                    skipped.append((mname, f"missing event {e.args[0]!r}"))
            except ZeroDivisionError:
                if strict:
                    raise
                if skipped is not None:
                    skipped.append((mname, "division by zero"))
        return out


def parse_group(text: str) -> PerfGroup:
    """Parse the LIKWID-like group format (GROUP/EVENTSET/METRICS)."""
    name, desc = "", ""
    events, metrics = [], []
    section = None
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("GROUP"):
            name = line.split(None, 1)[1].strip()
        elif line == "EVENTSET":
            section = "events"
        elif line == "METRICS":
            section = "metrics"
        elif line.startswith("DESC"):
            desc = line.split(None, 1)[1].strip()
        elif section == "events":
            events.append(line.split()[0])
        elif section == "metrics":
            parts = line.split(None, 1)
            if len(parts) == 2:
                metrics.append((parts[0], parts[1]))
    if not name:
        raise ValueError("group text missing GROUP header")
    return PerfGroup(name, events, metrics, desc)


# ROOFLINE: per-region roofline placement over marker work counters
# (repro.core.marker).  The template is shared with the calibrated
# re-registration path: without measured peaks the formulas reference the
# symbolic PEAK_FLOPS / HBM_BW names (HW_CONSTANTS fallback at eval
# time); with peaks they are baked in as numeric literals, so the
# resolved formula text itself carries the calibration inside any
# QuerySpec that references @ROOFLINE.* metrics.
_ROOFLINE_TEMPLATE = """
GROUP ROOFLINE
DESC marker-region roofline placement from work counters ({why})
EVENTSET
  flops
  bytes
  time_s
METRICS
  intensity           flops / bytes
  achieved_gflops     flops / time_s / 1e9
  attainable_gflops   min({pf}, {bw} * flops / bytes) / 1e9
  roofline_frac       flops / time_s / min({pf}, {bw} * flops / bytes)
"""


def roofline_group_text(peak_flops: Optional[float] = None,
                        peak_bw: Optional[float] = None) -> str:
    """The ROOFLINE group text, with measured peaks baked in when given."""
    if peak_flops is None and peak_bw is None:
        return _ROOFLINE_TEMPLATE.format(pf="PEAK_FLOPS", bw="HBM_BW",
                                         why="hardware-constant peaks")
    pf = float(PEAK_FLOPS if peak_flops is None else peak_flops)
    bw = float(HBM_BW if peak_bw is None else peak_bw)
    return _ROOFLINE_TEMPLATE.format(pf=repr(pf), bw=repr(bw),
                                     why="calibrated peaks")


# The built-in groups (TPU analogues of the paper's §V metric list).
_GROUP_TEXTS = [
    """
    GROUP FLOPS
    DESC floating point throughput and machine utilization (IPC analogue)
    EVENTSET
      hlo_flops
      model_flops
      step_time_s
    METRICS
      gflops_per_s        hlo_flops / step_time_s / 1e9
      hw_flops_util       hlo_flops / step_time_s / PEAK_FLOPS
      mfu                 model_flops / step_time_s / PEAK_FLOPS
      useful_flop_ratio   model_flops / hlo_flops
    """,
    """
    GROUP MEM
    DESC memory bandwidth and footprint
    EVENTSET
      hlo_bytes
      step_time_s
      hbm_bytes_in_use
    METRICS
      mem_gb_per_s        hlo_bytes / step_time_s / 1e9
      hbm_bw_util         hlo_bytes / step_time_s / HBM_BW
      hbm_used_gb         hbm_bytes_in_use / 1e9
    """,
    """
    GROUP ICI
    DESC interconnect (collective) traffic — the QPI/network analogue
    EVENTSET
      collective_bytes
      wire_bytes
      step_time_s
    METRICS
      ici_gb_per_s        collective_bytes / step_time_s / 1e9
      ici_bw_util         collective_bytes / step_time_s / ICI_BW
      ici_wire_gb_per_s   wire_bytes / step_time_s / 1e9
      ici_wire_bw_util    wire_bytes / step_time_s / ICI_BW
    """,
    """
    GROUP GOODPUT
    DESC end-to-end job progress (the "CPU load" analogue for a TPU job)
    EVENTSET
      step_time_s
      tokens_per_step
      data_wait_s
    METRICS
      tokens_per_s        tokens_per_step / step_time_s
      data_stall_frac     data_wait_s / step_time_s
      steps_per_s         1.0 / step_time_s
    """,
    roofline_group_text(),
]

GROUPS = {g.name: g for g in (parse_group(t) for t in _GROUP_TEXTS)}


def available_groups() -> list:
    return sorted(GROUPS)


def register_group(text: str) -> PerfGroup:
    """Parse and register a deployment-specific group (LIKWID drops group
    files into a directory; here the text registers in-process).  Its
    metrics immediately become resolvable by :func:`formula_for`, i.e.
    answerable by the query engine *retroactively* over stored raw events
    — no collection-time change needed."""
    g = parse_group(text)
    GROUPS[g.name] = g
    return g


def formula_for(metric: str) -> Optional[str]:
    """The formula behind a group metric name, or None.

    ``metric`` may be qualified (``MEM.hbm_bw_util``) to pin a group, or
    bare (``hbm_bw_util``) to search every registered group — the hook
    that lets a query spec (``repro.core.query``) or an analysis rule name
    any group metric and have it derived at query time from stored raw
    events."""
    if "." in metric:
        gname, _, mname = metric.partition(".")
        g = GROUPS.get(gname)
        if g is not None:
            for name, formula in g.metrics:
                if name == mname:
                    return formula
        return None
    # snapshot before iterating: register_group may insert concurrently
    # (the httpd is a threading server), and a size change mid-iteration
    # would raise RuntimeError out of a perfectly valid query
    for g in list(GROUPS.values()):
        for name, formula in g.metrics:
            if name == metric:
                return formula
    return None


def derive_all(raw_events: dict, skipped: Optional[list] = None) -> dict:
    """Run every group whose event set is (partially) satisfied."""
    out = {}
    for g in list(GROUPS.values()):     # snapshot vs concurrent register
        out.update(g.derive(raw_events, skipped=skipped))
    return out
