"""Model zoo: layers, attention, MoE, SSM mixers, and assembly."""
