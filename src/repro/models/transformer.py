"""Model assembly: decoder-only / enc-dec / SSM / hybrid LMs.

One entry point, :func:`forward`, serves all 10 assigned architectures in all
three execution modes (train / prefill / decode).  Layers are *scanned* with
stacked parameters — essential to keep HLO size and compile time flat across
60–96-layer configs in the 80-compile dry-run matrix.

Caches are pytrees stacked over the layer axis, so the same scan carries
them; decode-time cache writes are one-hot selects (GSPMD-safe when the cache
sequence axis is sharded, see ``attention.onehot_update``).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attn_specs, cross_attention, cross_kv, gqa_attention, mla_attention,
    mla_specs)
from repro.models.layers import (
    apply_mlp, apply_norm, cross_entropy, embed_tokens, embedding_specs,
    lm_logits, mlp_specs, mrope_table, norm_specs, rope_table)
from repro.models.moe import apply_moe, moe_specs
from repro.models.params import abstract_params, init_params, spec, stack_specs
from repro.parallel.sharding import NullConstraints


# ==========================================================================
# Per-layer specs
# ==========================================================================


def _attn_block_specs(cfg: ModelConfig, mlp_override: Optional[int] = None,
                      moe_layer: bool = False, cross: bool = False):
    out = {"ln1": norm_specs(cfg)}
    if cfg.attention_type == "mla":
        out["attn"] = mla_specs(cfg)
    else:
        out["attn"] = attn_specs(cfg)
    if cross:
        out["ln_cross"] = norm_specs(cfg)
        out["cross"] = attn_specs(cfg)
    out["ln2"] = norm_specs(cfg)
    if moe_layer:
        out["moe"] = moe_specs(cfg)
    else:
        out["mlp"] = mlp_specs(cfg, d_ff=mlp_override)
    return out


def _layer_plan(cfg: ModelConfig) -> dict:
    """How many layers of each kind, as stacked groups."""
    if cfg.family == "ssm":                               # rwkv6
        return {"rwkv": cfg.num_layers}
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid.attn_every
        rem = cfg.num_layers - n_groups * cfg.hybrid.attn_every
        return {"hybrid_groups": n_groups, "hybrid_rem": rem}
    if cfg.moe is not None:
        return {"dense": cfg.moe.num_dense_layers,
                "moe": cfg.num_layers - cfg.moe.num_dense_layers}
    return {"dense": cfg.num_layers}


def model_specs(cfg: ModelConfig):
    """Full parameter-spec tree (stacked layers)."""
    plan = _layer_plan(cfg)
    out: dict = {"embed": embedding_specs(cfg),
                 "final_norm": norm_specs(cfg)}

    if cfg.family == "ssm":
        blk = ssm_mod.rwkv6_specs(cfg)
        out["layers"] = stack_specs(blk, plan["rwkv"])
    elif cfg.family == "hybrid":
        mamba = ssm_mod.mamba2_specs(cfg)
        mamba = {"ln": norm_specs(cfg), **mamba}
        ae = cfg.hybrid.attn_every
        if plan["hybrid_groups"]:
            out["groups"] = stack_specs(
                stack_specs(mamba, ae, "inner_layers"),
                plan["hybrid_groups"])
        if plan["hybrid_rem"]:
            out["rem"] = stack_specs(mamba, plan["hybrid_rem"])
        out["shared"] = stack_specs(_attn_block_specs(cfg),
                                    cfg.hybrid.num_shared_blocks)
    else:
        if plan.get("dense"):
            dff = cfg.moe.d_ff_dense if (cfg.moe is not None
                                         and cfg.moe.d_ff_dense) else None
            out["dense_layers"] = stack_specs(
                _attn_block_specs(cfg, mlp_override=dff), plan["dense"])
        if plan.get("moe"):
            out["moe_layers"] = stack_specs(
                _attn_block_specs(cfg, moe_layer=True), plan["moe"])

    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg)
        out["encoder"] = {
            "layers": stack_specs(_attn_block_specs(enc_cfg),
                                  cfg.num_encoder_layers),
            "final_norm": norm_specs(cfg),
        }
        # decoder self-attn blocks get a cross-attention sublayer
        dff = None
        out.pop("dense_layers", None)
        out["dec_layers"] = stack_specs(
            _attn_block_specs(cfg, mlp_override=dff, cross=True),
            cfg.num_layers)
    return out


# ==========================================================================
# Caches — spec'd with logical axes (single source of truth for shapes,
# shardings and zero-init; mirrors the params system)
# ==========================================================================


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """ParamSpec tree for the decode caches (all zero-init).

    Logical axes drive the dry-run shardings: KV caches shard batch over DP
    and kv_heads over TP, falling back to the cache sequence dim when
    kv_heads does not divide (see ``_AXIS_PRIORITY`` in parallel.sharding).
    """
    plan = _layer_plan(cfg)
    kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len

    def attn_cache():
        if cfg.attention_type == "mla":
            a = cfg.mla
            return {"ckv": spec((batch, max_len, a.kv_lora_rank),
                                ("batch", "cache_seq", None), dtype,
                                init="zeros"),
                    "krope": spec((batch, max_len, a.qk_rope_head_dim),
                                  ("batch", "cache_seq", None), dtype,
                                  init="zeros")}
        kv = spec((batch, kv_len, cfg.num_kv_heads, cfg.head_dim),
                  ("batch", "cache_seq", "kv_heads", None), dtype,
                  init="zeros")
        return {"k": kv, "v": kv}

    def mamba_cache():
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.num_heads(cfg.d_model)
        conv_dim = di + 2 * s.n_groups * s.state_dim
        return {"conv": spec((batch, s.conv_width - 1, conv_dim),
                             ("batch", None, "inner"), dtype, init="zeros"),
                "ssm": spec((batch, nh, s.head_dim, s.state_dim),
                            ("batch", "ssm_heads", None, None), jnp.float32,
                            init="zeros")}

    def rwkv_cache():
        d = cfg.d_model
        nh = d // cfg.rwkv.head_dim
        return {"shift_tm": spec((batch, d), ("batch", "embed"), dtype,
                                 init="zeros"),
                "shift_cm": spec((batch, d), ("batch", "embed"), dtype,
                                 init="zeros"),
                "wkv": spec((batch, nh, cfg.rwkv.head_dim,
                             cfg.rwkv.head_dim),
                            ("batch", "ssm_heads", None, None), jnp.float32,
                            init="zeros")}

    if cfg.family == "ssm":
        return stack_specs(rwkv_cache(), plan["rwkv"])
    if cfg.family == "hybrid":
        out = {}
        ae = cfg.hybrid.attn_every
        if plan["hybrid_groups"]:
            out["groups"] = stack_specs(
                stack_specs(mamba_cache(), ae, "inner_layers"),
                plan["hybrid_groups"])
            out["shared_attn"] = stack_specs(attn_cache(),
                                             plan["hybrid_groups"])
        if plan["hybrid_rem"]:
            out["rem"] = stack_specs(mamba_cache(), plan["hybrid_rem"])
        return out
    if cfg.family == "encdec":
        cross = spec((batch, cfg.encdec_source_len, cfg.num_kv_heads,
                      cfg.head_dim),
                     ("batch", "cache_seq", "kv_heads", None), dtype,
                     init="zeros")
        return {"self": stack_specs(attn_cache(), cfg.num_layers),
                "cross": stack_specs({"k": cross, "v": cross},
                                     cfg.num_layers)}
    out = {}
    if plan.get("dense"):
        out["dense"] = stack_specs(attn_cache(), plan["dense"])
    if plan.get("moe"):
        out["moe"] = stack_specs(attn_cache(), plan["moe"])
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Concrete zero caches matching forward()'s scan layout."""
    return init_params(cache_specs(cfg, batch, max_len, dtype))


# ==========================================================================
# Blocks
# ==========================================================================


ZERO_AUX = {"moe_aux_loss": 0.0, "moe_dropped_frac": 0.0, "moe_max_load": 0.0}


def _zero_aux():
    return {k: jnp.float32(v) for k, v in ZERO_AUX.items()}


def _attn_block(p, x, cfg, *, rope, mode, cache, pos, pc, attn_impl,
                moe_layer=False, cross_kv_cache=None, bidirectional=False,
                cache_update="onehot"):
    """Pre-norm transformer block; returns (x, new_cache, aux)."""
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.attention_type == "mla":
        y, new_cache = mla_attention(p["attn"], h, cfg, rope=rope, mode=mode,
                                     cache=cache, pos=pos,
                                     attn_impl=attn_impl,
                                     cache_update=cache_update)
    else:
        y, new_cache = gqa_attention(
            p["attn"], h, cfg, rope=rope, mode=mode, cache=cache, pos=pos,
            attn_impl=attn_impl, bidirectional=bidirectional,
            cache_update=cache_update,
            kv_out_constraint=(pc.kv_cache if pc is not None else None))
    x = x + y
    if cross_kv_cache is not None:
        h = apply_norm(p["ln_cross"], x, cfg)
        x = x + cross_attention(p["cross"], h, cross_kv_cache, cfg)
    h = apply_norm(p["ln2"], x, cfg)
    aux = _zero_aux()
    if moe_layer:
        y, moe_aux = apply_moe(p["moe"], h, cfg, pc=pc)
        aux.update({k: jnp.asarray(v, jnp.float32)
                    for k, v in moe_aux.items()})
    else:
        y = apply_mlp(p["mlp"], h, cfg)
    x = x + y
    if pc is not None:
        x = pc.tokens(x)
    return x, new_cache, aux


def _rwkv_block(p, x, cfg, *, mode, cache):
    ln_tm = {"scale": p["ln_tm_scale"], "bias": p["ln_tm_bias"]}
    ln_cm = {"scale": p["ln_cm_scale"], "bias": p["ln_cm_bias"]}
    lcfg = dataclasses.replace(cfg, norm_type="layernorm")
    y, c_tm = ssm_mod.rwkv6_time_mix(p, apply_norm(ln_tm, x, lcfg), cfg,
                                     mode=mode, cache=cache)
    x = x + y
    y, c_cm = ssm_mod.rwkv6_channel_mix(p, apply_norm(ln_cm, x, lcfg), cfg,
                                        mode=mode, cache=cache)
    x = x + y
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {**cache, **(c_tm or {}), **(c_cm or {})}
    return x, new_cache


def _mamba_block(p, x, cfg, *, mode, cache, pc):
    h = apply_norm(p["ln"], x, cfg)
    y, new_cache = ssm_mod.mamba2_block(
        {k: v for k, v in p.items() if k != "ln"}, h, cfg,
        mode=mode, cache=cache)
    x = x + y
    if pc is not None:
        x = pc.tokens(x)
    return x, new_cache


# ==========================================================================
# Forward
# ==========================================================================


def _combine_aux(acc, aux):
    return {
        "moe_aux_loss": acc["moe_aux_loss"] + aux["moe_aux_loss"],
        "moe_dropped_frac": acc["moe_dropped_frac"] + aux["moe_dropped_frac"],
        "moe_max_load": jnp.maximum(acc["moe_max_load"], aux["moe_max_load"]),
    }


def _rope_for(cfg: ModelConfig, positions, extras):
    if cfg.rope_type == "none":
        return None
    hd = cfg.mla.qk_rope_head_dim if cfg.attention_type == "mla" \
        else cfg.head_dim
    if cfg.rope_type == "mrope":
        mpos = extras["mrope_pos"]                        # (B, S, 3)
        return mrope_table(mpos, hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_table(positions, hd, cfg.rope_theta)


def _sinusoidal(positions, d):
    """Absolute sinusoidal position encoding (enc-dec family)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _scan_layers(body, x, stacked_params, stacked_cache, *, remat="none",
                 unroll: int = 1):
    """Scan ``body(x, layer_params, layer_cache) -> (x, new_cache, aux)``."""
    if remat != "none":
        policy = {"minimal": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                  "full": jax.checkpoint_policies.nothing_saveable}[remat]
        body = jax.checkpoint(body, policy=policy)

    def step(carry, xs):
        x, aux_acc = carry
        lp, lc = xs
        x, new_cache, aux = body(x, lp, lc)
        return (x, _combine_aux(aux_acc, aux)), new_cache

    (x, aux), new_caches = jax.lax.scan(
        step, (x, _zero_aux()), (stacked_params, stacked_cache),
        unroll=unroll)
    return x, new_caches, aux


def forward(params, cfg: ModelConfig, *, tokens, mode="train", cache=None,
            pos=None, pc=None, extras=None, attn_impl="masked",
            remat="none", scan_unroll: int = 1, cache_update="onehot"):
    """Run the model.

    tokens: (B, S) int32.  decode: S is the number of new tokens (1).
    cache: stacked cache pytree (prefill out / decode in-out).
    pos: scalar int32 — tokens already in the cache (decode only).
    extras: modality inputs — {"src_frames", "patches", "mrope_pos"}.
    Returns (logits, new_cache, aux).
    """
    pc = pc or NullConstraints()
    extras = extras or {}
    b, s = tokens.shape
    if pos is None:
        positions = jnp.arange(s)[None, :]
    else:
        positions = pos + jnp.arange(s)[None, :]

    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm" and "patches" in extras:
        patches = extras["patches"].astype(x.dtype)       # (B, P, d)
        p_len = patches.shape[1]
        x = jnp.concatenate([x[:, :1], patches, x[:, 1 + p_len:]], axis=1)
    if cfg.family == "encdec":
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
    x = pc.tokens(x)

    rope = _rope_for(cfg, positions, extras)
    aux = _zero_aux()
    new_cache: Any = None

    # ---------------- family dispatch -------------------------------------
    if cfg.family == "ssm":
        def body(x, lp, lc):
            x, nc = _rwkv_block(lp, x, cfg, mode=mode,
                                cache=(None if mode == "train" else lc))
            return x, nc, _zero_aux()
        lc = cache if cache is not None else _dummy_cache(cfg, b, mode)
        x, new_cache, aux = _scan_layers(body, x, params["layers"], lc,
                                         remat=remat if mode == "train"
                                         else "none", unroll=scan_unroll)

    elif cfg.family == "hybrid":
        x, new_cache, aux = _hybrid_forward(
            params, x, cfg, mode=mode, cache=cache, pos=pos, rope=rope,
            pc=pc, attn_impl=attn_impl, remat=remat,
            scan_unroll=scan_unroll, cache_update=cache_update)

    elif cfg.family == "encdec":
        x, new_cache, aux = _encdec_forward(
            params, x, cfg, mode=mode, cache=cache, pos=pos, pc=pc,
            extras=extras, attn_impl=attn_impl, remat=remat,
            scan_unroll=scan_unroll, cache_update=cache_update)

    else:
        new_cache = {}
        trem = remat if mode == "train" else "none"
        for group, key in (("dense_layers", "dense"), ("moe_layers", "moe")):
            if group not in params:
                continue
            moe_layer = key == "moe"

            def body(x, lp, lc, moe_layer=moe_layer):
                return _attn_block(lp, x, cfg, rope=rope, mode=mode,
                                   cache=(None if mode == "train" else lc),
                                   pos=pos, pc=pc, attn_impl=attn_impl,
                                   moe_layer=moe_layer,
                                   cache_update=cache_update)
            lc = cache[key] if cache is not None \
                else _dummy_cache(cfg, b, mode,
                                  n=jax.tree.leaves(params[group])[0].shape[0])
            x, nc, a = _scan_layers(body, x, params[group], lc, remat=trem,
                                    unroll=scan_unroll)
            new_cache[key] = nc
            aux = _combine_aux(aux, a)
        if not any(k in params for k in ("dense_layers", "moe_layers")):
            raise ValueError("no layer groups in params")

    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)
    logits = pc.logits(logits)
    return logits, new_cache, aux


def _dummy_cache(cfg, batch, mode, n=None):
    """Scan requires an xs tree even when no cache flows (train mode)."""
    n = n if n is not None else cfg.num_layers
    return jnp.zeros((n, 0), jnp.float32)


# -- hybrid (zamba2) --------------------------------------------------------


def _hybrid_forward(params, x, cfg, *, mode, cache, pos, rope, pc, attn_impl,
                    remat, scan_unroll, cache_update="onehot"):
    ae = cfg.hybrid.attn_every
    nsb = cfg.hybrid.num_shared_blocks
    aux = _zero_aux()
    new_cache = {}
    trem = remat if mode == "train" else "none"
    b = x.shape[0]

    if "groups" in params:
        n_groups = jax.tree.leaves(params["groups"])[0].shape[0]

        # The shared-attention caches are the dominant decode state (13 x
        # 500k KV at long context); they ride the scan CARRY with per-group
        # dynamic slice/update so XLA keeps one aliased buffer — as scan
        # xs/ys they would be double-buffered and re-stacked every step
        # (§Perf: zamba2 long_500k memory term -~2x).
        def group_body(carry, xs):
            x, aux_acc, ac_all = carry
            gp, gc, gi = xs
            ac = None
            if ac_all is not None:
                ac = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, gi, axis=0, keepdims=False), ac_all)

            def inner(x, lp, lc):
                x, nc = _mamba_block(lp, x, cfg, mode=mode,
                                     cache=(None if mode == "train" else lc),
                                     pc=pc)
                return x, nc, _zero_aux()
            if trem != "none":
                policy = {"minimal":
                          jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                          "full": jax.checkpoint_policies.nothing_saveable}[trem]
                inner = jax.checkpoint(inner, policy=policy)

            def mamba_step(c, xs2):
                x = c
                lp, lc = xs2
                x, nc, _ = inner(x, lp, lc)
                return x, nc
            x, new_gc = jax.lax.scan(mamba_step, x, (gp, gc))

            # shared attention block, weights alternate over applications
            sel = jnp.mod(gi, nsb)
            sp = jax.tree.map(lambda w: w[sel], params["shared"])
            x, new_ac, a = _attn_block(sp, x, cfg, rope=rope, mode=mode,
                                       cache=(None if mode == "train"
                                              else ac),
                                       pos=pos, pc=pc, attn_impl=attn_impl,
                                       cache_update=cache_update)
            if ac_all is not None and new_ac is not None:
                ac_all = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), gi, axis=0), ac_all, new_ac)
            return (x, _combine_aux(aux_acc, a), ac_all), new_gc

        gc = cache["groups"] if cache is not None \
            else jnp.zeros((n_groups, ae, 0))
        ac_all0 = cache["shared_attn"] if cache is not None else None
        (x, aux, new_ac_all), new_gc = jax.lax.scan(
            group_body, (x, aux, ac_all0),
            (params["groups"], gc, jnp.arange(n_groups)))
        new_cache["groups"] = new_gc
        new_cache["shared_attn"] = new_ac_all

    if "rem" in params:
        def body(x, lp, lc):
            x, nc = _mamba_block(lp, x, cfg, mode=mode,
                                 cache=(None if mode == "train" else lc),
                                 pc=pc)
            return x, nc, _zero_aux()
        rc = cache["rem"] if cache is not None else _dummy_cache(
            cfg, b, mode, n=jax.tree.leaves(params["rem"])[0].shape[0])
        x, new_rc, _ = _scan_layers(body, x, params["rem"], rc, remat=trem,
                                    unroll=scan_unroll)
        new_cache["rem"] = new_rc
    return x, (new_cache if mode != "train" else None), aux


# -- encoder-decoder (seamless) ----------------------------------------------


def encode(params, cfg: ModelConfig, src_frames, pc=None, remat="none"):
    """Encoder over (stubbed) frame embeddings -> (B, S_src, d)."""
    pc = pc or NullConstraints()
    x = src_frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])[None, :]
    x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)
    x = pc.tokens(x)

    def body(x, lp, lc):
        return _attn_block(lp, x, cfg, rope=None, mode="train", cache=lc,
                           pos=None, pc=pc, attn_impl="masked",
                           bidirectional=True)
    n = jax.tree.leaves(params["encoder"]["layers"])[0].shape[0]
    x, _, _ = _scan_layers(body, x, params["encoder"]["layers"],
                           _dummy_cache(cfg, x.shape[0], "train", n=n),
                           remat=remat)
    return apply_norm(params["encoder"]["final_norm"], x, cfg)


def encdec_cross_caches(params, cfg: ModelConfig, enc_out):
    """Per-decoder-layer cross K/V, stacked: (L, B, S_src, KV, D)."""
    def one(lp):
        return cross_kv(lp["cross"], enc_out, cfg)
    return jax.lax.map(one, params["dec_layers"])


def _encdec_forward(params, x, cfg, *, mode, cache, pos, pc, extras,
                    attn_impl, remat, scan_unroll, cache_update="onehot"):
    trem = remat if mode == "train" else "none"
    b = x.shape[0]
    if mode in ("train", "prefill"):
        enc_out = encode(params, cfg, extras["src_frames"], pc=pc,
                         remat=trem)
        cross_caches = encdec_cross_caches(params, cfg, enc_out)
    else:
        cross_caches = cache["cross"]

    def body(x, lp, lc):
        sc, cc = lc
        return _attn_block(lp, x, cfg, rope=None, mode=mode, cache=sc,
                           pos=pos, pc=pc, attn_impl=attn_impl,
                           cross_kv_cache=cc, cache_update=cache_update)

    n = jax.tree.leaves(params["dec_layers"])[0].shape[0]
    sc = cache["self"] if cache is not None else _dummy_cache(cfg, b, mode,
                                                              n=n)
    x, new_sc, aux = _scan_layers(body, x, params["dec_layers"],
                                  (sc, cross_caches), remat=trem,
                                  unroll=scan_unroll)
    new_cache = None
    if mode != "train":
        new_cache = {"self": new_sc,
                     "cross": jax.tree.map(
                         lambda c: c.astype(jnp.bfloat16), cross_caches)
                     if mode == "prefill" else cache["cross"]}
    return x, new_cache, aux


# ==========================================================================
# Loss / steps (pure model level; the distributed step lives in repro.train)
# ==========================================================================


def loss_fn(params, cfg: ModelConfig, batch, *, pc=None, attn_impl="masked",
            remat="none", scan_unroll: int = 1):
    """Next-token CE loss + aux.  batch: {"tokens", "labels", extras...}."""
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits, _, aux = forward(params, cfg, tokens=batch["tokens"],
                             mode="train", pc=pc, extras=extras,
                             attn_impl=attn_impl, remat=remat,
                             scan_unroll=scan_unroll)
    mask = (batch["labels"] >= 0)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy(logits, labels, cfg, mask=mask)
    total = loss
    if cfg.moe is not None:
        total = total + 0.01 * aux["moe_aux_loss"] / max(cfg.num_layers, 1)
    metrics = {"loss": loss, **aux}
    return total, metrics


def init_model_params(cfg: ModelConfig, seed: int = 0):
    return init_params(model_specs(cfg), seed)


def abstract_model_params(cfg: ModelConfig):
    return abstract_params(model_specs(cfg))
